"""On-device entropy codec for the SZ-like int32 residual codes
(DESIGN.md §8): chunked bitplane / fixed-length packing.

DEFLATE was the last pipeline stage still running on the host after the
device-resident compress (§4) and decompress (§5) paths landed — and the
sole reason the stream scheduler needs worker-thread pools. This module
replaces it with a device codec in the TopoSZp mold (lightweight,
embarrassingly parallel, no byte-sequential state):

* the flat code array splits into fixed ``CHUNK``-code chunks;
* each chunk zigzag-maps its codes to uint32 (small magnitudes of either
  sign become small unsigned values) and keeps only the ``b`` lowest
  bitplanes, where ``b`` is the bit length of the chunk's max magnitude
  — Lorenzo residuals are tiny almost everywhere, so most chunks store
  a handful of planes and a constant chunk stores none;
* plane ``k`` of a chunk is the k-th bit of all ``CHUNK`` codes,
  transposed into ``CHUNK/32`` uint32 words (bit ``t`` of word ``m`` is
  code ``m*32+t``'s bit ``k``), so a chunk occupies exactly
  ``b * CHUNK/32`` words of the output stream;
* chunk output offsets are an exclusive parallel prefix sum over the
  per-chunk word counts (``szlike.int32_cumsum`` — the PR-4 slab-carry
  scan — is the building block), followed by one scatter that compacts
  the worst-case-dense per-chunk regions into the final stream.

Three bitwise-identical implementations share this layout contract:

* ``pack_codes_pallas`` / ``unpack_codes_pallas`` — the production
  kernels: one grid program per chunk computes the chunk's bit width
  and its 32 transposed planes with static loops and 2D iotas (VPU
  vector ops; blocks are (1, CHUNK) so the lane dimension stays a
  multiple of 128). The offset scan + compaction scatter stay XLA-level
  around the kernel — a hand-rolled Pallas scan would only re-derive
  ``int32_cumsum``.
* ``pack_codes_jnp`` / ``unpack_codes_jnp`` — pure-jnp twins (the
  ``reference`` backend, and what the ``sharded`` backend runs on its
  global arrays: every per-chunk stage is independent, so GSPMD
  partitions it for free).
* ``pack_codes_host`` / ``unpack_codes_host`` — the numpy mirror that
  backs the byte-level blob codec in ``compress.szlike`` (host-path
  artifacts, conformance tests). All integer arithmetic, so host and
  device agree bit for bit.

Everything is exact integer work — no rounding contract needed. The
full int32 range round-trips, including ``INT32_MIN`` (zigzag
``0xFFFFFFFF``, 32 planes).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .extrema import default_interpret

#: codes per chunk — one bit-width decision (and one pallas grid
#: program) per CHUNK codes; must stay a multiple of 32 so bitplanes
#: transpose into whole uint32 words
CHUNK = 1024


def words_per_plane(chunk: int = CHUNK) -> int:
    """uint32 words one bitplane of a ``chunk``-code chunk occupies."""
    if chunk % 32:
        raise ValueError(f"chunk must be a multiple of 32, got {chunk}")
    return chunk // 32


# ---------------------------------------------------------------------------
# shared jnp building blocks (also what the pallas wrappers compose with)
# ---------------------------------------------------------------------------

def _zigzag_jnp(r: jnp.ndarray) -> jnp.ndarray:
    """int32 -> uint32 zigzag map (0,-1,1,-2,.. -> 0,1,2,3,..); exact
    bit-level twin of ``_zigzag_np``."""
    zz = jnp.bitwise_xor(r << 1, r >> 31)          # int32 wrap is defined
    return jax.lax.bitcast_convert_type(zz, jnp.uint32)


def _unzigzag_jnp(u: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ``_zigzag_jnp``: uint32 -> int32."""
    v = (u >> jnp.uint32(1)) ^ (jnp.uint32(0) - (u & jnp.uint32(1)))
    return jax.lax.bitcast_convert_type(v, jnp.int32)


def _chunk_layout(n: int, chunk: int) -> Tuple[int, int, int]:
    """(n_chunks, padded length, words/plane) of an ``n``-code stream."""
    wpp = words_per_plane(chunk)
    n_chunks = -(-n // chunk) if n else 0
    return n_chunks, n_chunks * chunk, wpp


def _offsets_jnp(bits: jnp.ndarray, wpp: int):
    """(exclusive word offsets, total words) from per-chunk bit widths,
    via the ``int32_cumsum`` slab-carry scan (exact in int32: the stream
    is at most n_codes words, and code counts fit int32 by the device
    path's own size regime)."""
    from ..compress.szlike import int32_cumsum
    words = bits * jnp.int32(wpp)
    # mszlint: disable=int32-range -- per-chunk word counts are bounded
    # by the stream length (<= n_codes words), which fits int32 by the
    # device path's own size regime
    ends = int32_cumsum(words, 0)
    return ends - words, ends[-1] if bits.size else jnp.int32(0)


def _pack_planes_jnp(u3: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(dense, bits) of zigzagged chunks ``u3`` (n_chunks, wpp, 32):
    ``dense`` (n_chunks, 32*wpp) holds every chunk's 32 transposed
    bitplanes plane-major, ``bits`` the per-chunk bit widths."""
    n_chunks, wpp, _ = u3.shape
    maxu = jnp.max(u3, axis=(1, 2)) if n_chunks else \
        jnp.zeros((0,), jnp.uint32)
    bits = (jnp.uint32(32) - jax.lax.clz(maxu)).astype(jnp.int32)
    t = jax.lax.broadcasted_iota(jnp.uint32, (n_chunks, wpp, 32), 2)
    planes = [jnp.sum(((u3 >> jnp.uint32(k)) & jnp.uint32(1)) << t,
                      axis=2, dtype=jnp.uint32) for k in range(32)]
    dense = jnp.stack(planes, axis=1).reshape(n_chunks, 32 * wpp)
    return dense, bits


def _unpack_planes_jnp(dense: jnp.ndarray, wpp: int) -> jnp.ndarray:
    """Inverse of ``_pack_planes_jnp``: dense (n_chunks, 32*wpp) with
    absent planes zero-filled -> zigzagged codes (n_chunks, wpp, 32)."""
    n_chunks = dense.shape[0]
    d3 = dense.reshape(n_chunks, 32, wpp)
    t = jax.lax.broadcasted_iota(jnp.uint32, (n_chunks, wpp, 32), 2)
    u3 = jnp.zeros((n_chunks, wpp, 32), jnp.uint32)
    for k in range(32):
        u3 = u3 | (((d3[:, k, :, None] >> t) & jnp.uint32(1))
                   << jnp.uint32(k))
    return u3


def _compact_jnp(dense: jnp.ndarray, bits: jnp.ndarray, wpp: int):
    """Scatter the per-chunk dense regions into the compact stream:
    (words[capacity], n_words). Capacity is the b=32 worst case (one
    word per code); callers slice to ``n_words`` after a host sync."""
    n_chunks, region = dense.shape
    cap = n_chunks * region
    offsets, n_words = _offsets_jnp(bits, wpp)
    j = jnp.arange(region, dtype=jnp.int32)
    valid = j[None, :] < (bits * jnp.int32(wpp))[:, None]
    gidx = jnp.where(valid, offsets[:, None] + j[None, :], jnp.int32(cap))
    out = jnp.zeros((cap,), jnp.uint32)
    out = out.at[gidx.reshape(-1)].add(
        jnp.where(valid, dense, jnp.uint32(0)).reshape(-1), mode="drop")
    return out, n_words


def _expand_jnp(words: jnp.ndarray, bits: jnp.ndarray, wpp: int
                ) -> jnp.ndarray:
    """Gather each chunk's words out of the compact stream into the
    zero-filled dense layout ``_unpack_planes_jnp`` consumes. ``words``
    may be the exact ``n_words``-long stream — invalid lanes gather
    clipped and are masked to zero."""
    n_chunks = bits.shape[0]
    region = 32 * wpp
    offsets, _ = _offsets_jnp(bits, wpp)
    j = jnp.arange(region, dtype=jnp.int32)
    valid = j[None, :] < (bits * jnp.int32(wpp))[:, None]
    gidx = offsets[:, None] + j[None, :]
    # one sentinel word so an all-constant stream (zero words total)
    # still has a gatherable axis; valid lanes never reach it
    padded = jnp.concatenate([words, jnp.zeros((1,), jnp.uint32)])
    gathered = jnp.take(padded, gidx, mode="clip")
    return jnp.where(valid, gathered, jnp.uint32(0))


# ---------------------------------------------------------------------------
# pure-jnp codec (reference backend; sharded runs it on global arrays)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk",))
def pack_codes_jnp(r: jnp.ndarray, chunk: int = CHUNK):
    """Pack int32 residual codes into the chunked-bitplane stream.

    Returns ``(words, bits, n_words)``: ``words`` a capacity-sized
    uint32 array (jit outputs are static-shaped; only the first
    ``n_words`` entries are the stream — slice after a host sync),
    ``bits`` the per-chunk widths (int32), ``n_words`` the stream
    length as a device scalar.
    """
    n = r.size
    n_chunks, n_pad, wpp = _chunk_layout(n, chunk)
    if n_chunks == 0:
        return (jnp.zeros((0,), jnp.uint32), jnp.zeros((0,), jnp.int32),
                jnp.int32(0))
    flat = jnp.pad(r.reshape(-1).astype(jnp.int32), (0, n_pad - n))
    u3 = _zigzag_jnp(flat).reshape(n_chunks, wpp, 32)
    dense, bits = _pack_planes_jnp(u3)
    words, n_words = _compact_jnp(dense, bits, wpp)
    return words, bits, n_words


@functools.partial(jax.jit, static_argnames=("shape", "chunk"))
def unpack_codes_jnp(words: jnp.ndarray, bits: jnp.ndarray,
                     shape: Tuple[int, ...], chunk: int = CHUNK
                     ) -> jnp.ndarray:
    """Inverse of ``pack_codes_jnp``: the int32 code array of ``shape``
    from the packed stream (``words`` may be exactly ``n_words`` long)."""
    n = 1
    for s in shape:
        n *= int(s)
    n_chunks, _, wpp = _chunk_layout(n, chunk)
    if n_chunks == 0:
        return jnp.zeros(shape, jnp.int32)
    dense = _expand_jnp(words.astype(jnp.uint32), bits.astype(jnp.int32),
                        wpp)
    u3 = _unpack_planes_jnp(dense, wpp)
    return _unzigzag_jnp(u3.reshape(-1))[:n].reshape(shape)


# ---------------------------------------------------------------------------
# pallas kernels (production path; one grid program per chunk)
# ---------------------------------------------------------------------------

def _pack_kernel(u_ref, dense_ref, bits_ref, *, wpp: int):
    u = u_ref[...].reshape(wpp, 32)
    maxu = jnp.max(u)
    bits_ref[0, 0] = (jnp.uint32(32) - jax.lax.clz(maxu)).astype(jnp.int32)
    t = jax.lax.broadcasted_iota(jnp.uint32, (wpp, 32), 1)
    planes = [jnp.sum(((u >> jnp.uint32(k)) & jnp.uint32(1)) << t,
                      axis=1, dtype=jnp.uint32) for k in range(32)]
    dense_ref[...] = jnp.stack(planes, axis=0).reshape(1, 32 * wpp)


def _unpack_kernel(dense_ref, u_ref, *, wpp: int):
    d3 = dense_ref[...].reshape(32, wpp)
    t = jax.lax.broadcasted_iota(jnp.uint32, (wpp, 32), 1)
    u = jnp.zeros((wpp, 32), jnp.uint32)
    for k in range(32):
        u = u | (((d3[k][:, None] >> t) & jnp.uint32(1)) << jnp.uint32(k))
    u_ref[...] = u.reshape(1, 32 * wpp)


def pack_codes_pallas(r: jnp.ndarray, chunk: int = CHUNK, *,
                      interpret: Optional[bool] = None):
    """``pack_codes_jnp`` with the per-chunk plane transpose running as
    a Pallas kernel (grid over chunks, (1, chunk) uint32 blocks — lane
    dimension a multiple of 128). The offset prefix scan and the
    compaction scatter stay XLA-level around the kernel. Bitwise
    identical to the jnp and host codecs. The whole composition runs
    jitted so its scalar constants bake in at trace time (eager
    execution would ship them per call — an implicit transfer under
    ``debug.no_transfers()``)."""
    if interpret is None:
        interpret = default_interpret()
    return _pack_codes_pallas_jit(r, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _pack_codes_pallas_jit(r: jnp.ndarray, chunk: int, interpret: bool):
    n = r.size
    n_chunks, n_pad, wpp = _chunk_layout(n, chunk)
    if n_chunks == 0:
        return (jnp.zeros((0,), jnp.uint32), jnp.zeros((0,), jnp.int32),
                jnp.int32(0))
    flat = jnp.pad(r.reshape(-1).astype(jnp.int32), (0, n_pad - n))
    u2 = _zigzag_jnp(flat).reshape(n_chunks, chunk)
    dense, bits = pl.pallas_call(
        functools.partial(_pack_kernel, wpp=wpp),
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((1, chunk), lambda c: (c, 0))],
        out_specs=[pl.BlockSpec((1, chunk), lambda c: (c, 0)),
                   pl.BlockSpec((1, 1), lambda c: (c, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_chunks, chunk), jnp.uint32),
                   jax.ShapeDtypeStruct((n_chunks, 1), jnp.int32)],
        interpret=interpret,
    )(u2)
    words, n_words = _compact_jnp(dense, bits.reshape(-1), wpp)
    return words, bits.reshape(-1), n_words


def unpack_codes_pallas(words: jnp.ndarray, bits: jnp.ndarray,
                        shape: Tuple[int, ...], chunk: int = CHUNK, *,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """Inverse of ``pack_codes_pallas``: XLA-level expand gather, then
    the per-chunk plane transpose back to codes as a Pallas kernel.
    Jitted end to end (see ``pack_codes_pallas``)."""
    if interpret is None:
        interpret = default_interpret()
    return _unpack_codes_pallas_jit(words, bits, shape=tuple(shape),
                                    chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("shape", "chunk", "interpret"))
def _unpack_codes_pallas_jit(words: jnp.ndarray, bits: jnp.ndarray,
                             shape: Tuple[int, ...], chunk: int,
                             interpret: bool) -> jnp.ndarray:
    n = 1
    for s in shape:
        n *= int(s)
    n_chunks, _, wpp = _chunk_layout(n, chunk)
    if n_chunks == 0:
        return jnp.zeros(shape, jnp.int32)
    dense = _expand_jnp(words.astype(jnp.uint32), bits.astype(jnp.int32),
                        wpp)
    u2 = pl.pallas_call(
        functools.partial(_unpack_kernel, wpp=wpp),
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((1, chunk), lambda c: (c, 0))],
        out_specs=pl.BlockSpec((1, chunk), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, chunk), jnp.uint32),
        interpret=interpret,
    )(dense)
    return _unzigzag_jnp(u2.reshape(-1))[:n].reshape(shape)


# ---------------------------------------------------------------------------
# numpy mirror (byte-level blob codec + conformance oracle)
# ---------------------------------------------------------------------------

def _zigzag_np(r: np.ndarray) -> np.ndarray:
    v = np.asarray(r, np.int64)
    return (((v << 1) ^ (v >> 31)) & 0xFFFFFFFF).astype(np.uint32)


def _unzigzag_np(u: np.ndarray) -> np.ndarray:
    u = np.asarray(u, np.uint64)
    v = (u >> np.uint64(1)).astype(np.int64) ^ -(u & np.uint64(1)).astype(
        np.int64)
    return v.astype(np.int32)


def _bits_np(maxu: np.ndarray) -> np.ndarray:
    """Per-chunk bit widths: bit_length of the max zigzagged magnitude
    (exact — no float log2)."""
    thresholds = (np.uint64(1) << np.arange(32, dtype=np.uint64))
    return np.sum(maxu.astype(np.uint64)[:, None] >= thresholds[None, :],
                  axis=1).astype(np.int32)


def pack_codes_host(r: np.ndarray, chunk: int = CHUNK
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """numpy twin of ``pack_codes_jnp``: ``(words, bits)`` with
    ``words`` already sliced to the true stream length. Backs the
    host-path blob codec and the device-codec conformance oracle —
    int32 range required (the device codes' own domain)."""
    flat = np.asarray(r).reshape(-1)
    if flat.size and not (np.all(flat >= np.iinfo(np.int32).min)
                          and np.all(flat <= np.iinfo(np.int32).max)):
        raise ValueError("device-pack serves int32 residual codes only")
    n = flat.size
    n_chunks, n_pad, wpp = _chunk_layout(n, chunk)
    if n_chunks == 0:
        return np.zeros(0, np.uint32), np.zeros(0, np.int32)
    u3 = np.zeros(n_pad, np.uint32)
    u3[:n] = _zigzag_np(flat)
    u3 = u3.reshape(n_chunks, wpp, 32)
    bits = _bits_np(u3.max(axis=(1, 2)))
    t = np.arange(32, dtype=np.uint32)
    dense = np.empty((n_chunks, 32, wpp), np.uint32)
    for k in range(32):
        dense[:, k, :] = np.sum(
            ((u3 >> np.uint32(k)) & np.uint32(1)) << t, axis=2,
            dtype=np.uint32)
    keep = np.arange(32)[None, :] < bits[:, None]          # (n_chunks, 32)
    return dense[keep].reshape(-1), bits


def unpack_codes_host(words: np.ndarray, bits: np.ndarray, n: int,
                      chunk: int = CHUNK) -> np.ndarray:
    """Inverse of ``pack_codes_host``: the flat int32 code array of
    length ``n``. Validates the stream length against the bit widths
    (truncated or over-long streams are hard errors, never a silent
    short decode)."""
    bits = np.asarray(bits, np.int64)
    words = np.asarray(words, np.uint32)
    n_chunks, _, wpp = _chunk_layout(n, chunk)
    if bits.size != n_chunks:
        raise ValueError(
            f"bit-width table has {bits.size} chunks, expected {n_chunks} "
            f"for {n} codes at chunk={chunk}")
    if np.any(bits < 0) or np.any(bits > 32):
        raise ValueError("chunk bit widths must lie in [0, 32]")
    expect = int(np.sum(bits)) * wpp
    if words.size != expect:
        raise ValueError(
            f"packed stream has {words.size} words, expected {expect} "
            "(truncated or over-long device-pack blob)")
    if n_chunks == 0:
        return np.zeros(0, np.int32)
    dense = np.zeros((n_chunks, 32, wpp), np.uint32)
    keep = np.arange(32)[None, :] < bits[:, None]
    dense[keep] = words.reshape(-1, wpp)
    t = np.arange(32, dtype=np.uint32)
    u3 = np.zeros((n_chunks, wpp, 32), np.uint32)
    for k in range(32):
        u3 |= ((dense[:, k, :, None] >> t) & np.uint32(1)) << np.uint32(k)
    return _unzigzag_np(u3.reshape(-1)[:n])
