"""Pallas TPU kernel: dual-quantization Lorenzo transform (the SZ-like
compressor's hot loop, repro.compress.szlike) for 2D and 3D fields.

3D:  r[z,y,x] = q - q(z-1) - q(y-1) - q(x-1) + q(z-1,y-1) + q(z-1,x-1)
              + q(y-1,x-1) - q(z-1,y-1,x-1),   q = round(f / step)
2D:  r[y,x]   = q - q(y-1) - q(x-1) + q(y-1,x-1)

Slab decomposition mirrors the extrema/fix kernels (3D: z-slabs of plane
shape (Y, X); 2D: y-rows of shape (1, X)), but Lorenzo is backward-only:
each program reads two slabs (s-1, s) and static in-plane shifts. The
quantization ``round(f / step)`` runs in the field's dtype — the shared
arithmetic contract with the host codec (szlike module docstring), so the
int32 residuals match the host's bit for bit within the int32 range
precondition.

``step`` and ``slab_lo`` are scalar OPERANDS, not static parameters:
``step`` so batched execution can vmap one compiled kernel over
per-member quantization steps, ``slab_lo`` (traced-capable, like the
extrema kernel's) so the sharded backend can transform its own Z-slab in
global coordinates — the q(z-1) term is zeroed at the TRUE domain
boundary z == 0 only, not at slab edges.

The inverse (d nested cumsums) stays XLA-level (szlike.sz_inverse): a
slab-carry ``lax.scan`` along the leading axis — O(n) and cache-friendly
where XLA's log-depth cumsum rewrite strides badly — and native cumsums
elsewhere; a hand-rolled Pallas kernel would only re-derive them."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .extrema import (_shift2d, default_interpret, slab_lo_operand,
                      slab_lo_spec, typed_operand)


def _kernel(slab_lo_c, step_c, f_m, f_c, r_out, *, ndim, P, X):
    z = slab_lo_c[0, 0] + pl.program_id(0)
    step = step_c[0, 0]

    def q_of(ref):
        return jnp.round(ref[...].reshape(P, X) / step).astype(jnp.int32)

    qc = q_of(f_c)
    qm = q_of(f_m)
    qm = jnp.where(z == 0, 0, qm)          # zero-pad before the domain

    def sh(a, dy, dx):
        return _shift2d(a, dy, dx, 0)

    if ndim == 3:
        r = (qc
             - sh(qc, -1, 0) - sh(qc, 0, -1) - qm
             + sh(qm, -1, 0) + sh(qm, 0, -1) + sh(qc, -1, -1)
             - sh(qm, -1, -1))
    else:                                  # 2D: slab axis is y, P == 1
        r = qc - sh(qc, 0, -1) - qm + sh(qm, 0, -1)
    r_out[...] = r.reshape(r_out.shape)


def lorenzo_quant_pallas(f: jnp.ndarray, step, *,
                         interpret: bool | None = None,
                         slab_lo=0) -> jnp.ndarray:
    """f: (Z,Y,X) or (Y,X) float; returns int32 Lorenzo residuals of
    round(f / step).

    ``slab_lo`` places a slab block inside a larger field exactly as in
    ``extrema_masks_pallas`` (no ``n_slabs_total`` — the stencil is
    backward-only, so only the z == 0 domain boundary matters). It may be
    a traced int32 scalar (the sharded transform passes
    ``axis_index * L - 1``); outputs on slabs whose backward 1-slab halo
    lies inside the block are bitwise identical to an unblocked run.
    """
    if interpret is None:
        interpret = default_interpret()
    if f.ndim == 3:
        n_local, P, X = f.shape
        specs = [
            pl.BlockSpec((1, P, X), lambda z: (jnp.maximum(z - 1, 0), 0, 0)),
            pl.BlockSpec((1, P, X), lambda z: (z, 0, 0)),
        ]
    elif f.ndim == 2:
        n_local, X = f.shape
        P = 1
        specs = [
            pl.BlockSpec((1, X), lambda z: (jnp.maximum(z - 1, 0), 0)),
            pl.BlockSpec((1, X), lambda z: (z, 0)),
        ]
    else:
        raise ValueError(f"lorenzo kernel supports 2D/3D, got shape {f.shape}")
    kern = functools.partial(_kernel, ndim=f.ndim, P=P, X=X)
    step_op = typed_operand(step, f.dtype).reshape(1, 1)
    return pl.pallas_call(
        kern,
        grid=(n_local,),
        in_specs=[slab_lo_spec(), slab_lo_spec()] + specs,
        out_specs=specs[1],
        out_shape=jax.ShapeDtypeStruct(f.shape, jnp.int32),
        interpret=interpret,
    )(slab_lo_operand(slab_lo), step_op, f, f)
