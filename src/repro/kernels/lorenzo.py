"""Pallas TPU kernel: dual-quantization Lorenzo transform (the SZ-like
compressor's hot loop, repro.compress.szlike) for 3D fields.

r[z,y,x] = q - q(z-1) - q(y-1) - q(x-1) + q(z-1,y-1) + q(z-1,x-1)
         + q(y-1,x-1) - q(z-1,y-1,x-1),   q = round(f / step)

Backward-only 1-halo in z (two slabs), static shifts in-plane. The inverse
(triple cumsum) stays an XLA associative scan — scans are already optimal
there and a hand-rolled kernel would only re-derive them."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .extrema import _shift2d


def _kernel(f_m, f_c, r_out, *, Z, Y, X, step):
    z = pl.program_id(0)
    inv = 1.0 / step

    def q_of(slab):
        return jnp.round(slab * inv).astype(jnp.int32)

    qc = q_of(f_c[0])
    qm = q_of(f_m[0])
    qm = jnp.where(z == 0, 0, qm)          # zero-pad before the domain

    def sh(a, dy, dx):
        return _shift2d(a, dy, dx, 0)

    r = (qc
         - sh(qc, -1, 0) - sh(qc, 0, -1) - qm
         + sh(qm, -1, 0) + sh(qm, 0, -1) + sh(qc, -1, -1)
         - sh(qm, -1, -1))
    r_out[0] = r


def lorenzo_quant_pallas(f: jnp.ndarray, step: float, *,
                         interpret: bool = True) -> jnp.ndarray:
    """f: (Z,Y,X) float; returns int32 Lorenzo residuals of round(f/step)."""
    Z, Y, X = f.shape
    specs = [
        pl.BlockSpec((1, Y, X), lambda z: (jnp.maximum(z - 1, 0), 0, 0)),
        pl.BlockSpec((1, Y, X), lambda z: (z, 0, 0)),
    ]
    kern = functools.partial(_kernel, Z=Z, Y=Y, X=X, step=float(step))
    return pl.pallas_call(
        kern,
        grid=(Z,),
        in_specs=specs,
        out_specs=pl.BlockSpec((1, Y, X), lambda z: (z, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Z, Y, X), jnp.int32),
        interpret=interpret,
    )(f, f)
