"""Pallas TPU kernel: fused 'update directions' + 'find false critical
points' (the paper's two dominant components, Table 1) for 2D and 3D
fields.

TPU mapping: grid over slabs along the leading axis; each program sees
three slabs of each input (s-1, s, s+1) via overlapping BlockSpecs with
clamped index maps — the TPU-native replacement for the paper's
per-thread vertex loop. A 3D field (Z, Y, X) decomposes over z-slabs of
plane shape (Y, X); a 2D field (Y, X) reuses the identical machinery with
y as the slab axis and (1, X) row planes (``slab_offsets``). Every
Freudenthal neighbor decomposes into a static slab select in {-1, 0, +1}
plus a static in-plane shift, so the whole stencil is vector ops on
VMEM-resident slabs; SoS tie-breaking uses arithmetic linear indices (no
index arrays are loaded).

Tiled execution (pMSz-style block decomposition, see DESIGN.md §3/§9):
``slab_lo`` / ``n_slabs_total`` let a caller run the kernel on a z-tile
of a larger field, and ``row_lo``/``col_lo`` with ``n_rows_total``/
``n_cols_total`` place the tile's *plane* inside a larger global plane
(the 2D/3D block decomposition of the sharded backend). Domain-boundary
handling and SoS linear indices then use *global* coordinates, so
outputs on vertices whose 1-vertex halo lies inside the tile are bitwise
identical to an untiled run; the tile drivers (core.backend.PallasBackend
z-tiles, distributed.shardfix blocks) keep a halo margin and discard the
rest.

Outputs per vertex: steepest ascending/descending direction codes of g,
and the three fix-source masks (self_edit / demote / promote) consumed by
the fix kernel. VMEM footprint: ~11 slabs x Y*X*4B (~11 MB at 512x512),
fits v5e VMEM; larger XY planes would tile Y as well.
"""
from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.grid import OFFSETS_2D, OFFSETS_3D, _sos_argbest


# platforms with a native Pallas lowering (Mosaic on TPU, Triton on GPU);
# everything else — notably XLA:CPU — must run the kernels interpreted
_LOWERED_PLATFORMS = ("tpu", "gpu", "cuda", "rocm")


def default_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode by default.

    Auto-detects the platform: TPUs lower through Mosaic and GPUs through
    Triton, so both take the compiled path; every other backend (XLA:CPU
    in particular) has no Pallas lowering and interprets. The
    ``MSZ_PALLAS_INTERPRET`` environment variable overrides the detection
    in both directions (``1``/``true``/``yes``/``on`` forces interpret
    mode, ``0``/``false``/``no``/``off`` forces the lowered path) — the
    escape hatch for debugging a kernel on an accelerator, or for
    asserting lowered-vs-interpret bitwise identity in tests. Every
    kernel entry point (extrema, fix pass, Lorenzo) and every backend
    with ``interpret=None`` routes through this policy.
    """
    env = os.environ.get("MSZ_PALLAS_INTERPRET", "").strip().lower()
    if env in ("1", "true", "yes", "on"):
        return True
    if env in ("0", "false", "no", "off"):
        return False
    if env:
        raise ValueError(
            f"MSZ_PALLAS_INTERPRET={env!r} not understood; use one of "
            "1/true/yes/on (interpret) or 0/false/no/off (lowered)")
    return jax.default_backend() not in _LOWERED_PLATFORMS


def slab_offsets(ndim: int) -> Tuple[Tuple[int, int, int], ...]:
    """Freudenthal offsets as (slab_delta, dy, dx) triples.

    3D fields decompose over z-slabs of plane shape (Y, X); 2D fields
    reuse the same slab machinery with y as the slab axis and (1, X)
    row planes, so dy is always 0 and the 2D dy becomes the slab delta.
    """
    if ndim == 3:
        return tuple(OFFSETS_3D)
    if ndim == 2:
        return tuple((dy, 0, dx) for (dy, dx) in OFFSETS_2D)
    raise ValueError(f"slab kernels support 2D/3D fields, got ndim={ndim}")


def slab_block_specs(ndim: int, n_local: int, P: int, X: int):
    """(halo_specs, center_spec) for a slab-decomposed field.

    ``halo_specs`` maps program s to slabs (s-1, s, s+1), clamped to the
    *local* array; clamping at a tile edge that is not a domain edge
    yields garbage the tile driver must discard (the kernels mask true
    domain edges themselves, in global coordinates).
    """
    if ndim == 3:
        halo = [
            pl.BlockSpec((1, P, X), lambda z: (jnp.maximum(z - 1, 0), 0, 0)),
            pl.BlockSpec((1, P, X), lambda z: (z, 0, 0)),
            pl.BlockSpec((1, P, X),
                         lambda z: (jnp.minimum(z + 1, n_local - 1), 0, 0)),
        ]
        center = pl.BlockSpec((1, P, X), lambda z: (z, 0, 0))
    else:
        halo = [
            pl.BlockSpec((1, X), lambda z: (jnp.maximum(z - 1, 0), 0)),
            pl.BlockSpec((1, X), lambda z: (z, 0)),
            pl.BlockSpec((1, X), lambda z: (jnp.minimum(z + 1, n_local - 1), 0)),
        ]
        center = pl.BlockSpec((1, X), lambda z: (z, 0))
    return halo, center


def _shift2d(a, dy: int, dx: int, fill):
    """Static in-plane shift: out[y,x] = a[y+dy, x+dx], `fill` off-edge."""
    Y, X = a.shape
    pads = [(max(0, -dy), max(0, dy)), (max(0, -dx), max(0, dx))]
    ap = jnp.pad(a, pads, constant_values=fill)
    return jax.lax.slice(ap, (max(0, dy), max(0, dx)),
                         (max(0, dy) + Y, max(0, dx) + X))


def _neighbor_scan(slabs, z, N, yg, xg, NY, NX, lin, offs, *,
                   ascending: bool):
    """Returns (best_code, is_extremum) for the SoS-steepest neighbor.

    Off-domain fills are ±inf in the slab dtype (not f32 literals), so
    f64 fields classify boundary extrema correctly. All three axes mask
    in GLOBAL coordinates (z against N, the plane iotas yg/xg against
    NY/NX): a local plane edge that is *not* a domain edge — a block
    seam of the sharded backend — keeps the neighbor value that the
    caller's ghost layers carried in. Candidates are stacked and reduced
    via ``grid._sos_argbest`` — a chained compare-and-select scan would
    compile exponentially on XLA:CPU (see that helper's docstring); the
    stacked form is bitwise identical.
    """
    # mszlint: disable=transfer-discipline -- kernel-body helper, only ever
    # called under trace where the constant folds at trace time
    fill = jnp.asarray(-jnp.inf if ascending else jnp.inf, slabs[1].dtype)
    vals = [slabs[1]]
    idxs = [lin]
    for ds, dy, dx in offs:
        v = _shift2d(slabs[ds + 1], dy, dx, fill)
        # domain boundaries, in GLOBAL coordinates (tiled runs pass the
        # tile's offset; clamped index_maps made slab s-1 == s, and
        # _shift2d filled local plane edges — re-masking them at the
        # true domain edge is then a no-op, while off-tile positions
        # inside the domain were overwritten by ghost data upstream)
        if ds == -1:
            v = jnp.where(z == 0, fill, v)
        elif ds == 1:
            v = jnp.where(z == N - 1, fill, v)
        if dy == -1:
            v = jnp.where(yg == 0, fill, v)
        elif dy == 1:
            v = jnp.where(yg == NY - 1, fill, v)
        if dx == -1:
            v = jnp.where(xg == 0, fill, v)
        elif dx == 1:
            v = jnp.where(xg == NX - 1, fill, v)
        vals.append(v)
        idxs.append(lin + (ds * NY + dy) * NX + dx)
    slot = _sos_argbest(jnp.stack(vals), jnp.stack(idxs), ascending=ascending)
    best_c = jnp.where(slot == 0, jnp.int32(len(offs)), slot - 1)
    return best_c, slot == 0


def _kernel(origin_c, g_m, g_c, g_p, Mf_m, Mf_c, Mf_p, mf_m, mf_c, mf_p,
            maxf_c, minf_c,
            up_out, dn_out, self_out, demote_out, promote_out,
            *, N, NY, NX, P, X, offs):
    z = origin_c[0, 0] + pl.program_id(0)
    yg = origin_c[0, 1] + jax.lax.broadcasted_iota(jnp.int32, (P, X), 0)
    xg = origin_c[0, 2] + jax.lax.broadcasted_iota(jnp.int32, (P, X), 1)
    lin = z * (NY * NX) + yg * NX + xg

    def plane(ref):
        return ref[...].reshape(P, X)

    g_slabs = (plane(g_m), plane(g_c), plane(g_p))
    up_c, is_max_g = _neighbor_scan(g_slabs, z, N, yg, xg, NY, NX, lin,
                                    offs, ascending=True)
    dn_c, is_min_g = _neighbor_scan(g_slabs, z, N, yg, xg, NY, NX, lin,
                                    offs, ascending=False)

    is_max_f = plane(maxf_c) != 0
    is_min_f = plane(minf_c) != 0

    # gather original labels at the g-steepest neighbor (Eq. 6 predicates)
    def gather_dir(slabs, code, self_val):
        out = self_val
        for k, (ds, dy, dx) in enumerate(offs):
            v = _shift2d(slabs[ds + 1], dy, dx, 0)
            out = jnp.where(code == k, v, out)
        return out

    Mf_slabs = (plane(Mf_m), plane(Mf_c), plane(Mf_p))
    mf_slabs = (plane(mf_m), plane(mf_c), plane(mf_p))
    M_next = gather_dir(Mf_slabs, up_c, Mf_slabs[1])
    m_next = gather_dir(mf_slabs, dn_c, mf_slabs[1])

    fpmax = is_max_g & ~is_max_f
    fpmin = is_min_g & ~is_min_f
    fnmax = ~is_max_g & is_max_f
    fnmin = ~is_min_g & is_min_f
    trouble_max = ~is_max_g & (M_next != Mf_slabs[1])
    trouble_min = ~is_min_g & (m_next != mf_slabs[1])

    up_out[...] = up_c.reshape(up_out.shape)
    dn_out[...] = dn_c.reshape(dn_out.shape)
    self_out[...] = (fpmax | fnmin).astype(jnp.int32).reshape(self_out.shape)
    demote_out[...] = ((fnmax | trouble_max).astype(jnp.int32)
                       .reshape(demote_out.shape))
    promote_out[...] = ((fpmin | trouble_min).astype(jnp.int32)
                        .reshape(promote_out.shape))


def typed_operand(v, dtype) -> jnp.ndarray:
    """Normalize a scalar operand — python number or traced/device
    value — to a device scalar of ``dtype``. Host values move via the
    EXPLICIT ``jax.device_put`` API: the kernel entry points are called
    eagerly, where an implicit ``jnp.asarray(number)`` conversion would
    trip ``debug.no_transfers()`` on every dispatch; device values just
    cast in place."""
    if isinstance(v, jnp.ndarray):
        return v.astype(dtype)
    import numpy as np
    return jax.device_put(np.asarray(v, dtype))


def _int32_operand(v) -> jnp.ndarray:
    return typed_operand(v, jnp.int32)


def slab_lo_operand(slab_lo) -> jnp.ndarray:
    """Normalize a slab offset — python int or traced int32 scalar (the
    sharded fix loop passes ``axis_index * block - 1``) — to the (1, 1)
    operand the kernels read. Static and traced offsets produce bitwise
    identical outputs; only the specialization key differs."""
    return _int32_operand(slab_lo).reshape(1, 1)


def slab_lo_spec() -> pl.BlockSpec:
    """Every grid program sees the same (1, 1) slab-offset block."""
    return pl.BlockSpec((1, 1), lambda z: (0, 0))


def origin_operand(slab_lo, row_lo=0, col_lo=0) -> jnp.ndarray:
    """Normalize a 3-component tile origin (slab, plane row, plane col)
    — python ints or traced int32 scalars — to the (1, 3) operand the
    stencil kernels read. The sharded backend passes each component as
    ``axis_index * block - halo`` so one SPMD program serves every block
    of a 2D/3D block mesh; static and traced origins produce bitwise
    identical outputs, only the specialization key differs."""
    parts = [_int32_operand(v).reshape(1) for v in
             (slab_lo, row_lo, col_lo)]
    return jnp.concatenate(parts).reshape(1, 3)


def origin_spec() -> pl.BlockSpec:
    """Every grid program sees the same (1, 3) tile-origin block."""
    return pl.BlockSpec((1, 3), lambda z: (0, 0))


def _axis_total(total, lo, extent: int, what: str) -> int:
    """Resolve a global axis extent: explicit ``total`` wins; otherwise
    the tile is assumed flush with the domain end (``lo + extent``),
    which requires a static ``lo``."""
    if total is None:
        if not isinstance(lo, int):
            raise ValueError(
                f"a traced {what} offset needs an explicit total extent")
        return lo + extent
    # mszlint: disable=transfer-discipline -- total is a host int parameter
    return int(total)


def extrema_masks_pallas(g: jnp.ndarray, M_f: jnp.ndarray, m_f: jnp.ndarray,
                         is_max_f: jnp.ndarray, is_min_f: jnp.ndarray,
                         *, interpret: bool | None = None,
                         slab_lo=0, n_slabs_total: int | None = None,
                         row_lo=0, col_lo=0,
                         n_rows_total: int | None = None,
                         n_cols_total: int | None = None):
    """g: (Z,Y,X) or (Y,X) float; M_f/m_f: int32 labels of the original
    field; is_max_f/min_f: int32 0/1. Returns (up_c, dn_c, self_edit,
    demote_src, promote_src), all int32 of g's shape.

    ``slab_lo``/``n_slabs_total`` place a z-tile inside a larger field
    (global slab index of g[0], and the field's total slab count);
    ``row_lo``/``col_lo`` with ``n_rows_total``/``n_cols_total`` do the
    same for the plane axes, placing a 2D/3D *block* of the sharded
    backend inside the global field (2D fields use the col pair for
    their second axis; the row pair is unused). Offsets may be traced
    int32 scalars (one SPMD program serves every shard of a sharded
    run); the matching total is then required.
    """
    if interpret is None:
        interpret = default_interpret()
    if g.ndim == 3:
        n_local, P, X = g.shape
    elif g.ndim == 2:
        n_local, X = g.shape
        P = 1
    else:
        raise ValueError(f"extrema kernel supports 2D/3D, got shape {g.shape}")
    N = _axis_total(n_slabs_total, slab_lo, n_local, "slab")
    NY = _axis_total(n_rows_total, row_lo, P, "row")
    NX = _axis_total(n_cols_total, col_lo, X, "col")

    halo, center = slab_block_specs(g.ndim, n_local, P, X)
    out_shape = [jax.ShapeDtypeStruct(g.shape, jnp.int32)] * 5
    kern = functools.partial(_kernel, N=N, NY=NY, NX=NX, P=P, X=X,
                             offs=slab_offsets(g.ndim))
    return pl.pallas_call(
        kern,
        grid=(n_local,),
        in_specs=[origin_spec()] + halo + halo + halo + [center, center],
        out_specs=[center] * 5,
        out_shape=out_shape,
        interpret=interpret,
    )(origin_operand(slab_lo, row_lo, col_lo), g, g, g,
      M_f, M_f, M_f, m_f, m_f, m_f,
      is_max_f.astype(jnp.int32), is_min_f.astype(jnp.int32))
