"""Pallas TPU kernel: fused 'update directions' + 'find false critical
points' (the paper's two dominant components, Table 1) for 3D fields.

TPU mapping: grid over z-slabs; each program sees three (1, Y, X) slabs of
each input (z-1, z, z+1) via overlapping BlockSpecs with clamped index
maps — the TPU-native replacement for the paper's per-thread vertex loop.
All 14 Freudenthal neighbors decompose into a static dz in {-1,0,1} slab
select + static (dy, dx) in-slab shift, so the whole stencil is vector ops
on VMEM-resident slabs; SoS tie-breaking uses arithmetic linear indices
(no index arrays are loaded).

Outputs per vertex: steepest ascending/descending direction codes of g,
and the three fix-source masks (self_edit / demote / promote) consumed by
the fix kernel. VMEM footprint: 8 slabs x Y*X*4B (~8 MB at 512x512), fits
v5e VMEM; larger XY planes would tile Y as well (not needed for the
paper's datasets).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.grid import OFFSETS_3D

SELF_CODE = len(OFFSETS_3D)  # 14
_NEG = -3.4e38
_POS = 3.4e38


def _shift2d(a, dy: int, dx: int, fill):
    """Static in-plane shift: out[y,x] = a[y+dy, x+dx], `fill` off-edge."""
    Y, X = a.shape
    pads = [(max(0, -dy), max(0, dy)), (max(0, -dx), max(0, dx))]
    ap = jnp.pad(a, pads, constant_values=fill)
    return jax.lax.slice(ap, (max(0, dy), max(0, dx)),
                         (max(0, dy) + Y, max(0, dx) + X))


def _neighbor_scan(slabs, z, Z, Y, X, lin, *, ascending: bool):
    """Returns (best_code, is_extremum) for the SoS-steepest neighbor."""
    fill = _NEG if ascending else _POS
    best_v = slabs[1]
    best_i = lin
    best_c = jnp.full((Y, X), SELF_CODE, jnp.int32)
    for k, (dz, dy, dx) in enumerate(OFFSETS_3D):
        src = slabs[dz + 1]
        v = _shift2d(src, dy, dx, fill)
        # z-boundary: clamped index_map made slab z-1 == slab z at z==0
        if dz == -1:
            v = jnp.where(z == 0, fill, v)
        elif dz == 1:
            v = jnp.where(z == Z - 1, fill, v)
        # in-plane validity is already encoded by the fill value
        ni = lin + (dz * Y + dy) * X + dx
        if ascending:
            take = (v > best_v) | ((v == best_v) & (ni > best_i))
        else:
            take = (v < best_v) | ((v == best_v) & (ni < best_i))
        best_v = jnp.where(take, v, best_v)
        best_i = jnp.where(take, ni, best_i)
        best_c = jnp.where(take, jnp.int32(k), best_c)
    return best_c, best_c == SELF_CODE


def _kernel(g_m, g_c, g_p, Mf_m, Mf_c, Mf_p, mf_m, mf_c, mf_p,
            maxf_c, minf_c,
            up_out, dn_out, self_out, demote_out, promote_out, *, Z, Y, X):
    z = pl.program_id(0)
    lin_yx = (jax.lax.broadcasted_iota(jnp.int32, (Y, X), 0) * X
              + jax.lax.broadcasted_iota(jnp.int32, (Y, X), 1))
    lin = z * (Y * X) + lin_yx

    g_slabs = (g_m[0], g_c[0], g_p[0])
    up_c, is_max_g = _neighbor_scan(g_slabs, z, Z, Y, X, lin, ascending=True)
    dn_c, is_min_g = _neighbor_scan(g_slabs, z, Z, Y, X, lin, ascending=False)

    is_max_f = maxf_c[0] != 0
    is_min_f = minf_c[0] != 0

    # gather original labels at the g-steepest neighbor (Eq. 6 predicates)
    def gather_dir(slabs, code, self_val):
        out = self_val
        for k, (dz, dy, dx) in enumerate(OFFSETS_3D):
            v = _shift2d(slabs[dz + 1], dy, dx, 0)
            out = jnp.where(code == k, v, out)
        return out

    Mf_slabs = (Mf_m[0], Mf_c[0], Mf_p[0])
    mf_slabs = (mf_m[0], mf_c[0], mf_p[0])
    M_next = gather_dir(Mf_slabs, up_c, Mf_c[0])
    m_next = gather_dir(mf_slabs, dn_c, mf_c[0])

    fpmax = is_max_g & ~is_max_f
    fpmin = is_min_g & ~is_min_f
    fnmax = ~is_max_g & is_max_f
    fnmin = ~is_min_g & is_min_f
    trouble_max = ~is_max_g & (M_next != Mf_c[0])
    trouble_min = ~is_min_g & (m_next != mf_c[0])

    up_out[0] = up_c
    dn_out[0] = dn_c
    self_out[0] = (fpmax | fnmin).astype(jnp.int32)
    demote_out[0] = (fnmax | trouble_max).astype(jnp.int32)
    promote_out[0] = (fpmin | trouble_min).astype(jnp.int32)


def extrema_masks_pallas(g: jnp.ndarray, M_f: jnp.ndarray, m_f: jnp.ndarray,
                         is_max_f: jnp.ndarray, is_min_f: jnp.ndarray,
                         *, interpret: bool = True):
    """g: (Z,Y,X) f32; M_f/m_f: int32 labels of the original field;
    is_max_f/min_f: int32 0/1. Returns (up_c, dn_c, self_edit, demote_src,
    promote_src), all (Z,Y,X) int32."""
    Z, Y, X = g.shape

    def halo_spec():
        return [
            pl.BlockSpec((1, Y, X), lambda z: (jnp.maximum(z - 1, 0), 0, 0)),
            pl.BlockSpec((1, Y, X), lambda z: (z, 0, 0)),
            pl.BlockSpec((1, Y, X),
                         lambda z: (jnp.minimum(z + 1, Z - 1), 0, 0)),
        ]

    center = pl.BlockSpec((1, Y, X), lambda z: (z, 0, 0))
    out_shape = [jax.ShapeDtypeStruct((Z, Y, X), jnp.int32)] * 5
    kern = functools.partial(_kernel, Z=Z, Y=Y, X=X)
    return pl.pallas_call(
        kern,
        grid=(Z,),
        in_specs=halo_spec() + halo_spec() + halo_spec() + [center, center],
        out_specs=[center] * 5,
        out_shape=out_shape,
        interpret=interpret,
    )(g, g, g, M_f, M_f, M_f, m_f, m_f, m_f,
      is_max_f.astype(jnp.int32), is_min_f.astype(jnp.int32))
