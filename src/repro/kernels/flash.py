"""Pallas TPU kernel: causal flash attention forward (GQA).

The LM-side perf-critical op: the framework's jnp chunked attention
(repro.models.layers.flash_attention, the oracle) bounds memory but leaves
tiling to XLA; this kernel owns the schedule explicitly — grid
(batch*kv_head*group, q_blocks, kv_blocks) with the kv dimension innermost
and sequential, online-softmax state (m, l, acc) in VMEM scratch carried
across kv steps, MXU-aligned (q_block x Dh) tiles.

Decode and window variants fall back to the jnp path (ops.py); this kernel
targets the train/prefill shapes where attention dominates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            qc: int, kc: int, nk: int, scale: float, causal: bool):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
    k_pos = ik * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)

    run = True
    if causal:
        # whole block above the diagonal -> nothing to do
        run = (ik * kc) <= (iq * qc + qc - 1)

    @pl.when(run if causal else True)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale        # (qc, Dh)
        k = k_ref[0].astype(jnp.float32)                # (kc, Dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            s = jnp.where(q_pos >= k_pos, s, jnp.asarray(-jnp.inf, s.dtype))
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, q_block: int = 256,
                           k_block: int = 256,
                           interpret: bool | None = None) -> jnp.ndarray:
    """q: (B, S, H, Dh); k/v: (B, T, Hk, Dh) with H = Hk*G. Returns
    (B, S, H, Dh). S % q_block == 0 and T % k_block == 0 required (the
    ops.py wrapper picks divisors). ``interpret=None`` resolves via
    ``default_interpret()`` like every other kernel entry point."""
    if interpret is None:
        from .extrema import default_interpret
        interpret = default_interpret()
    B, S, H, Dh = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    qc = min(q_block, S)
    kc = min(k_block, T)
    nq, nk = S // qc, T // kc
    BH = B * H

    # (BH, S, Dh) layout; KV indexed by bh // G (GQA sharing)
    qr = q.transpose(0, 2, 1, 3).reshape(BH, S, Dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hk, T, Dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hk, T, Dh)

    kern = functools.partial(_kernel, qc=qc, kc=kc, nk=nk,
                             scale=Dh ** -0.5, causal=causal)
    out = _call(kern, qr, kr, vr, BH, nq, nk, qc, kc, Dh, G, q.dtype,
                interpret)
    return out.reshape(B, H, S, Dh).transpose(0, 2, 1, 3)


def _call(kern, qr, kr, vr, BH, nq, nk, qc, kc, Dh, G, dtype, interpret):
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qc, Dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, kc, Dh), lambda bh, iq, ik: (bh // G, ik, 0)),
            pl.BlockSpec((1, kc, Dh), lambda bh, iq, ik: (bh // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, qc, Dh), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * qc, Dh), dtype),
        scratch_shapes=[
            pltpu.VMEM((qc,), jnp.float32),
            pltpu.VMEM((qc,), jnp.float32),
            pltpu.VMEM((qc, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
