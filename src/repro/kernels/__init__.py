"""repro.kernels — Pallas TPU kernels for the paper's perf-critical
components (fused extrema/direction stencil, pull-based fix pass,
dual-quantization Lorenzo transform) plus the LM-side flash-attention
forward. Each has a pure-jnp oracle (ref.py / models.layers); tests sweep
shapes/dtypes against it (interpret=True on CPU)."""
from .ops import extrema_masks, fix_pass, lorenzo_quant
from .flash import flash_attention_pallas

__all__ = ["extrema_masks", "fix_pass", "lorenzo_quant",
           "flash_attention_pallas"]
