"""Pure-jnp oracles for the Pallas kernels (the kernels must match these
bit-for-bit; swept in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import fixes, grid


def extrema_masks_ref(g, M_f, m_f, is_max_f, is_min_f):
    """Oracle for kernels.extrema.extrema_masks_pallas."""
    up_c, dn_c = grid.steepest_dirs(g)
    sc = grid.self_code(g.ndim)
    is_max_g = up_c == sc
    is_min_g = dn_c == sc
    M_next = grid.gather_dir(M_f, up_c)
    m_next = grid.gather_dir(m_f, dn_c)
    fpmax = is_max_g & ~is_max_f
    fpmin = is_min_g & ~is_min_f
    fnmax = ~is_max_g & is_max_f
    fnmin = ~is_min_g & is_min_f
    trouble_max = ~is_max_g & (M_next != M_f)
    trouble_min = ~is_min_g & (m_next != m_f)
    return (up_c, dn_c,
            (fpmax | fnmin).astype(jnp.int32),
            (fnmax | trouble_max).astype(jnp.int32),
            (fpmin | trouble_min).astype(jnp.int32))


def fix_pass_ref(g, lower, self_edit, demote_src, promote_src,
                 up_code_g, dn_code_f):
    """Oracle for kernels.fixpass.fix_pass_pallas (g_next only)."""
    target = ((self_edit != 0)
              | fixes._pull(demote_src != 0, up_code_g)
              | fixes._pull(promote_src != 0, dn_code_f))
    new = jnp.maximum((g + lower) * 0.5, lower)
    g2 = jnp.where(target, new, g)
    viol = (jnp.sum(self_edit) + jnp.sum(demote_src)
            + jnp.sum(promote_src)).astype(jnp.int32)
    return g2, viol


def lorenzo_quant_ref(f, step):
    """Oracle for kernels.lorenzo.lorenzo_quant_pallas. Divides by step
    (not multiply-by-reciprocal) — the canonical quantization arithmetic
    shared with the host codec (szlike module docstring)."""
    q = jnp.round(f / step).astype(jnp.int32)
    r = q
    for ax in range(f.ndim):
        shifted = jnp.concatenate(
            [jnp.zeros_like(jax.lax.slice_in_dim(r, 0, 1, axis=ax)),
             jax.lax.slice_in_dim(r, 0, r.shape[ax] - 1, axis=ax)], axis=ax)
        r = r - shifted
    return r
