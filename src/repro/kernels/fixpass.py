"""Pallas TPU kernel: 'fix false critical points' — the pull-based edit
application (paper Section 6.1, atomicCAS replaced by a gather + min
reduction; see DESIGN.md §2).

Each vertex j decreases to (g_j + lower_j)/2 iff
  * j is its own fix target (self_edit[j]), or
  * a stencil neighbor i has demote_src[i] and up_code_g[i] pointing at j, or
  * a stencil neighbor i has promote_src[i] and dn_code_f[i] pointing at j.

Same slab halo layout as the extrema kernel (3D: z-slabs; 2D: y-rows),
including the global-coordinate ``slab_lo``/``n_slabs_total`` placement
for tiled execution. Also emits per-slab violation (fix-source) and
edit-target counts: the source counts are the paper's lock-free
work-queue height turned into a reduction, and the target counts are the
dirty-slab bitmap the worklist drivers (DESIGN.md §7) use to skip slabs
whose neighborhood did not change last pass."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .extrema import (_axis_total, _shift2d, default_interpret,
                      origin_operand, origin_spec, slab_block_specs,
                      slab_offsets)

# code k is stored at i; i targets j = i + off_k. From j's view the source
# sits at -off_k and must carry code k.


def _kernel(origin_c, g_c, low_c, self_c,
            dem_m, dem_c, dem_p, pro_m, pro_c, pro_p,
            upg_m, upg_c, upg_p, dnf_m, dnf_c, dnf_p,
            g_out, viol_out, tgt_out, *, N, NY, NX, P, X, offs):
    z = origin_c[0, 0] + pl.program_id(0)
    yg = origin_c[0, 1] + jax.lax.broadcasted_iota(jnp.int32, (P, X), 0)
    xg = origin_c[0, 2] + jax.lax.broadcasted_iota(jnp.int32, (P, X), 1)

    def plane(ref):
        return ref[...].reshape(P, X)

    def pulled(src_slabs, code_slabs):
        out = jnp.zeros((P, X), bool)
        for k, (ds, dy, dx) in enumerate(offs):
            sds, sdy, sdx = -ds, -dy, -dx
            src = src_slabs[sds + 1]
            cod = code_slabs[sds + 1]
            m = _shift2d(src, -dy, -dx, 0) != 0
            c = _shift2d(cod, -dy, -dx, -1)
            # a pull source must lie inside the real domain, checked in
            # GLOBAL coordinates on all three axes: at a block seam the
            # shifted value is ghost data (valid), at a true domain edge
            # the _shift2d zero-fill already cleared m and the global
            # mask below is a no-op — identical either way
            if sds == -1:
                m = jnp.where(z == 0, False, m)
            elif sds == 1:
                m = jnp.where(z == N - 1, False, m)
            if sdy == -1:
                m = jnp.where(yg == 0, False, m)
            elif sdy == 1:
                m = jnp.where(yg == NY - 1, False, m)
            if sdx == -1:
                m = jnp.where(xg == 0, False, m)
            elif sdx == 1:
                m = jnp.where(xg == NX - 1, False, m)
            out = out | (m & (c == k))
        return out

    dem = (plane(dem_m), plane(dem_c), plane(dem_p))
    pro = (plane(pro_m), plane(pro_c), plane(pro_p))
    upg = (plane(upg_m), plane(upg_c), plane(upg_p))
    dnf = (plane(dnf_m), plane(dnf_c), plane(dnf_p))

    self_p = plane(self_c)
    target = ((self_p != 0)
              | pulled(dem, upg)
              | pulled(pro, dnf))
    g = plane(g_c)
    low = plane(low_c)
    new = jnp.maximum((g + low) * jnp.asarray(0.5, g.dtype), low)
    g_out[...] = jnp.where(target, new, g).reshape(g_out.shape)
    viol = jnp.sum(self_p) + jnp.sum(dem[1]) + jnp.sum(pro[1])
    viol_out[0, 0] = viol.astype(jnp.int32)
    tgt_out[0, 0] = jnp.sum(target).astype(jnp.int32)


def fix_pass_pallas(g, lower, self_edit, demote_src, promote_src,
                    up_code_g, dn_code_f, *, interpret: bool | None = None,
                    slab_lo=0, n_slabs_total: int | None = None,
                    row_lo=0, col_lo=0,
                    n_rows_total: int | None = None,
                    n_cols_total: int | None = None):
    """Apply one fused fix pass. All inputs (Z,Y,X) or (Y,X); masks int32
    0/1. Returns (g_next of g's shape/dtype, viol (n_slabs,) int32
    per-slab fix-SOURCE counts, tgt (n_slabs,) int32 per-slab edit-TARGET
    counts). ``viol`` drives convergence (0 sources everywhere == done);
    ``tgt`` feeds the dirty-slab worklists (DESIGN.md §7): a slab whose
    targets were 0 last pass — and whose 2-slab neighborhood's were too —
    produces bitwise-identical masks this pass and can be skipped.
    ``slab_lo``/``n_slabs_total`` and ``row_lo``/``col_lo`` with
    ``n_rows_total``/``n_cols_total`` as in the extrema kernel (offsets
    may be traced; the matching total is then required)."""
    if interpret is None:
        interpret = default_interpret()
    if g.ndim == 3:
        n_local, P, X = g.shape
    elif g.ndim == 2:
        n_local, X = g.shape
        P = 1
    else:
        raise ValueError(f"fix kernel supports 2D/3D, got shape {g.shape}")
    N = _axis_total(n_slabs_total, slab_lo, n_local, "slab")
    NY = _axis_total(n_rows_total, row_lo, P, "row")
    NX = _axis_total(n_cols_total, col_lo, X, "col")

    halo, center = slab_block_specs(g.ndim, n_local, P, X)
    count_spec = pl.BlockSpec((1, 1), lambda z: (z, 0))
    count_shape = jax.ShapeDtypeStruct((n_local, 1), jnp.int32)
    out_specs = [center, count_spec, count_spec]
    out_shape = [jax.ShapeDtypeStruct(g.shape, g.dtype),
                 count_shape, count_shape]
    kern = functools.partial(_kernel, N=N, NY=NY, NX=NX, P=P, X=X,
                             offs=slab_offsets(g.ndim))
    g2, viol, tgt = pl.pallas_call(
        kern,
        grid=(n_local,),
        in_specs=([origin_spec(), center, center, center]
                  + halo + halo + halo + halo),
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(origin_operand(slab_lo, row_lo, col_lo), g, lower, self_edit,
      demote_src, demote_src, demote_src,
      promote_src, promote_src, promote_src,
      up_code_g, up_code_g, up_code_g,
      dn_code_f, dn_code_f, dn_code_f)
    return g2, viol[:, 0], tgt[:, 0]
