"""Pallas TPU kernel: 'fix false critical points' — the pull-based edit
application (paper Section 6.1, atomicCAS replaced by a gather + min
reduction; see DESIGN.md §2).

Each vertex j decreases to (g_j + lower_j)/2 iff
  * j is its own fix target (self_edit[j]), or
  * a stencil neighbor i has demote_src[i] and up_code_g[i] pointing at j, or
  * a stencil neighbor i has promote_src[i] and dn_code_f[i] pointing at j.

Same z-slab halo layout as the extrema kernel. Also emits the per-slab
violation count (the paper's lock-free work-queue height becomes a
reduction)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.grid import OFFSETS_3D
from .extrema import _shift2d

# code k is stored at i; i targets j = i + off_k. From j's view the source
# sits at -off_k and must carry code k.


def _kernel(g_c, low_c, self_c,
            dem_m, dem_c, dem_p, pro_m, pro_c, pro_p,
            upg_m, upg_c, upg_p, dnf_m, dnf_c, dnf_p,
            g_out, viol_out, *, Z, Y, X):
    z = pl.program_id(0)

    def pulled(src_slabs, code_slabs):
        out = jnp.zeros((Y, X), bool)
        for k, (dz, dy, dx) in enumerate(OFFSETS_3D):
            sdz = -dz
            src = src_slabs[sdz + 1]
            cod = code_slabs[sdz + 1]
            m = _shift2d(src, -dy, -dx, 0) != 0
            c = _shift2d(cod, -dy, -dx, -1)
            if sdz == -1:
                edge = z == 0
                m = jnp.where(edge, False, m)
            elif sdz == 1:
                edge = z == Z - 1
                m = jnp.where(edge, False, m)
            out = out | (m & (c == k))
        return out

    dem = (dem_m[0], dem_c[0], dem_p[0])
    pro = (pro_m[0], pro_c[0], pro_p[0])
    upg = (upg_m[0], upg_c[0], upg_p[0])
    dnf = (dnf_m[0], dnf_c[0], dnf_p[0])

    target = ((self_c[0] != 0)
              | pulled(dem, upg)
              | pulled(pro, dnf))
    g = g_c[0]
    low = low_c[0]
    new = jnp.maximum((g + low) * 0.5, low)
    g_out[0] = jnp.where(target, new, g)
    viol = (jnp.sum(self_c[0]) + jnp.sum(dem_c[0]) + jnp.sum(pro_c[0]))
    viol_out[0, 0] = viol.astype(jnp.int32)


def fix_pass_pallas(g, lower, self_edit, demote_src, promote_src,
                    up_code_g, dn_code_f, *, interpret: bool = True):
    """Apply one fused fix pass. All inputs (Z,Y,X); masks int32 0/1.
    Returns (g_next (Z,Y,X) f32, viol (Z,) int32 per-slab counts)."""
    Z, Y, X = g.shape

    def halo():
        return [
            pl.BlockSpec((1, Y, X), lambda z: (jnp.maximum(z - 1, 0), 0, 0)),
            pl.BlockSpec((1, Y, X), lambda z: (z, 0, 0)),
            pl.BlockSpec((1, Y, X),
                         lambda z: (jnp.minimum(z + 1, Z - 1), 0, 0)),
        ]

    center = pl.BlockSpec((1, Y, X), lambda z: (z, 0, 0))
    out_specs = [center, pl.BlockSpec((1, 1), lambda z: (z, 0))]
    out_shape = [jax.ShapeDtypeStruct((Z, Y, X), g.dtype),
                 jax.ShapeDtypeStruct((Z, 1), jnp.int32)]
    kern = functools.partial(_kernel, Z=Z, Y=Y, X=X)
    g2, viol = pl.pallas_call(
        kern,
        grid=(Z,),
        in_specs=[center, center, center] + halo() + halo() + halo() + halo(),
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(g, lower, self_edit,
      demote_src, demote_src, demote_src,
      promote_src, promote_src, promote_src,
      up_code_g, up_code_g, up_code_g,
      dn_code_f, dn_code_f, dn_code_f)
    return g2, viol[:, 0]
