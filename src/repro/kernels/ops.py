"""Jit'd public wrappers around the Pallas kernels with automatic fallback
to the pure-jnp reference path (non-TPU backends where interpret-mode
would be slower than XLA's fused stencils).

These are the low-level per-kernel entry points; production code goes
through the stencil-backend dispatch in ``repro.core.backend`` instead,
which adds 2D/3D selection, Z-tiling, and batching on top of the same
kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .extrema import default_interpret, extrema_masks_pallas
from .fixpass import fix_pass_pallas
from .lorenzo import lorenzo_quant_pallas


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def extrema_masks(g, M_f, m_f, is_max_f, is_min_f, use_pallas: bool = False):
    if use_pallas and g.ndim in (2, 3):
        return extrema_masks_pallas(g, M_f, m_f, is_max_f, is_min_f)
    return ref.extrema_masks_ref(g, M_f, m_f, is_max_f, is_min_f)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def fix_pass(g, lower, self_edit, demote_src, promote_src, up_code_g,
             dn_code_f, use_pallas: bool = False):
    if use_pallas and g.ndim in (2, 3):
        g2, viol, _ = fix_pass_pallas(g, lower, self_edit, demote_src,
                                      promote_src, up_code_g, dn_code_f)
        return g2, jnp.sum(viol)
    return ref.fix_pass_ref(g, lower, self_edit, demote_src, promote_src,
                            up_code_g, dn_code_f)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def lorenzo_quant(f, step, use_pallas: bool = False):
    if use_pallas and f.ndim in (2, 3):
        return lorenzo_quant_pallas(f, step, interpret=default_interpret())
    return ref.lorenzo_quant_ref(f, step)
