"""Per-layer block forwards for the uniform transformer families
(dense / MoE / gemma2-style local-global / llava backbone / whisper).

Every function takes the layer's param dict and returns the residual
stream. `window` may be a traced per-layer scalar: a huge value (2**30)
means global attention, enabling heterogeneous local/global patterns
inside a homogeneous lax.scan."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers
from .config import ArchConfig

GLOBAL_WINDOW = 1 << 30


class AttnOut(NamedTuple):
    y: jnp.ndarray
    k: jnp.ndarray
    v: jnp.ndarray


def _qkv(cfg: ArchConfig, p, x, positions):
    B, S, _ = x.shape
    H, Hk, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, S, Hk, Dh)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, S, Hk, Dh)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(cfg: ArchConfig, p, x, positions, *, window=None,
                    causal=True, q_offset=0) -> AttnOut:
    """Pre-norm attention with optional gemma2-style post-norm."""
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, h, positions)
    y = layers.flash_attention(
        q, k, v, causal=causal, window=window,
        logit_softcap=cfg.attn_softcap, q_offset=q_offset)
    y = jnp.einsum("bsh,hd->bsd",
                   y.reshape(y.shape[0], y.shape[1], -1), p["wo"])
    if "ln1_post" in p:
        y = layers.rms_norm(y, p["ln1_post"], cfg.norm_eps)
    return AttnOut(x + y, k, v)


def attention_decode(cfg: ArchConfig, p, x, k_cache, v_cache, t, *,
                     window=None):
    """One-token attention; returns (residual, new_k_cache, new_v_cache)."""
    B = x.shape[0]
    H, Hk, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    positions = jnp.full((B, 1), t, jnp.int32)
    q, k, v = _qkv(cfg, p, h, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), t, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), t, 1)
    y = layers.decode_attention(q, k_cache, v_cache, t + 1, window=window,
                                logit_softcap=cfg.attn_softcap)
    y = jnp.einsum("bsh,hd->bsd", y.reshape(B, 1, -1), p["wo"])
    if "ln1_post" in p:
        y = layers.rms_norm(y, p["ln1_post"], cfg.norm_eps)
    return x + y, k_cache, v_cache


def ffn_block(cfg: ArchConfig, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense SwiGLU or MoE; returns (residual, aux_loss)."""
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        moe_impl = layers.moe_ffn_ep if layers.MOE_EP_MODE else layers.moe_ffn
        out = moe_impl(
            h, {"router": p["router"], "w_gate": p["moe_w_gate"],
                "w_up": p["moe_w_up"], "w_down": p["moe_w_down"]},
            cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.capacity_factor)
        y, aux = out.y, out.aux_loss
    else:
        y = layers.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
        aux = jnp.zeros((), jnp.float32)
    if "ln2_post" in p:
        y = layers.rms_norm(y, p["ln2_post"], cfg.norm_eps)
    return x + y, aux


# --- whisper (enc-dec) ------------------------------------------------------

def gelu_mlp(p, x, eps):
    h = layers.rms_norm(x, p["ln2"], eps)
    u = jnp.einsum("bsd,df->bsf", h, p["w1"])
    u = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return x + jnp.einsum("bsf,fd->bsd", u, p["w2"])


def whisper_encoder_block(cfg: ArchConfig, p, x):
    a = attention_block(cfg, p, x,
                        positions=jnp.zeros(x.shape[:2], jnp.int32),
                        causal=False)
    return gelu_mlp(p, a.y, cfg.norm_eps)


def cross_attention(cfg: ArchConfig, p, x, enc_out):
    B, S, _ = x.shape
    Te = enc_out.shape[1]
    H, Hk, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = layers.rms_norm(x, p["ln_x"], cfg.norm_eps)
    q = jnp.einsum("bsd,dh->bsh", h, p["wq_x"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk_x"]).reshape(B, Te, Hk, Dh)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv_x"]).reshape(B, Te, Hk, Dh)
    y = layers.flash_attention(q, k, v, causal=False)
    return x + jnp.einsum("bsh,hd->bsd", y.reshape(B, S, -1), p["wo_x"])


def whisper_decoder_block(cfg: ArchConfig, p, x, enc_out, positions):
    a = attention_block(cfg, p, x, positions, causal=True)
    h = cross_attention(cfg, p, a.y, enc_out)
    return gelu_mlp(p, h, cfg.norm_eps), a.k, a.v
