"""Recurrent / hybrid block forwards: xLSTM (mLSTM + sLSTM) and Hymba's
parallel attention+SSM layer."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import layers
from .config import ArchConfig

_GATE_CAP = 15.0  # softcap on log input gate pre-activations (stability)


# --- xLSTM: mLSTM block -----------------------------------------------------
# TP layout (EXPERIMENTS.md §Perf-2): weights are stored Dh-major —
# wq/wk/wv (d, Dh, H), w_down3 (Dh, H, d) — so every activation and the
# matrix memory C shard on the Dh dimension alone (model axis). The state
# update (v outer k), readout (C.q) and normalizer are then fully local
# per device; the only per-layer collective is the psum of the (B, d)
# down-projection. The naive (d, H*Dh) layout forces XLA into an
# H x Dh mixed sharding and an involuntary full state rematerialization
# every decode step.


def _mlstm_qkvzg(cfg: ArchConfig, p, h):
    q = jnp.einsum("bsd,dvh->bshv", h, p["wq3"])      # (B,S,H,Dh)
    k = jnp.einsum("bsd,dvh->bshv", h, p["wk3"])
    v = jnp.einsum("bsd,dvh->bshv", h, p["wv3"])
    z = jnp.einsum("bsd,dvh->bshv", h, p["w_z3"])     # gate, same layout
    gates = jnp.einsum("bsd,dg->bsg", h, p["w_if"])   # (B,S,2H)
    H_ = cfg.n_heads
    log_i = layers.softcap(gates[..., :H_].astype(jnp.float32), _GATE_CAP)
    log_f = jax.nn.log_sigmoid(gates[..., H_:].astype(jnp.float32))
    return q, k, v, z, log_i, log_f


def mlstm_block(cfg: ArchConfig, p, x):
    """Pre-norm mLSTM block (Beck et al. 2024; simplified: no causal conv4,
    gates/projections taken directly from the normed stream)."""
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v, z, log_i, log_f = _mlstm_qkvzg(cfg, p, h)
    y = layers.mlstm_scan(q, k, v, log_f, log_i)      # (B,S,H,Dh)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + jnp.einsum("bshv,vhd->bsd", y, p["w_down3"])


def mlstm_block_step(cfg: ArchConfig, p, x, state):
    """O(1) decode step; state = (C, n)."""
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v, z, log_i, log_f = _mlstm_qkvzg(cfg, p, h)
    state, y = layers.mlstm_step(state, q, k, v, log_f, log_i)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + jnp.einsum("bshv,vhd->bsd", y, p["w_down3"]), state


# --- xLSTM: sLSTM block -----------------------------------------------------

def _slstm_preact(cfg, p, h):
    B, S, d = h.shape
    H, Dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    rs = lambda a: a.reshape(B, S, H, Dh)
    zi = rs(jnp.einsum("bsd,de->bse", h, p["w_zi"]))
    zf = rs(jnp.einsum("bsd,de->bse", h, p["w_zf"]))
    zz = rs(jnp.einsum("bsd,de->bse", h, p["w_zz"]))
    zo = rs(jnp.einsum("bsd,de->bse", h, p["w_zo"]))
    return zi, zf, zz, zo


def slstm_block(cfg: ArchConfig, p, x):
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    zi, zf, zz, zo = _slstm_preact(cfg, p, h)
    y = layers.slstm_scan(zi, zf, zz, zo)
    B, S, _ = x.shape
    return x + jnp.einsum("bsd,de->bse", y.reshape(B, S, -1), p["w_down"])


def slstm_block_step(cfg: ArchConfig, p, x, state):
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    zi, zf, zz, zo = _slstm_preact(cfg, p, h)
    state, y = layers.slstm_step(state, zi, zf, zz, zo)
    B = x.shape[0]
    return x + jnp.einsum("bsd,de->bse", y.reshape(B, 1, -1), p["w_down"]), state


# --- Hymba: parallel attention + SSM heads ----------------------------------

def hymba_block(cfg: ArchConfig, p, x, positions, *, window, q_offset=0):
    """Attention and Mamba-style SSM run in parallel on the same input;
    their per-branch-normalized outputs are averaged before the shared
    output projection (Hymba, arXiv:2411.13676; meta-tokens omitted —
    see DESIGN.md)."""
    B, S, d = x.shape
    H, Hk, Dh, N = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.ssm_state
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    # attention branch
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,dh->bsh", h, p["wk"]).reshape(B, S, Hk, Dh)
    v = jnp.einsum("bsd,dh->bsh", h, p["wv"]).reshape(B, S, Hk, Dh)
    q = layers.rope(q, positions, cfg.rope_theta)
    k_r = layers.rope(k, positions, cfg.rope_theta)
    ya = layers.flash_attention(q, k_r, v, causal=True, window=window,
                                q_offset=q_offset)
    ya = ya.reshape(B, S, H * Dh)
    # ssm branch
    xs = jnp.einsum("bsd,dh->bsh", h, p["ssm_in"]).reshape(B, S, H, Dh)
    dt = jnp.einsum("bsd,dh->bsh", h, p["ssm_dt"])             # (B,S,H)
    Bm = jnp.einsum("bsd,dh->bsh", h, p["ssm_B"]).reshape(B, S, H, N)
    Cm = jnp.einsum("bsd,dh->bsh", h, p["ssm_C"]).reshape(B, S, H, N)
    ys = layers.ssm_scan(xs, dt, Bm, Cm, p["A_log"]).reshape(B, S, H * Dh)
    # fuse: average of per-branch RMS-normalized outputs
    fused = 0.5 * (layers.rms_norm(ya, p["attn_norm"], cfg.norm_eps)
                   + layers.rms_norm(ys, p["ssm_norm"], cfg.norm_eps))
    y = jnp.einsum("bsh,hd->bsd", fused, p["wo"])
    x = x + y
    # dense FFN
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    y2 = layers.swiglu(h2, p["w_gate"], p["w_up"], p["w_down"])
    return x + y2, k_r, v


def hymba_block_step(cfg: ArchConfig, p, x, k_cache, v_cache, ssm_state, t,
                     *, window):
    """Decode step: ring-buffered window cache handled by caller via cache
    size; here we write at position t % cache_len."""
    B, _, d = x.shape
    H, Hk, Dh, N = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.ssm_state
    T_cache = k_cache.shape[1]
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    positions = jnp.full((B, 1), t, jnp.int32)
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"]).reshape(B, 1, H, Dh)
    k = jnp.einsum("bsd,dh->bsh", h, p["wk"]).reshape(B, 1, Hk, Dh)
    v = jnp.einsum("bsd,dh->bsh", h, p["wv"]).reshape(B, 1, Hk, Dh)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    slot = t % T_cache  # ring buffer; global layers size T_cache >= max t
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), slot, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), slot, 1)
    # ring buffer: all T_cache entries are valid once t >= T_cache
    n_valid = jnp.minimum(t + 1, T_cache)
    ya = layers.decode_attention(q, k_cache, v_cache, n_valid,
                                 window=None)  # window == cache size
    ya = ya.reshape(B, 1, H * Dh)
    xs = jnp.einsum("bsd,dh->bsh", h, p["ssm_in"]).reshape(B, 1, H, Dh)
    dt = jnp.einsum("bsd,dh->bsh", h, p["ssm_dt"])
    Bm = jnp.einsum("bsd,dh->bsh", h, p["ssm_B"]).reshape(B, 1, H, N)
    Cm = jnp.einsum("bsd,dh->bsh", h, p["ssm_C"]).reshape(B, 1, H, N)
    ssm_state, ys = layers.ssm_step(ssm_state, xs, dt, Bm, Cm, p["A_log"])
    ys = ys.reshape(B, 1, H * Dh)
    fused = 0.5 * (layers.rms_norm(ya, p["attn_norm"], cfg.norm_eps)
                   + layers.rms_norm(ys, p["ssm_norm"], cfg.norm_eps))
    x = x + jnp.einsum("bsh,hd->bsd", fused, p["wo"])
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + layers.swiglu(h2, p["w_gate"], p["w_up"], p["w_down"])
    return x, k_cache, v_cache, ssm_state
