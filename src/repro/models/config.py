"""Architecture configuration schema for the 10 assigned architectures.

One ArchConfig fully describes a model: the decoder/encoder stack shape,
attention flavor (GQA, sliding/global pattern, softcap), FFN flavor
(dense SwiGLU / MoE top-k), and non-transformer blocks (mLSTM/sLSTM,
Mamba-style SSM for the hybrid)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None            # default d_model // n_heads
    moe: Optional[MoEConfig] = None
    # attention details
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None    # window for local layers
    local_global_period: int = 0            # gemma2: alternate local/global
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    final_softcap: Optional[float] = None   # gemma2: 30.0
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_positions: int = 0                  # encoder memory length (frames)
    # ssm / recurrent
    ssm_state: int = 0                      # mamba state size (hymba)
    slstm_every: int = 0                    # xlstm: 1 sLSTM per this many
    # multimodal stub
    n_img_tokens: int = 0                   # llava: prepended patch embeds
    # numerics
    dtype: str = "bfloat16"
    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k+ contexts? (SSM state / bounded window
        for all but O(1) layers.)"""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks)."""
        d, dh = self.d_model, self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        att = d * self.n_heads * dh * 2 + d * self.n_kv_heads * dh * 2
        if self.family == "ssm":
            # xlstm blocks (Dh-major layout): q,k,v,z projections + down
            blk = 5 * d * d
            return emb + self.n_layers * blk
        if self.moe:
            ffn = self.moe.n_experts * 3 * d * self.d_ff + d * self.moe.n_experts
        else:
            ffn = 3 * d * self.d_ff
        blk = att + ffn
        if self.family == "hybrid":
            blk += d * (2 * self.ssm_state + 2) * self.n_heads  # ssm params
        dec = self.n_layers * blk
        enc = self.n_enc_layers * (att + ffn) if self.enc_dec else 0
        cross = self.n_layers * att if self.enc_dec else 0
        return emb + dec + enc + cross

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        full = self.n_params()
        ffn_all = self.n_layers * self.moe.n_experts * 3 * d * self.d_ff
        ffn_act = self.n_layers * self.moe.top_k * 3 * d * self.d_ff
        return full - ffn_all + ffn_act


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    """Look up a registered input-shape bundle by name (KeyError when
    unknown)."""
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
