"""repro.models — composable pure-JAX model zoo for the 10 assigned
architectures (dense GQA / MoE / local-global / VLM / enc-dec audio /
xLSTM / hybrid attention+SSM)."""
from .config import ArchConfig, MoEConfig, ShapeConfig, SHAPES, shape_by_name
from .model import (init_params, forward, decode_step, init_decode_cache,
                    window_schedule, ForwardOut)
from .sharding import (MeshAxes, axes_for_mesh, tree_param_specs,
                       mesh_shape_dict, constrain, param_spec)

__all__ = [
    "ArchConfig", "MoEConfig", "ShapeConfig", "SHAPES", "shape_by_name",
    "init_params", "forward", "decode_step", "init_decode_cache",
    "window_schedule", "ForwardOut",
    "MeshAxes", "axes_for_mesh", "tree_param_specs", "mesh_shape_dict",
    "constrain", "param_spec",
]
