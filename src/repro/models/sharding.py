"""Mesh-axis conventions and parameter sharding rules.

Mesh axes: single-pod (data, model); multi-pod (pod, data, model). `pod`
joins `data` as a pure data-parallel axis (with compressed gradient
all-reduce across pods, see repro.distributed). TP shards attention heads,
FFN hidden, MoE experts, and vocab over `model`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    batch: Tuple[str, ...] = ("data",)    # ("pod","data") on multi-pod
    model: str = "model"

    @property
    def dp(self):
        return self.batch if len(self.batch) > 1 else self.batch[0]


def use_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` where available (newer jax); on older versions the
    Mesh object's own context manager — ``ambient_axes()`` then reports
    no abstract mesh and mesh-aware layers fall back to their dense
    paths, which is the correct degradation."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def axes_for_mesh(mesh: jax.sharding.Mesh) -> MeshAxes:
    """The batch/model logical-axis assignment for ``mesh`` (pods fold
    into the batch axes when present)."""
    names = mesh.axis_names
    if "pod" in names:
        return MeshAxes(batch=("pod", "data"), model="model")
    return MeshAxes(batch=("data",), model="model")


def constrain(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def ambient_axes() -> Optional[MeshAxes]:
    """MeshAxes derived from the ambient (jax.set_mesh) mesh, or None.
    Axes that are Manual in the current context (inside a shard_map, e.g.
    the pod axis during compressed gradient sync) are excluded — sharding
    constraints may only reference Auto/Explicit axes."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except AttributeError:
        return None
    names = tuple(getattr(am, "axis_names", ()) or ())
    if not names:
        return None
    manual = set()
    try:
        manual = set(am.manual_axes)
    except Exception:
        pass
    batch = tuple(n for n in ("pod", "data") if n in names and n not in manual)
    if not batch or "model" not in names or "model" in manual:
        return None
    return MeshAxes(batch=batch, model="model")


def _dims_ok(x, dim: int, parts_axes, am=None) -> bool:
    try:
        am = am or jax.sharding.get_abstract_mesh()
        parts = 1
        shape = dict(zip(am.axis_names, am.axis_sizes))
        for a in (parts_axes if isinstance(parts_axes, tuple) else (parts_axes,)):
            parts *= shape.get(a, 1)
        return x.shape[dim] % parts == 0 and x.shape[dim] >= parts
    except Exception:
        return False


def constrain_model_dim(x, dim: int):
    """Pin one dim of an activation/state to the model axis (ambient mesh;
    no-op without one). Used inside scan bodies whose stacked outputs
    would otherwise lose their sharding and force a full gather at the
    step boundary (xLSTM decode state, EXPERIMENTS.md §Perf-2)."""
    ax = ambient_axes()
    if ax is None:
        return x
    if not _dims_ok(x, dim, ax.model):
        return x
    spec = [None] * x.ndim
    spec[dim] = ax.model
    return constrain(x, P(*spec))


def constrain_batch(x, extra_model_dim: Optional[int] = None):
    """Constrain dim 0 of an activation to the data axes (and optionally
    one more dim to the model axis) under the ambient mesh; no-op without
    a mesh. Keeps the SPMD partitioner from replicating the big
    intermediates when sharding propagation gives up (e.g. scan carries)."""
    ax = ambient_axes()
    if ax is None:
        return x
    spec = [None] * x.ndim
    if _dims_ok(x, 0, ax.batch):
        spec[0] = ax.batch if len(ax.batch) > 1 else ax.batch[0]
    if extra_model_dim is not None and _dims_ok(x, extra_model_dim, ax.model):
        spec[extra_model_dim] = ax.model
    return constrain(x, P(*spec))


def _div(n: int, parts: int) -> bool:
    return parts > 0 and n % parts == 0


def param_spec(path: str, shape: Tuple[int, ...], ax: MeshAxes,
               mesh_shape: dict, zero1: bool = False) -> P:
    """Sharding rule for one parameter, by name suffix.

    Conventions (leading stack dims from lax.scan get None):
      embed (V, d)            -> (model, None)
      unembed (d, V)          -> (None, model)
      attn wq/wk/wv (d, H*Dh) -> (None, model)   heads sharded
      attn wo (H*Dh, d)       -> (model, None)
      ffn w_gate/w_up (d, ff) -> (None, model)
      ffn w_down (ff, d)      -> (model, None)
      moe (E, d, ff)          -> (model, None, None) if E%tp==0 (EP)
                                 else (None, None, model) (TP inside expert)
      norms / small vectors   -> replicated
    `zero1` additionally shards the first remaining None dim over the data
    axes for optimizer-state pytrees (ZeRO-1).
    """
    tp = mesh_shape.get("model", 1)
    dp = int(np.prod([mesh_shape.get(a, 1) for a in ax.batch]))
    nd = len(shape)
    # leading scan-stack dims (layers/groups) are never sharded
    lead = 0
    base: list = [None] * nd
    name = path.split("/")[-1]

    def last_two(i):  # index helpers relative to trailing dims
        return nd - 2 + i

    if name in ("embed",):
        if _div(shape[lead], tp):
            base[lead] = ax.model
    elif name in ("unembed",):
        if _div(shape[-1], tp):
            base[-1] = ax.model
    elif name == "w_down3":              # mLSTM (Dh, H, d): shard Dh
        if _div(shape[-3], tp):
            base[-3] = ax.model
    elif name in ("wv3", "w_z3"):        # mLSTM (d, Dh, H): shard Dh
        if _div(shape[-2], tp):
            base[-2] = ax.model
    elif name in ("wq3", "wk3"):         # mLSTM q/k replicated (small) so
        pass                             # the C.q readout is local
    elif name in ("wq", "wk", "wv", "w_gate", "w_up", "ssm_in", "w_z",
                  "w_zi", "w_zf", "w_zz", "w_zo", "wq_x", "wk_x", "wv_x",
                  "w1"):
        if _div(shape[-1], tp):
            base[-1] = ax.model
    elif name in ("wo", "w_down", "ssm_out", "w_downproj", "wo_x", "w2"):
        if _div(shape[-2], tp):
            base[-2] = ax.model
    elif name == "router":
        pass  # small, replicated
    elif name in ("moe_w_gate", "moe_w_up"):          # (.., E, d, ff)
        if _div(shape[-3], tp):
            base[-3] = ax.model                        # expert parallel
        elif _div(shape[-1], tp):
            base[-1] = ax.model
    elif name == "moe_w_down":                         # (.., E, ff, d)
        if _div(shape[-3], tp):
            base[-3] = ax.model
        elif _div(shape[-2], tp):
            base[-2] = ax.model

    if zero1:
        # shard one remaining large dim over the data axes (ZeRO-1)
        for i, s in enumerate(base):
            if s is None and i < nd and shape[i] >= dp and _div(shape[i], dp):
                base[i] = ax.batch if len(ax.batch) > 1 else ax.batch[0]
                break
    return P(*base)


def tree_param_specs(params_shape, ax: MeshAxes, mesh_shape: dict,
                     zero1: bool = False):
    """Build a PartitionSpec pytree matching a params (shape) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        name = "/".join(getattr(k, "key", str(k)) for k in path)
        specs.append(param_spec(name, leaf.shape, ax, mesh_shape, zero1))
    return jax.tree_util.tree_unflatten(treedef, specs)


def mesh_shape_dict(mesh: jax.sharding.Mesh) -> dict:
    """{axis name: device count} of ``mesh``."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))
