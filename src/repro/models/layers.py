"""Model building blocks (pure JAX, param dicts in, arrays out).

Conventions:
  * activations bf16 (cfg.dtype), reductions/softmax/norms in f32,
  * params are plain dicts of jnp arrays,
  * attention is flash-style chunked (never materializes S x T logits),
  * MoE uses sort-based token dispatch with static capacity (no E x C
    one-hot dispatch tensors),
  * recurrent blocks (mLSTM, Mamba SSM) use chunkwise-parallel scans for
    train/prefill and O(1) state updates for decode.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, w_down)


# ---------------------------------------------------------------------------
# flash-style chunked attention (train / prefill)
# ---------------------------------------------------------------------------

def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (n assumed power-of-two-ish)."""
    c = min(n, target)
    while n % c:
        c -= 1
    return max(c, 1)


# Route plain-causal/full attention through the Pallas flash kernel
# (repro.kernels.flash) instead of the jnp chunked path. Off by default:
# on CPU the kernel runs in interpret mode (slower than XLA); enable on
# TPU via env REPRO_PALLAS_ATTN=1 (read by the launchers).
PALLAS_ATTENTION = False


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    logit_softcap: Optional[float] = None,
                    q_offset: int = 0,
                    q_chunk: int = 512, k_chunk: int = 1024) -> jnp.ndarray:
    """Online-softmax attention with GQA, O(S * k_chunk) memory.

    q: (B, S, H, D); k/v: (B, T, Hk, D). Returns (B, S, H, D).
    ``window``: only attend to keys with q_pos - k_pos < window (local attn).
    This jnp formulation is the oracle for the Pallas flash kernel
    (repro.kernels.flash); XLA fuses it acceptably for the dry-run baseline.
    """
    if (PALLAS_ATTENTION and window is None and logit_softcap is None
            and q_offset == 0 and q.shape[1] == k.shape[1]
            and q.shape[1] % 128 == 0):
        from ..kernels.flash import flash_attention_pallas
        return flash_attention_pallas(
            q, k, v, causal=causal, q_block=_pick_chunk(q.shape[1], 256),
            k_block=_pick_chunk(k.shape[1], 256))
    B, S, H, D = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    qc = _pick_chunk(S, q_chunk)
    kc = _pick_chunk(T, k_chunk)
    nq, nk = S // qc, T // kc
    scale = jnp.asarray(D ** -0.5, jnp.float32)

    qr = q.reshape(B, nq, qc, Hk, G, D)
    kr = k.reshape(B, nk, kc, Hk, D)
    vr = v.reshape(B, nk, kc, Hk, D)

    def q_block(iq, qb):
        # qb: (B, qc, Hk, G, D)
        q_pos = q_offset + iq * qc + jnp.arange(qc)

        def kv_step(carry, ik):
            m, l, acc = carry
            kb = jax.lax.dynamic_index_in_dim(kr, ik, 1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, ik, 1, keepdims=False)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, logit_softcap)
            k_pos = ik * kc + jnp.arange(kc)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m2 = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m2 = -inf)
            m_safe = jnp.where(jnp.isfinite(m2), m2, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l2 = l * corr + jnp.sum(p, -1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc2 = acc * corr[..., None] + pv
            return (m2, l2, acc2), None

        m0 = jnp.full((B, Hk, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Hk, G, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        # (B,Hk,G,qc,D) -> (B,qc,Hk,G,D)
        return out.transpose(0, 3, 1, 2, 4)

    blocks = jax.lax.map(lambda args: q_block(*args),
                         (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5)))
    # blocks: (nq, B, qc, Hk, G, D)
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, D)
    return out.astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     t: jnp.ndarray, *, window: Optional[int] = None,
                     logit_softcap: Optional[float] = None) -> jnp.ndarray:
    """Single-token attention against a (B, T, Hk, D) KV cache.

    q: (B, 1, H, D); t: current position (number of valid cache entries).
    Unchunked: the (B, H, T) logits are small and shard cleanly when the
    cache's T dim is sharded over the model axis.
    """
    B, _, H, D = q.shape
    T, Hk = k_cache.shape[1], k_cache.shape[2]
    G = H // Hk
    qr = q.reshape(B, Hk, G, D)
    s = jnp.einsum("bhgd,bthd->bhgt", qr, k_cache,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    s = softcap(s, logit_softcap)
    pos = jnp.arange(T)
    mask = pos[None, None, None, :] < t
    if window is not None:
        mask &= pos[None, None, None, :] >= (t - window)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based dispatch, static capacity)
# ---------------------------------------------------------------------------

class MoEOut(NamedTuple):
    y: jnp.ndarray
    aux_loss: jnp.ndarray


def _constrain_moe(x, *, expert_dim: int = None, token_dim: int = None):
    """Pin MoE intermediate shardings (experts over model, tokens over the
    data axes) so the partitioner never falls back to replicating the
    dispatch buffers — unconstrained, that fallback costs an all-gather of
    the full (E*cap, d) buffer per layer (see EXPERIMENTS.md §Perf-1)."""
    from .sharding import ambient_axes, constrain, _dims_ok
    from jax.sharding import PartitionSpec as P
    ax = ambient_axes()
    if ax is None:
        return x
    spec = [None] * x.ndim
    if expert_dim is not None and _dims_ok(x, expert_dim, ax.model):
        spec[expert_dim] = ax.model
    if token_dim is not None and _dims_ok(x, token_dim, ax.batch):
        spec[token_dim] = ax.batch if len(ax.batch) > 1 else ax.batch[0]
    return constrain(x, P(*spec))


def moe_ffn(x: jnp.ndarray, p: Params, n_experts: int, top_k: int,
            capacity_factor: float = 1.25) -> MoEOut:
    """Top-k MoE with sort-based dispatch.

    x: (B, S, d). p: router (d, E), w_gate/w_up (E, d, ff), w_down (E, ff, d).
    Tokens beyond an expert's static capacity are dropped (standard
    GShard-style dropping); aux_loss is the load-balancing loss.
    """
    B, S, d = x.shape
    N = B * S
    E, K = n_experts, top_k
    xf = x.reshape(N, d)
    # NOTE: no sharding constraints here — annotating this data-dependent
    # scatter was measured to INCREASE collective traffic (§Perf-1 iter 1,
    # refuted); at scale use moe_ffn_ep, this path serves small token
    # counts and single-host runs.
    logits = jnp.einsum("nd,de->ne", xf, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)          # (N, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # load-balance loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=1),
        axis=0)
    aux = jnp.asarray(E, jnp.float32) * jnp.sum(me * ce)

    cap = int(np.ceil(N * K / E * capacity_factor / 8)) * 8

    flat_e = expert_ids.reshape(-1)                          # (N*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_in_grp = jnp.arange(N * K) - group_start[sorted_e]
    keep = pos_in_grp < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_grp, E * cap)  # drop row
    tok = order // K

    buf = jnp.zeros((E * cap + 1, d), x.dtype).at[slot].set(xf[tok])
    h_in = buf[:E * cap].reshape(E, cap, d)
    g = jnp.einsum("ecd,edf->ecf", h_in, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h_in, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * cap, d)
    out_e = jnp.concatenate([out_e, jnp.zeros((1, d), x.dtype)], 0)

    gathered = out_e[slot]                                    # (N*K, d)
    w = (gate_vals.reshape(-1)[order] * keep).astype(jnp.float32)
    y = jnp.zeros((N, d), jnp.float32).at[tok].add(
        gathered.astype(jnp.float32) * w[:, None])
    return MoEOut(y.reshape(B, S, d).astype(x.dtype), aux)


# --- expert-parallel MoE: shard_map + explicit all_to_all -------------------
# Enabled via repro.models.layers.MOE_EP_MODE (env REPRO_MOE_EP=1 in the
# launchers). The dense sort-based dispatch above is partitioner-hostile:
# its data-dependent global scatter forces XLA SPMD to replicate the
# (E*cap, d) buffers (measured: 85 GB all-gather per layer on qwen3 —
# EXPERIMENTS.md §Perf-1). Here the token movement is exactly two
# all_to_all ops over the model axis, the theoretical minimum for EP.

MOE_EP_MODE = False


def _moe_ep_body(xf, router, w_gate, w_up, w_down, *, E, K, m, tp, cap_send,
                 cap_loc, data_axes):
    """Per-(data,model)-shard body. xf: (N_loc, d) local tokens.
    w_*: (E_virt_loc, d, ff/m) local virtual-expert weights. `m` = ff
    slices per real expert (virtual experts let E < tp shard over model:
    each slice computes a partial down-projection; the weighted
    scatter-add combine sums the partials). Two a2a: tokens out, results
    back."""
    N_loc, d = xf.shape
    E_virt = E * m
    E_loc = E_virt // tp
    K_eff = K * m
    logits = jnp.einsum("nd,de->ne", xf, router,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)            # (N_loc, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32),
                          axis=1), axis=0)
    aux = jnp.asarray(E, jnp.float32) * jnp.sum(me * ce)

    # virtualize: assignment (token, expert e) -> m copies (e*m + j)
    virt = (expert_ids[..., None] * m
            + jnp.arange(m, dtype=expert_ids.dtype))           # (N,K,m)
    flat_e = virt.reshape(-1)                                  # (N*K*m,)
    gate_rep = jnp.broadcast_to(gate_vals[..., None],
                                virt.shape).reshape(-1)
    dest = flat_e // E_loc                                     # model shard
    order = jnp.argsort(dest, stable=True)
    sorted_dest = dest[order]
    start = jnp.searchsorted(sorted_dest, jnp.arange(tp))
    pos = jnp.arange(N_loc * K_eff) - start[sorted_dest]
    keep = pos < cap_send
    slot = jnp.where(keep, sorted_dest * cap_send + pos, tp * cap_send)
    tok = order // K_eff

    send = jnp.zeros((tp * cap_send + 1, d), xf.dtype).at[slot].set(xf[tok])
    send_eid = jnp.full((tp * cap_send + 1,), -1, jnp.int32).at[slot].set(
        (flat_e % E_loc)[order].astype(jnp.int32))
    send = send[:-1].reshape(tp, cap_send, d)
    send_eid = send_eid[:-1].reshape(tp, cap_send)

    recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                              tiled=False)
    recv_eid = jax.lax.all_to_all(send_eid, "model", 0, 0, tiled=False)
    rx = recv.reshape(tp * cap_send, d)                        # local tokens
    re = recv_eid.reshape(tp * cap_send)

    # group received tokens by local expert (second, local dispatch);
    # sort/search on the pad-corrected KEY (pads -> E_loc, sorted last) —
    # searching the raw ids would binary-search a non-ascending array
    key2 = jnp.where(re < 0, E_loc, re)
    order2 = jnp.argsort(key2, stable=True)
    sorted_key2 = key2[order2]
    sorted_e2 = re[order2]
    start2 = jnp.searchsorted(sorted_key2, jnp.arange(E_loc))
    pos2 = jnp.arange(tp * cap_send) - start2[jnp.clip(sorted_e2, 0, E_loc - 1)]
    keep2 = (pos2 < cap_loc) & (sorted_e2 >= 0)
    slot2 = jnp.where(keep2, sorted_e2 * cap_loc + pos2, E_loc * cap_loc)

    buf = jnp.zeros((E_loc * cap_loc + 1, d), xf.dtype).at[slot2].set(
        rx[order2])
    h_in = buf[:-1].reshape(E_loc, cap_loc, d)
    g = jnp.einsum("ecd,edf->ecf", h_in, w_gate)
    u = jnp.einsum("ecd,edf->ecf", h_in, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xf.dtype) * u
    out_e = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(-1, d)
    out_e = jnp.concatenate([out_e, jnp.zeros((1, d), xf.dtype)], 0)

    # un-group, a2a back, combine
    back = jnp.zeros((tp * cap_send, d), xf.dtype).at[order2].set(
        out_e[slot2] * keep2[:, None])
    back = jax.lax.all_to_all(back.reshape(tp, cap_send, d), "model",
                              0, 0, tiled=False)
    flat_back = back.reshape(tp * cap_send, d)

    gathered = jnp.concatenate([flat_back,
                                jnp.zeros((1, d), xf.dtype)], 0)[slot]
    w = (gate_rep[order] * keep).astype(jnp.float32)
    y = jnp.zeros((N_loc, d), jnp.float32).at[tok].add(
        gathered.astype(jnp.float32) * w[:, None])
    aux = jax.lax.pmean(aux, "model")
    for a in data_axes:
        aux = jax.lax.pmean(aux, a)
    return y.astype(xf.dtype), aux


def moe_ffn_ep(x: jnp.ndarray, p: Params, n_experts: int, top_k: int,
               capacity_factor: float = 1.25) -> MoEOut:
    """Expert-parallel MoE: manual over (data, model), experts sharded over
    model, token movement = exactly two all_to_all. E < tp is handled by
    ff-sliced virtual experts (m = tp/gcd(E,tp) slices per expert).
    Falls back to the dense moe_ffn without an ambient mesh."""
    import math
    from jax.sharding import PartitionSpec as P
    from .sharding import ambient_axes
    ax = ambient_axes()
    if ax is None:
        return moe_ffn(x, p, n_experts, top_k, capacity_factor)
    am = jax.sharding.get_abstract_mesh()
    sizes = dict(zip(am.axis_names, am.axis_sizes))
    tp = sizes.get("model", 1)
    dp = int(np.prod([sizes.get(a, 1) for a in ax.batch]))

    B, S, d = x.shape
    E, K = n_experts, top_k
    ff = p["w_gate"].shape[-1]
    m = tp // math.gcd(E, tp)
    if ff % m or (E * m) % tp or (B * S) % dp:
        return moe_ffn(x, p, n_experts, top_k, capacity_factor)
    if B * S <= 4096:
        # decode-shaped calls: too few tokens to amortize the a2a (and the
        # virtual-expert weight reshape would reshard weights every step);
        # the dense dispatch is cheap at this size (§Perf-1/3)
        return moe_ffn(x, p, n_experts, top_k, capacity_factor)

    def virt3(w):                       # (E, d, ff) -> (E*m, d, ff/m)
        Ew, dw, fw = w.shape
        return (w.reshape(Ew, dw, m, fw // m).transpose(0, 2, 1, 3)
                .reshape(Ew * m, dw, fw // m))

    def virt_down(w):                   # (E, ff, d) -> (E*m, ff/m, d)
        Ew, fw, dw = w.shape
        return (w.reshape(Ew, m, fw // m, dw).reshape(Ew * m, fw // m, dw))

    wg, wu, wd = virt3(p["w_gate"]), virt3(p["w_up"]), virt_down(p["w_down"])

    N = B * S
    N_loc = N // dp
    K_eff = K * m
    cap_send = max(int(np.ceil(N_loc * K_eff / tp * capacity_factor / 8)) * 8,
                   8)
    # a shard receives <= tp*cap_send rows spread over its E_loc experts
    E_loc = E * m // tp
    cap_loc = max(int(np.ceil(tp * cap_send / E_loc
                              * capacity_factor / 8)) * 8, 8)

    manual = set(ax.batch) | {"model"}
    dspec = ax.batch if len(ax.batch) > 1 else ax.batch[0]

    def body(xf, router, wg_, wu_, wd_):
        return _moe_ep_body(xf, router, wg_, wu_, wd_, E=E, K=K, m=m, tp=tp,
                            cap_send=cap_send, cap_loc=cap_loc,
                            data_axes=ax.batch)

    y, aux = jax.shard_map(
        body,
        in_specs=(P(dspec, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dspec, None), P()),
        axis_names=manual, check_vma=False,
    )(x.reshape(N, d), p["router"], wg, wu, wd)
    return MoEOut(y.reshape(B, S, d), aux)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM) — chunkwise-parallel form + O(1) decode step
# ---------------------------------------------------------------------------

def mlstm_scan(q, k, v, log_f, log_i, chunk: int = 256):
    """Chunkwise-parallel mLSTM (matrix memory; Beck et al. 2024).

    q/k/v: (B, S, H, D); log_f/log_i: (B, S, H) (log forget in (-inf,0],
    log input bounded by softcap upstream). Returns (B, S, H, D).
    C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t q_t / max(|n_t . q_t|, 1)
    """
    B, S, H, D = q.shape
    L = _pick_chunk(S, chunk)
    nC = S // L
    scale = D ** -0.5

    qr = q.reshape(B, nC, L, H, D).astype(jnp.float32) * scale
    kr = k.reshape(B, nC, L, H, D).astype(jnp.float32)
    vr = v.reshape(B, nC, L, H, D).astype(jnp.float32)
    lf = log_f.reshape(B, nC, L, H).astype(jnp.float32)
    li = log_i.reshape(B, nC, L, H).astype(jnp.float32)

    LF = jnp.cumsum(lf, axis=2)                # decay chunk-start -> t
    tot = LF[:, :, -1, :]                      # (B,nC,H) full-chunk decay

    # intra-chunk weights: w[t,s] = exp(LF_t - LF_s + li_s), s <= t
    wmat = LF[:, :, :, None, :] - LF[:, :, None, :, :] + li[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    wmat = jnp.where(tri[None, None, :, :, None], jnp.exp(wmat), 0.0)

    def chunk_step(carry, inp):
        C, n = carry                            # (B,H,D,D), (B,H,D)
        qc_, kc_, vc_, LFc, lic, wc, totc = inp
        dec = jnp.exp(LFc)                      # (B,L,H)
        h_inter = jnp.einsum("blh,bhde,blhe->blhd", dec, C, qc_)
        n_inter = jnp.einsum("blh,bhd->blhd", dec, n)
        qk = jnp.einsum("blhd,bmhd->blmh", qc_, kc_)
        A = qk * wc                             # (B,L,M,H) decayed weights
        h_intra = jnp.einsum("blmh,bmhd->blhd", A, vc_)
        # normalizer: n_t . q_t = inter + sum_s w[t,s] (k_s . q_t)
        denom_intra = jnp.sum(A, axis=2)        # (B,L,H)
        denom = jnp.abs(jnp.einsum("blhd,blhd->blh", n_inter, qc_)
                        + denom_intra)
        h = (h_inter + h_intra) / jnp.maximum(denom, 1.0)[..., None]
        # state update to end of chunk
        wk = jnp.exp(totc[:, None, :] - LFc + lic)   # (B,L,H)
        C2 = jnp.einsum("bh,bhde->bhde", jnp.exp(totc), C) + \
             jnp.einsum("blh,blhd,blhe->bhde", wk, vc_, kc_)
        n2 = jnp.einsum("bh,bhd->bhd", jnp.exp(totc), n) + \
             jnp.einsum("blh,blhd->bhd", wk, kc_)
        return (C2, n2), h

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    inputs = (qr.transpose(1, 0, 2, 3, 4), kr.transpose(1, 0, 2, 3, 4),
              vr.transpose(1, 0, 2, 3, 4), LF.transpose(1, 0, 2, 3),
              li.transpose(1, 0, 2, 3), wmat.transpose(1, 0, 2, 3, 4),
              tot.transpose(1, 0, 2))
    (_, _), hs = jax.lax.scan(chunk_step, (C0, n0), inputs)
    # hs: (nC, B, L, H, D)
    return hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D).astype(q.dtype)


def mlstm_step(state, q, k, v, log_f, log_i):
    """O(1) mLSTM decode step. state: (C (B,H,D,D) f32 or bf16, n (B,H,D)
    f32); q/k/v: (B,1,H,D); log_f/log_i: (B,1,H). The C update is computed
    in f32 and stored back in C's dtype (bf16 storage halves the dominant
    decode memory traffic)."""
    C, n = state
    D = q.shape[-1]
    qf = q[:, 0].astype(jnp.float32) * (D ** -0.5)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    f = jnp.exp(log_f[:, 0].astype(jnp.float32))[..., None, None]
    i = jnp.exp(log_i[:, 0].astype(jnp.float32))[..., None, None]
    C2 = f * C.astype(jnp.float32) + i * jnp.einsum("bhd,bhe->bhde", vf, kf)
    n2 = f[..., 0] * n + i[..., 0] * kf
    num = jnp.einsum("bhde,bhe->bhd", C2, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n2, qf)), 1.0)
    h = (num / den[..., None])[:, None].astype(q.dtype)
    return (C2.astype(C.dtype), n2), h


# ---------------------------------------------------------------------------
# sLSTM — stabilized scalar-memory recurrence (sequential scan)
# ---------------------------------------------------------------------------

def slstm_scan(zi, zf, zz, zo):
    """zi/zf/zz/zo: (B, S, H, D) pre-activations. Stabilized sLSTM:
    m_t = max(log_sig(zf) + m, zi); c,n in exp(. - m) domain."""
    B, S, H, D = zz.shape

    def step(carry, inp):
        c, n, m = carry
        zi_t, zf_t, zz_t, zo_t = inp
        lf = jax.nn.log_sigmoid(zf_t.astype(jnp.float32))
        li = zi_t.astype(jnp.float32)
        m2 = jnp.maximum(lf + m, li)
        c2 = jnp.exp(lf + m - m2) * c + jnp.exp(li - m2) * jnp.tanh(
            zz_t.astype(jnp.float32))
        n2 = jnp.exp(lf + m - m2) * n + jnp.exp(li - m2)
        h = jax.nn.sigmoid(zo_t.astype(jnp.float32)) * c2 / jnp.maximum(n2, 1.0)
        return (c2, n2, m2), h

    init = (jnp.zeros((B, H, D), jnp.float32),
            jnp.zeros((B, H, D), jnp.float32),
            jnp.full((B, H, D), -1e30, jnp.float32))
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (zi, zf, zz, zo))
    (_, _, _), hs = jax.lax.scan(step, init, xs)
    return hs.transpose(1, 0, 2, 3).astype(zz.dtype)


def slstm_step(state, zi, zf, zz, zo):
    c, n, m = state
    lf = jax.nn.log_sigmoid(zf[:, 0].astype(jnp.float32))
    li = zi[:, 0].astype(jnp.float32)
    m2 = jnp.maximum(lf + m, li)
    c2 = jnp.exp(lf + m - m2) * c + jnp.exp(li - m2) * jnp.tanh(
        zz[:, 0].astype(jnp.float32))
    n2 = jnp.exp(lf + m - m2) * n + jnp.exp(li - m2)
    h = jax.nn.sigmoid(zo[:, 0].astype(jnp.float32)) * c2 / jnp.maximum(n2, 1.0)
    return (c2, n2, m2), h[:, None].astype(zz.dtype)


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (hymba's parallel-SSM heads)
# ---------------------------------------------------------------------------

def ssm_scan(x, delta, Bmat, Cmat, A_log, chunk: int = 256):
    """Chunkwise diagonal selective SSM.

    x: (B, S, H, D); delta: (B, S, H); Bmat/Cmat: (B, S, H, N);
    A_log: (H, N) learned (A = -exp(A_log)).
    state h: (B, H, N, D):  h_t = exp(delta_t A) h_{t-1} + delta_t B_t x_t^T
    y_t = C_t . h_t
    """
    B, S, H, D = x.shape
    N = Bmat.shape[-1]
    L = _pick_chunk(S, chunk)
    nC = S // L
    A = -jnp.exp(A_log.astype(jnp.float32))                   # (H,N)
    dt = jax.nn.softplus(delta.astype(jnp.float32))           # (B,S,H)
    lg = dt[..., None] * A[None, None]                        # (B,S,H,N) log-decay
    xB = dt[..., None] * Bmat.astype(jnp.float32)             # input weight

    lgr = lg.reshape(B, nC, L, H, N)
    xr = x.reshape(B, nC, L, H, D).astype(jnp.float32)
    br = xB.reshape(B, nC, L, H, N)
    cr = Cmat.reshape(B, nC, L, H, N).astype(jnp.float32)
    LG = jnp.cumsum(lgr, axis=2)
    tot = LG[:, :, -1]

    # intra-chunk transfer w[t,s] = exp(LG_t - LG_s), s <= t  (B,L,M,H,N)
    tri = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, inp):
        h = carry                                              # (B,H,N,D)
        xc, bc, cc, LGc, totc = inp
        dec = jnp.exp(LGc)                                     # (B,L,H,N)
        y_inter = jnp.einsum("blhn,bhnd->blhd", cc * dec, h)
        wm = LGc[:, :, None] - LGc[:, None, :]                 # (B,L,M,H,N)
        wm = jnp.where(tri[None, :, :, None, None], jnp.exp(wm), 0.0)
        # y_intra[t] = sum_s C_t . (w[t,s] B_s) x_s
        cb = jnp.einsum("blhn,blmhn,bmhn->blmh", cc, wm, bc)   # (B,L,M,H)
        y_intra = jnp.einsum("blmh,bmhd->blhd", cb, xc)
        y = y_inter + y_intra
        wk = jnp.exp(totc[:, None] - LGc)                      # (B,L,H,N)
        h2 = jnp.exp(totc)[..., None] * h + jnp.einsum(
            "blhn,blhd->bhnd", wk * bc, xc)
        return h2, y

    h0 = jnp.zeros((B, H, N, D), jnp.float32)
    inputs = (xr.transpose(1, 0, 2, 3, 4), br.transpose(1, 0, 2, 3, 4),
              cr.transpose(1, 0, 2, 3, 4), LG.transpose(1, 0, 2, 3, 4),
              tot.transpose(1, 0, 2, 3))
    _, ys = jax.lax.scan(chunk_step, h0, inputs)
    return ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D).astype(x.dtype)


def ssm_step(h, x, delta, Bmat, Cmat, A_log):
    """O(1) SSM decode step. h: (B,H,N,D); x/delta/Bmat/Cmat single-step."""
    A = -jnp.exp(A_log.astype(jnp.float32))
    dt = jax.nn.softplus(delta[:, 0].astype(jnp.float32))      # (B,H)
    dec = jnp.exp(dt[..., None] * A[None])                     # (B,H,N)
    xb = (dt[..., None] * Bmat[:, 0].astype(jnp.float32))      # (B,H,N)
    h2 = dec[..., None] * h + jnp.einsum("bhn,bhd->bhnd", xb,
                                         x[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhn,bhnd->bhd", Cmat[:, 0].astype(jnp.float32), h2)
    return h2, y[:, None].astype(x.dtype)
