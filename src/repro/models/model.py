"""Model assembly: parameter init (eval_shape-safe), stacked-layer
forwards (lax.scan for deep uniform stacks), prefill-with-cache, and
single-token decode for every assigned architecture family."""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import blocks, layers, recurrent
from .blocks import GLOBAL_WINDOW
from .config import ArchConfig
from .sharding import constrain_batch, constrain_model_dim

Params = Dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_init(key, shape, dtype, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _layer_param_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    d, H, Hk, Dh, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                        cfg.head_dim, cfg.d_ff)
    p: Dict[str, Tuple[int, ...]] = {"ln1": (d,), "ln2": (d,)}
    p.update(wq=(d, H * Dh), wk=(d, Hk * Dh), wv=(d, Hk * Dh),
             wo=(H * Dh, d))
    if cfg.local_global_period:           # gemma2 post-norms
        p.update(ln1_post=(d,), ln2_post=(d,))
    if cfg.moe is not None:
        E = cfg.moe.n_experts
        p.update(router=(d, E), moe_w_gate=(E, d, ff), moe_w_up=(E, d, ff),
                 moe_w_down=(E, ff, d))
    elif cfg.enc_dec:
        p.update(w1=(d, ff), w2=(ff, d))   # whisper GELU MLP
    else:
        p.update(w_gate=(d, ff), w_up=(d, ff), w_down=(ff, d))
    if cfg.family == "hybrid":
        N = cfg.ssm_state
        p.update(ssm_in=(d, H * Dh), ssm_dt=(d, H), ssm_B=(d, H * N),
                 ssm_C=(d, H * N), A_log=(H, N),
                 attn_norm=(H * Dh,), ssm_norm=(H * Dh,))
    if cfg.enc_dec:                       # decoder cross-attention
        p.update(ln_x=(d,), wq_x=(d, H * Dh), wk_x=(d, Hk * Dh),
                 wv_x=(d, Hk * Dh), wo_x=(H * Dh, d))
    return p


def _mlstm_param_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    # Dh-major TP layout: q/k replicated, v and the down-projection shard
    # on Dh so the matrix memory stays local per device (§Perf-2).
    d, H = cfg.d_model, cfg.n_heads
    Dh = d // H
    return dict(ln1=(d,), wq3=(d, Dh, H), wk3=(d, Dh, H), wv3=(d, Dh, H),
                w_z3=(d, Dh, H), w_if=(d, 2 * H), w_down3=(Dh, H, d))


def _slstm_param_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    # four separate gate projections: a fused (d, 4d) weight would shard
    # its output across gate boundaries and reshard on every split
    d = cfg.d_model
    return dict(ln1=(d,), w_zi=(d, d), w_zf=(d, d), w_zz=(d, d),
                w_zo=(d, d), w_down=(d, d))


def _init_group(key, shapes: Dict[str, Tuple[int, ...]], stack: Tuple[int, ...],
                dtype, d_model: int):
    out = {}
    keys = jax.random.split(key, len(shapes))
    for (name, shp), k in zip(sorted(shapes.items()), keys):
        full = stack + shp
        if len(shp) == 1 or name == "A_log":
            if name == "A_log":
                out[name] = jnp.broadcast_to(
                    jnp.log(jnp.arange(1, shp[-1] + 1, dtype=jnp.float32)),
                    full).astype(jnp.float32)
            else:
                out[name] = jnp.zeros(full, dtype)
        else:
            scale = (shp[0]) ** -0.5
            out[name] = _norm_init(k, full, dtype, scale)
    return out


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    """Build the full parameter pytree. Pure-jax: usable under
    jax.eval_shape for the allocation-free dry-run."""
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": _norm_init(keys[0], (cfg.vocab, cfg.d_model), dt, 0.02),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _norm_init(
            keys[1], (cfg.d_model, cfg.vocab), dt, cfg.d_model ** -0.5)

    if cfg.family == "ssm":               # xLSTM: groups of (m..m, s)
        G, per = _xlstm_groups(cfg)
        params["mlstm"] = _init_group(keys[2], _mlstm_param_shapes(cfg),
                                      (G, per - 1), dt, cfg.d_model)
        params["slstm"] = _init_group(keys[3], _slstm_param_shapes(cfg),
                                      (G,), dt, cfg.d_model)
    elif cfg.enc_dec:
        enc_shapes = {k: v for k, v in _layer_param_shapes(cfg).items()
                      if not k.endswith("_x")}
        params["enc_blocks"] = _init_group(keys[2], enc_shapes,
                                           (cfg.n_enc_layers,), dt, cfg.d_model)
        params["blocks"] = _init_group(keys[3], _layer_param_shapes(cfg),
                                       (cfg.n_layers,), dt, cfg.d_model)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
        params["enc_pos"] = _norm_init(keys[4], (cfg.enc_positions,
                                                 cfg.d_model), dt, 0.02)
    else:
        params["blocks"] = _init_group(keys[2], _layer_param_shapes(cfg),
                                       (cfg.n_layers,), dt, cfg.d_model)
    return params


def _xlstm_groups(cfg: ArchConfig) -> Tuple[int, int]:
    per = cfg.slstm_every if cfg.slstm_every else cfg.n_layers
    if cfg.n_layers % per:
        raise ValueError("n_layers must divide by slstm_every")
    return cfg.n_layers // per, per


def window_schedule(cfg: ArchConfig) -> np.ndarray:
    """Per-layer attention window (GLOBAL_WINDOW = full attention)."""
    L = cfg.n_layers
    w = np.full((L,), GLOBAL_WINDOW, np.int32)
    if cfg.local_global_period and cfg.sliding_window:
        for i in range(L):                 # gemma2: local on even layers
            if i % cfg.local_global_period == 0:
                w[i] = cfg.sliding_window
    elif cfg.family == "hybrid" and cfg.sliding_window:
        w[:] = cfg.sliding_window          # hymba: SWA everywhere except
        for i in (0, L // 2, L - 1):       # first / middle / last global
            w[i] = GLOBAL_WINDOW
    return w


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

class ForwardOut(NamedTuple):
    logits: jnp.ndarray
    aux_loss: jnp.ndarray
    cache: Optional[Any]          # per-layer (k, v) or recurrent states


def _embed_inputs(cfg: ArchConfig, params, batch):
    dt = _dtype(cfg)
    tok = batch["tokens"]
    x = jnp.take(params["embed"], tok, axis=0).astype(dt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.n_img_tokens and "image_embeds" in batch:
        img = batch["image_embeds"].astype(dt)
        x = jnp.concatenate([img, x], axis=1)
    return x


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray],
            *, return_cache: bool = False, q_offset: int = 0,
            logits_mode: str = "all") -> ForwardOut:
    """Full-sequence forward. batch: tokens (B,S); llava adds image_embeds
    (B,Ni,d); whisper adds frames (B,Te,d).

    logits_mode: 'all' (train), 'last' (prefill: unembed only the final
    position — avoids the (B,S,V) buffer), 'hidden' (return the final
    hidden states in .logits; the caller computes chunked CE without ever
    materializing full logits — see repro.train.step)."""
    x = _embed_inputs(cfg, params, batch)
    x = constrain_batch(x)
    B, S, _ = x.shape
    positions = q_offset + jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                            (B, S))
    aux_total = jnp.zeros((), jnp.float32)
    cache = None

    if cfg.family == "ssm":
        x, cache = _xlstm_stack(cfg, params, x, return_cache)
    elif cfg.enc_dec:
        enc = batch["frames"].astype(x.dtype)
        enc = enc + params["enc_pos"][None, :enc.shape[1]].astype(x.dtype)

        def enc_step(h, lp):
            return constrain_batch(
                blocks.whisper_encoder_block(cfg, lp, h)), None
        enc, _ = jax.lax.scan(enc_step, enc, params["enc_blocks"])
        enc = layers.rms_norm(enc, params["enc_norm"], cfg.norm_eps)

        def dec_step(h, lp):
            h2, k, v = blocks.whisper_decoder_block(cfg, lp, constrain_batch(h),
                                                    enc, positions)
            return constrain_batch(h2), (k, v) if return_cache else None
        x, kv = jax.lax.scan(dec_step, x, params["blocks"])
        cache = {"kv": kv, "enc_out": enc} if return_cache else None
    elif cfg.family == "hybrid":
        x, cache = _hymba_stack(cfg, params, x, positions, return_cache,
                                q_offset)
    else:
        wsched = jnp.asarray(window_schedule(cfg))

        def step(h, inp):
            lp, w = inp
            h = constrain_batch(h)
            a = blocks.attention_block(cfg, lp, h, positions, window=w,
                                       q_offset=q_offset)
            h2, aux = blocks.ffn_block(cfg, lp, a.y)
            return constrain_batch(h2), ((a.k, a.v) if return_cache else None,
                                         aux)

        def step_wrap(carry, inp):
            h, aux_acc = carry
            h2, (kv, aux) = step(h, inp)
            return (h2, aux_acc + aux), kv
        (x, aux_total), kv = jax.lax.scan(
            step_wrap, (x, aux_total), (params["blocks"], wsched))
        cache = {"kv": kv} if return_cache else None

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if logits_mode == "hidden":
        return ForwardOut(x, aux_total, cache)
    if logits_mode == "last":
        x = x[:, -1:]
    unemb = params.get("unembed")
    if unemb is None:
        unemb = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, unemb,
                        preferred_element_type=jnp.float32)
    logits = constrain_batch(logits, extra_model_dim=2)
    logits = layers.softcap(logits, cfg.final_softcap)
    return ForwardOut(logits, aux_total, cache)


def _xlstm_stack(cfg, params, x, return_cache):
    G, per = _xlstm_groups(cfg)

    def group_step(h, gp):
        def m_step(hh, lp):
            hh2 = recurrent.mlstm_block(cfg, lp, constrain_batch(hh))
            return constrain_batch(hh2), None
        h, _ = jax.lax.scan(m_step, h, gp["m"])
        h = recurrent.slstm_block(cfg, gp["s"], h)
        return constrain_batch(h), None

    h, _ = jax.lax.scan(group_step, x,
                        {"m": params["mlstm"], "s": params["slstm"]})
    # prefill cache for SSM families is produced by `prefill` (needs final
    # recurrent states, which the train scan does not thread out).
    return h, None


def _hymba_stack(cfg, params, x, positions, return_cache, q_offset):
    wsched = jnp.asarray(window_schedule(cfg))

    def step(h, inp):
        lp, w = inp
        h2, k, v = recurrent.hymba_block(cfg, lp, constrain_batch(h),
                                         positions, window=w,
                                         q_offset=q_offset)
        return constrain_batch(h2), (k, v) if return_cache else None

    x, kv = jax.lax.scan(step, x, (params["blocks"], wsched))
    return x, ({"kv": kv} if return_cache else None)


# ---------------------------------------------------------------------------
# decode (single token, KV/state caches)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int) -> Any:
    """Allocate the decode cache pytree (called under eval_shape for the
    dry-run; real serving allocates it once)."""
    dt = _dtype(cfg)
    Hk, Dh = cfg.n_kv_heads, cfg.head_dim
    if cfg.family == "ssm":
        G, per = _xlstm_groups(cfg)
        H, D = cfg.n_heads, cfg.d_model // cfg.n_heads
        return {
            # C in bf16: halves the dominant decode memory term; the
            # normalizer n and the sLSTM scalar states stay f32
            # (EXPERIMENTS.md §Perf-2, iteration 5)
            "mlstm_C": jnp.zeros((G, per - 1, batch, H, D, D), jnp.bfloat16),
            "mlstm_n": jnp.zeros((G, per - 1, batch, H, D), jnp.float32),
            "slstm_c": jnp.zeros((G, batch, H, D), jnp.float32),
            "slstm_n": jnp.zeros((G, batch, H, D), jnp.float32),
            "slstm_m": jnp.full((G, batch, H, D), -1e30, jnp.float32),
        }
    if cfg.family == "hybrid":
        ws = window_schedule(cfg)
        caches = []
        H, N = cfg.n_heads, cfg.ssm_state
        for w in ws:
            T = int(min(int(w), max_len))
            caches.append({
                "k": jnp.zeros((batch, T, Hk, Dh), dt),
                "v": jnp.zeros((batch, T, Hk, Dh), dt),
                "ssm": jnp.zeros((batch, H, N, Dh), jnp.float32),
            })
        return {"layers": caches}
    if cfg.enc_dec:
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, Hk, Dh), dt),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, Hk, Dh), dt),
            "enc_out": jnp.zeros((batch, cfg.enc_positions, cfg.d_model), dt),
        }
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, Hk, Dh), dt),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, Hk, Dh), dt),
    }


def decode_step(cfg: ArchConfig, params: Params, cache: Any,
                tokens: jnp.ndarray, t) -> Tuple[jnp.ndarray, Any]:
    """One decode step: tokens (B,1) -> logits (B,1,V), updated cache.
    `t` is the current sequence position (traced scalar)."""
    dt = _dtype(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)

    if cfg.family == "ssm":
        x, cache = _xlstm_decode(cfg, params, cache, x)
    elif cfg.family == "hybrid":
        ws = window_schedule(cfg)
        new_layers = []
        for li, lc in enumerate(cache["layers"]):
            x, k2, v2, s2 = recurrent.hymba_block_step(
                cfg, jax.tree.map(lambda a: a[li], params["blocks"]),
                x, lc["k"], lc["v"], lc["ssm"], t,
                window=int(ws[li]))
            new_layers.append({"k": k2, "v": v2, "ssm": s2})
        cache = {"layers": new_layers}
    elif cfg.enc_dec:
        def step(carry, inp):
            h, = carry
            lp, kc, vc = inp
            h2, kc2, vc2 = blocks.attention_decode(cfg, lp, h, kc, vc, t)
            h2 = blocks.cross_attention(cfg, lp, h2, cache["enc_out"])
            h2 = blocks.gelu_mlp(lp, h2, cfg.norm_eps)
            return (h2,), (kc2, vc2)
        (x,), (k2, v2) = jax.lax.scan(
            step, (x,), (params["blocks"], cache["k"], cache["v"]))
        cache = {"k": k2, "v": v2, "enc_out": cache["enc_out"]}
    else:
        wsched = jnp.asarray(window_schedule(cfg))

        def step(carry, inp):
            h, = carry
            lp, kc, vc, w = inp
            h2, kc2, vc2 = blocks.attention_decode(cfg, lp, h, kc, vc, t,
                                                   window=w)
            h2, _ = blocks.ffn_block(cfg, lp, h2)
            return (h2,), (kc2, vc2)
        (x,), (k2, v2) = jax.lax.scan(
            step, (x,), (params["blocks"], cache["k"], cache["v"], wsched))
        cache = {"k": k2, "v": v2}

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    unemb = params.get("unembed")
    if unemb is None:
        unemb = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, unemb,
                        preferred_element_type=jnp.float32)
    return layers.softcap(logits, cfg.final_softcap), cache


def _xlstm_decode(cfg, params, cache, x):
    G, per = _xlstm_groups(cfg)

    def group_step(carry, inp):
        h, = carry
        gp, C, n, sc, sn, sm = inp

        def m_step(carry2, inp2):
            hh, = carry2
            lp, Ci, ni = inp2
            hh2, (C2, n2) = recurrent.mlstm_block_step(cfg, lp, hh, (Ci, ni))
            # keep the stacked scan output in the cache's (B/dp,H,Dv/tp,Dk)
            # layout — otherwise the step ends with a full state gather
            C2 = constrain_batch(C2, extra_model_dim=2)
            n2 = constrain_batch(n2)
            return (hh2,), (C2, n2)
        (h,), (C2, n2) = jax.lax.scan(m_step, (h,), (gp["m"], C, n))
        h, (sc2, sn2, sm2) = recurrent.slstm_block_step(
            cfg, gp["s"], h, (sc, sn, sm))
        return (h,), (C2, n2, sc2, sn2, sm2)

    (x,), (C2, n2, sc2, sn2, sm2) = jax.lax.scan(
        group_step, (x,),
        ({"m": params["mlstm"], "s": params["slstm"]},
         cache["mlstm_C"], cache["mlstm_n"], cache["slstm_c"],
         cache["slstm_n"], cache["slstm_m"]))
    cache = {"mlstm_C": C2, "mlstm_n": n2, "slstm_c": sc2,
             "slstm_n": sn2, "slstm_m": sm2}
    return x, cache
