"""Token pipeline for LM training: synthetic deterministic streams plus a
memmap .bin reader, with host-side sharding for multi-process data
parallelism (each host loads only its DP shard)."""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


def synthetic_tokens(vocab_size: int, batch: int, seq_len: int, step: int,
                     seed: int = 0) -> dict:
    """Deterministic pseudo-corpus: a mixture of Zipfian unigrams and
    shifted-repeat structure so models have learnable signal."""
    rng = np.random.default_rng(np.uint32(seed) + np.uint32(step))
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(vocab_size, size=(batch, seq_len + 1), p=probs)
    # inject copy structure: second half repeats first half with shift
    half = seq_len // 2
    toks[:, half:half * 2] = (toks[:, :half] + 1) % vocab_size
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }


@dataclasses.dataclass
class TokenPipeline:
    """Iterable pipeline. If `bin_path` exists, reads a flat int32 memmap
    corpus; otherwise generates synthetic batches. `dp_rank`/`dp_size`
    shard the global batch across hosts."""
    vocab_size: int
    batch: int                 # GLOBAL batch
    seq_len: int
    bin_path: Optional[str] = None
    dp_rank: int = 0
    dp_size: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.batch % self.dp_size:
            raise ValueError("global batch must divide by dp_size")
        self._local_batch = self.batch // self.dp_size
        self._mm = None
        if self.bin_path and Path(self.bin_path).exists():
            self._mm = np.memmap(self.bin_path, dtype=np.int32, mode="r")

    def get_batch(self, step: int) -> dict:
        """The (tokens, labels) dict for one train step — deterministic
        per step, memory-mapped when a corpus file is configured."""
        if self._mm is None:
            full = synthetic_tokens(self.vocab_size, self.batch, self.seq_len,
                                    step, self.seed)
        else:
            n_tok = self.batch * (self.seq_len + 1)
            start = (step * n_tok) % max(1, (len(self._mm) - n_tok))
            flat = np.asarray(self._mm[start:start + n_tok])
            toks = flat.reshape(self.batch, self.seq_len + 1) % self.vocab_size
            full = {"tokens": toks[:, :-1].astype(np.int32),
                    "labels": toks[:, 1:].astype(np.int32)}
        lo = self.dp_rank * self._local_batch
        hi = lo + self._local_batch
        return {k: v[lo:hi] for k, v in full.items()}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.get_batch(step)
            step += 1
