"""repro.data — input pipelines: procedural scalar fields standing in for
the paper's application datasets, and the LM token pipeline."""
from .fields import synthetic_field, FIELD_GENERATORS
from .tokens import TokenPipeline, synthetic_tokens

__all__ = ["synthetic_field", "FIELD_GENERATORS", "TokenPipeline",
           "synthetic_tokens"]
