"""Procedural scalar fields standing in for the paper's datasets.

The paper evaluates on Nyx (cosmology), viscous fingering, Red Sea, climate
(CESM/IVT), combustion, molecular (AT) data — none of which ship with this
container. Each generator below reproduces the *topological character* of
one dataset class (multi-scale smooth extrema, filamentary structure,
turbulent small-scale critical points) so edit ratios / iteration counts
land in comparable regimes. All generators are deterministic in (name,
shape, seed).
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence, Tuple

import numpy as np


def _freq_grid(shape):
    axes = [np.fft.fftfreq(s) for s in shape]
    mesh = np.meshgrid(*axes, indexing="ij")
    return np.sqrt(sum(m * m for m in mesh))


def _spectral_field(shape, slope, seed) -> np.ndarray:
    """Gaussian random field with power-law spectrum |k|^slope."""
    rng = np.random.default_rng(seed)
    white = rng.normal(size=shape)
    k = _freq_grid(shape)
    amp = np.where(k > 0, np.power(np.maximum(k, 1e-9), slope / 2.0), 0.0)
    f = np.fft.ifftn(np.fft.fftn(white) * amp).real
    f = (f - f.mean()) / (f.std() + 1e-12)
    return f.astype(np.float32)


def nyx_like(shape=(64, 64, 64), seed=1) -> np.ndarray:
    """Cosmology-like: log-normal density with filamentary walls (steep
    spectrum + exponentiation sharpens peaks like dark-matter density)."""
    g = _spectral_field(shape, slope=-2.5, seed=seed)
    return np.exp(1.2 * g).astype(np.float32)


def viscous_fingering_like(shape=(64, 64, 64), seed=2) -> np.ndarray:
    """High topological complexity: mid-scale turbulence plus a density
    gradient (salt collecting at the bottom of the cylinder)."""
    g = _spectral_field(shape, slope=-1.2, seed=seed)
    z = np.linspace(0, 1, shape[0], dtype=np.float32)
    grad = z.reshape(-1, *([1] * (len(shape) - 1)))
    return (g + 2.0 * grad).astype(np.float32)


def climate_like(shape=(180, 360), seed=3) -> np.ndarray:
    """IVT-like 2D: smooth large-scale bands with embedded filaments."""
    g = _spectral_field(shape, slope=-3.0, seed=seed)
    bands = np.sin(np.linspace(0, 4 * np.pi, shape[0], dtype=np.float32))
    return (g + 0.8 * bands[:, None]).astype(np.float32)


def combustion_like(shape=(64, 64, 64), seed=4) -> np.ndarray:
    """Flame-like: sharp reaction fronts = tanh of a smooth field."""
    g = _spectral_field(shape, slope=-2.0, seed=seed)
    return np.tanh(3.0 * g).astype(np.float32)


def molecular_like(shape=(48, 48, 24), seed=5) -> np.ndarray:
    """Electron-density-like: superposition of atomic Gaussians."""
    rng = np.random.default_rng(seed)
    coords = [np.arange(s, dtype=np.float32) for s in shape]
    mesh = np.meshgrid(*coords, indexing="ij")
    f = np.zeros(shape, np.float32)
    n_atoms = max(8, int(np.prod(shape) // 2000))
    for _ in range(n_atoms):
        c = [rng.uniform(0, s) for s in shape]
        w = rng.uniform(1.5, 4.0)
        r2 = sum((m - ci) ** 2 for m, ci in zip(mesh, c))
        f += rng.uniform(0.5, 2.0) * np.exp(-r2 / (2 * w * w))
    return f.astype(np.float32)


def heated_flow_like(shape=(150, 450), seed=6) -> np.ndarray:
    """2D flow past a heated cylinder: vortex street pattern."""
    g = _spectral_field(shape, slope=-1.8, seed=seed)
    y, x = np.meshgrid(np.linspace(-1, 1, shape[0], dtype=np.float32),
                       np.linspace(0, 6, shape[1], dtype=np.float32),
                       indexing="ij")
    street = np.sin(3 * x - 2 * y) * np.exp(-np.abs(y) * 1.5)
    return (0.6 * g + street).astype(np.float32)


FIELD_GENERATORS: Dict[str, Callable[..., np.ndarray]] = {
    "nyx": nyx_like,
    "fingering": viscous_fingering_like,
    "climate": climate_like,
    "combustion": combustion_like,
    "molecular": molecular_like,
    "heated_flow": heated_flow_like,
}


def synthetic_field(name: str, shape: Tuple[int, ...] | None = None,
                    seed: int | None = None) -> np.ndarray:
    """A procedural stand-in for one of the paper's datasets (see
    FIELD_GENERATORS for names); deterministic per (name, shape, seed)."""
    gen = FIELD_GENERATORS[name]
    kwargs = {}
    if shape is not None:
        kwargs["shape"] = tuple(shape)
    if seed is not None:
        kwargs["seed"] = seed
    return gen(**kwargs)
