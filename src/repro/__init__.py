"""MSz on JAX/Pallas: topology-preserving error-bounded lossy compression.

A reproduction — grown toward a production-scale serving system — of
*MSz: An Efficient Parallel Algorithm for Correcting Morse-Smale
Segmentations in Error-Bounded Lossy Compressors*. The package couples
error-bounded lossy base compressors with a parallel fix loop that edits
the decompressed field until its Morse-Smale segmentation is EXACTLY the
original's, while keeping every value within the error bound.

Layer map (see README.md and DESIGN.md):

* ``repro.core``        — MSz itself: grid stencils, MSS labels, the fix
  loops, the stencil-backend protocol, and the high-level
  ``derive_edits`` / ``verify_preservation`` API.
* ``repro.kernels``     — Pallas slab kernels for the stencil stages and
  the Lorenzo transform.
* ``repro.compress``    — SZ/ZFP-like base codecs, the edit codec, the
  end-to-end pipeline (``compress_preserving_mss``), and the streaming
  scheduler (``repro.compress.stream``).
* ``repro.distributed`` — the slab-sharded SPMD fix loop over a device
  mesh (``shardfix``) plus gradient-compression utilities.
* ``repro.serve``       — the request-batched compression service
  (``repro.serve.compression``) and LM serving steps.
* ``repro.launch``      — mesh construction and the service/train/LM
  launchers; ``repro.data`` — synthetic fields standing in for the
  paper's datasets; ``repro.models`` / ``repro.train`` / ``repro.configs``
  / ``repro.checkpoint`` — the LM stack the serving scaffolding grew
  around.
"""
