"""Fault-tolerant checkpointing.

Guarantees:
  * atomic commits — payloads are written to a temp dir, fsync'd, then
    renamed; a manifest with per-tensor checksums is written LAST, so a
    checkpoint without a valid manifest is garbage-collected, never loaded;
  * crash-safe restore — `latest` resolution scans manifests newest-first
    and verifies checksums before use;
  * elastic resharding — tensors are stored unsharded (gathered); restore
    re-shards onto the *current* mesh whatever mesh wrote them, so restarts
    may change pod/data/model sizes freely (elastic scaling);
  * optional error-bounded lossy payload compression (the paper's SZ-like
    compressor) for non-critical tensors (optimizer second moments by
    default) with per-tensor bounds recorded in the manifest; exact (zlib)
    for params. MSz topology-corrected compression is exposed for scalar
    *field* checkpoints (the paper's own data kind).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import struct
import tempfile
import time
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..compress.szlike import sz_compress, sz_decompress

_FORMAT_VERSION = 3


def _tensor_key(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _encode(arr: np.ndarray, mode: str, rel_bound: float):
    """Returns (blob, meta). mode: 'raw' | 'zlib' | 'sz'."""
    if mode == "sz" and arr.dtype in (np.float32, np.float64) and arr.ndim in (2, 3):
        rng = float(np.max(arr) - np.min(arr)) if arr.size else 0.0
        xi = max(rng * rel_bound, 1e-12)
        blob = sz_compress(arr, xi)
        return blob, {"codec": "sz", "xi": xi, "dtype": str(arr.dtype),
                      "shape": list(arr.shape)}
    if mode in ("zlib", "sz"):
        return (zlib.compress(arr.tobytes(), 1),
                {"codec": "zlib", "dtype": str(arr.dtype),
                 "shape": list(arr.shape)})
    return arr.tobytes(), {"codec": "raw", "dtype": str(arr.dtype),
                           "shape": list(arr.shape)}


def _decode(blob: bytes, meta: dict) -> np.ndarray:
    if meta["codec"] == "sz":
        return sz_decompress(blob).astype(meta["dtype"]).reshape(meta["shape"])
    raw = zlib.decompress(blob) if meta["codec"] == "zlib" else blob
    a = np.frombuffer(raw, dtype=np.dtype(meta["dtype"]))
    return a.reshape(meta["shape"]).copy()


def save_checkpoint(directory: str | Path, step: int, tree: Any,
                    compress: str = "zlib", lossy_rel_bound: float = 1e-5,
                    lossy_filter: Optional[Callable[[str], bool]] = None
                    ) -> Path:
    """Atomically write `tree` under directory/step_<N>."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:010d}"
    tmp = Path(tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_"))
    manifest: Dict[str, Any] = {"format": _FORMAT_VERSION, "step": step,
                                "time": time.time(), "tensors": {}}
    try:
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        for i, (path, leaf) in enumerate(flat):
            key = _tensor_key(path)
            arr = np.asarray(jax.device_get(leaf))
            # bf16 has no numpy dtype string round-trip: store raw bytes + tag
            tag = None
            if arr.dtype == jnp.bfloat16:
                tag = "bfloat16"
                arr = arr.view(np.uint16)
            mode = compress
            if compress == "sz" and lossy_filter and not lossy_filter(key):
                mode = "zlib"
            blob, meta = _encode(arr, mode, lossy_rel_bound)
            if tag:
                meta["jax_dtype"] = tag
            fn = f"t{i:05d}.bin"
            (tmp / fn).write_bytes(blob)
            meta["file"] = fn
            meta["sha1"] = hashlib.sha1(blob).hexdigest()
            manifest["tensors"][key] = meta
        manifest["treedef"] = str(treedef)
        # manifest written last = commit point
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def _valid_ckpts(directory: Path):
    out = []
    for p in sorted(directory.glob("step_*"), reverse=True):
        if (p / "manifest.json").exists():
            out.append(p)
    return out


def restore_checkpoint(directory: str | Path, like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Restore the newest valid checkpoint (or a specific step) into the
    structure of `like`. If `shardings` (a NamedSharding pytree) is given,
    tensors are placed sharded onto the CURRENT mesh — elastic restore."""
    directory = Path(directory)
    cands = _valid_ckpts(directory)
    if step is not None:
        cands = [p for p in cands if p.name == f"step_{step:010d}"]
    last_err: Optional[Exception] = None
    for ckpt in cands:
        try:
            manifest = json.loads((ckpt / "manifest.json").read_text())
            flat, treedef = jax.tree_util.tree_flatten_with_path(like)
            leaves = []
            shard_flat = (jax.tree_util.tree_leaves(shardings)
                          if shardings is not None else [None] * len(flat))
            for (path, leaf), shard in zip(flat, shard_flat):
                key = _tensor_key(path)
                meta = manifest["tensors"][key]
                blob = (ckpt / meta["file"]).read_bytes()
                if hashlib.sha1(blob).hexdigest() != meta["sha1"]:
                    raise IOError(f"checksum mismatch for {key}")
                arr = _decode(blob, meta)
                if meta.get("jax_dtype") == "bfloat16":
                    arr = arr.view(np.uint16) if arr.dtype != np.uint16 else arr
                    jarr = jnp.asarray(arr).view(jnp.bfloat16)
                else:
                    jarr = jnp.asarray(arr)
                jarr = jarr.reshape(leaf.shape)
                if shard is not None:
                    jarr = jax.device_put(jarr, shard)
                leaves.append(jarr)
            tree = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(like), leaves)
            return tree, int(manifest["step"])
        except Exception as e:      # corrupted: try the next-newest
            last_err = e
            continue
    raise FileNotFoundError(
        f"no valid checkpoint under {directory}"
        + (f" (last error: {last_err})" if last_err else ""))


@dataclasses.dataclass
class CheckpointManager:
    """save-every-N policy + retention + auto-resume."""
    directory: str | Path
    save_every: int = 100
    keep: int = 3
    compress: str = "zlib"

    def maybe_save(self, step: int, tree: Any) -> Optional[Path]:
        """Save ``tree`` when ``step`` hits the save cadence; returns
        the checkpoint path (None when this step is skipped)."""
        if step % self.save_every:
            return None
        p = save_checkpoint(self.directory, step, tree, self.compress)
        self._gc()
        return p

    def _gc(self):
        ckpts = _valid_ckpts(Path(self.directory))
        for old in ckpts[self.keep:]:
            shutil.rmtree(old, ignore_errors=True)
        # orphaned temp dirs from crashes
        for tmp in Path(self.directory).glob(".tmp_ckpt_*"):
            shutil.rmtree(tmp, ignore_errors=True)

    def restore_latest(self, like: Any, shardings: Any = None):
        """Restore the newest checkpoint in the directory into the
        structure of ``like`` (optionally placed onto ``shardings``)."""
        return restore_checkpoint(self.directory, like, shardings=shardings)
