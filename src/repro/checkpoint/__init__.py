"""repro.checkpoint — fault-tolerant checkpointing with optional
error-bounded compressed payloads and elastic mesh resharding."""
from .manager import CheckpointManager, save_checkpoint, restore_checkpoint

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint"]
