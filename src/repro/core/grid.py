"""Structured-grid PL topology primitives (Freudenthal triangulation).

The paper operates on triangular/tetrahedral meshes; every dataset it
evaluates is a structured grid, for which the Freudenthal triangulation
yields fixed neighbor stencils:

  * 2D: 6-neighborhood  (4 axis + the (+1,+1)/(-1,-1) diagonal)
  * 3D: 14-neighborhood (6 axis + 8 diagonal offsets along the main diagonal)

All comparisons use Simulation-of-Simplicity (SoS) total ordering
``(value, linear_index)`` so non-Morse (tied) inputs are handled exactly as
in the paper (Edelsbrunner & Muecke).

Everything here is expressed as dense shift-based stencils (pad + slice),
which XLA fuses well and which map 1:1 onto the Pallas TPU kernels in
``repro.kernels``.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Freudenthal stencils. Offsets come in +/- pairs: code(2k+1) = -code(2k).
OFFSETS_2D: Tuple[Tuple[int, ...], ...] = (
    (0, 1), (0, -1),
    (1, 0), (-1, 0),
    (1, 1), (-1, -1),
)
OFFSETS_3D: Tuple[Tuple[int, ...], ...] = (
    (0, 0, 1), (0, 0, -1),
    (0, 1, 0), (0, -1, 0),
    (1, 0, 0), (-1, 0, 0),
    (0, 1, 1), (0, -1, -1),
    (1, 0, 1), (-1, 0, -1),
    (1, 1, 0), (-1, -1, 0),
    (1, 1, 1), (-1, -1, -1),
)


def offsets_for(ndim: int) -> Tuple[Tuple[int, ...], ...]:
    """The paper's neighbor stencil offsets: 8-neighborhood in 2D,
    14-neighborhood (6 face + 8 body diagonal) in 3D."""
    if ndim == 2:
        return OFFSETS_2D
    if ndim == 3:
        return OFFSETS_3D
    raise ValueError(f"MSz supports 2D/3D piecewise-linear fields, got ndim={ndim}")


def n_neighbors(ndim: int) -> int:
    """Stencil size: 8 in 2D, 14 in 3D."""
    return len(offsets_for(ndim))


def self_code(ndim: int) -> int:
    """Direction code meaning 'self' (the vertex is an extremum)."""
    return n_neighbors(ndim)


def shift(x: jnp.ndarray, off: Sequence[int], fill) -> jnp.ndarray:
    """y[v] = x[v + off], with ``fill`` outside the domain."""
    pads = [(max(0, -o), max(0, o)) for o in off]
    xp = jnp.pad(x, pads, constant_values=fill)
    sl = tuple(slice(max(0, o), max(0, o) + s) for o, s in zip(off, x.shape))
    return xp[sl]


def linear_index(shape: Sequence[int]) -> jnp.ndarray:
    """Row-major flat vertex ids of a grid, shaped like the grid (the
    SoS tie-break key: lower id wins ties)."""
    return jnp.arange(int(np.prod(shape)), dtype=jnp.int32).reshape(shape)


def _sos_argbest(vals: jnp.ndarray, idxs: jnp.ndarray, *, ascending: bool):
    """Slot of the SoS-lexicographic best along axis 0 of stacked
    (values, linear indices): max (v, i) when ascending, min otherwise.

    Three small reductions instead of a chained compare-and-select scan:
    the scan's carried values feed every comparison of every later step,
    and XLA:CPU's elemental emitter re-emits each operand expression per
    use, making one fused 14-step scan kernel exponential (~4x compile
    time per stencil neighbor, >10^5 s for the full Freudenthal stencil).
    Reductions are emitted as loops, keeping codegen linear; results are
    bitwise identical to the scan.
    """
    if ascending:
        v_best = jnp.max(vals, axis=0)
        i_fill = jnp.int32(np.iinfo(np.int32).min)
        i_best = jnp.max(jnp.where(vals == v_best, idxs, i_fill), axis=0)
    else:
        v_best = jnp.min(vals, axis=0)
        i_fill = jnp.int32(np.iinfo(np.int32).max)
        i_best = jnp.min(jnp.where(vals == v_best, idxs, i_fill), axis=0)
    win = (vals == v_best) & (idxs == i_best)
    return jnp.argmax(win, axis=0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def steepest_dirs(f: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused 'update directions' + 'classify extrema' stencil.

    Returns ``(up_code, dn_code)`` int32 arrays of f's shape. ``up_code[v]``
    is the stencil code of the steepest SoS-ascending neighbor of ``v``
    (the first edge of v's ascending integral line), or ``self_code(ndim)``
    when ``v`` is a maximum. Symmetrically for ``dn_code`` / minima.

    This is the paper's dominant component ('updating directions', ~80% of
    CPU time, Table 1) fused with its 'find critical points' pass. Slot 0
    of the stacked candidates is the vertex itself, so slot k+1 is stencil
    code k and slot 0 winning means 'extremum'.
    """
    offs = offsets_for(f.ndim)
    sc = jnp.int32(self_code(f.ndim))
    lin = linear_index(f.shape)
    neg_inf = jnp.asarray(-jnp.inf, f.dtype)
    pos_inf = jnp.asarray(jnp.inf, f.dtype)

    up_vals = jnp.stack([f] + [shift(f, o, neg_inf) for o in offs])
    up_idxs = jnp.stack([lin] + [shift(lin, o, jnp.int32(-1)) for o in offs])
    slot_up = _sos_argbest(up_vals, up_idxs, ascending=True)
    up_c = jnp.where(slot_up == 0, sc, slot_up - 1)

    i_max = jnp.int32(np.iinfo(np.int32).max)
    dn_vals = jnp.stack([f] + [shift(f, o, pos_inf) for o in offs])
    dn_idxs = jnp.stack([lin] + [shift(lin, o, i_max) for o in offs])
    slot_dn = _sos_argbest(dn_vals, dn_idxs, ascending=False)
    dn_c = jnp.where(slot_dn == 0, sc, slot_dn - 1)
    return up_c, dn_c


def gather_dir(x: jnp.ndarray, code: jnp.ndarray) -> jnp.ndarray:
    """y[v] = x[v + offset(code[v])]; y[v] = x[v] where code==self."""
    offs = offsets_for(x.ndim)
    out = x
    zero = jnp.zeros((), x.dtype)
    for k, off in enumerate(offs):
        # fill value irrelevant — a valid code never points off-domain.
        out = jnp.where(code == k, shift(x, off, zero), out)
    return out


def dir_to_pointer(code: jnp.ndarray) -> jnp.ndarray:
    """Direction codes -> flattened next-vertex pointers (self at extrema)."""
    lin = linear_index(code.shape)
    nxt = gather_dir(lin, code)
    return nxt.reshape(-1)


def is_extremum(code: jnp.ndarray) -> jnp.ndarray:
    return code == self_code(code.ndim)
