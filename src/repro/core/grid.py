"""Structured-grid PL topology primitives (Freudenthal triangulation).

The paper operates on triangular/tetrahedral meshes; every dataset it
evaluates is a structured grid, for which the Freudenthal triangulation
yields fixed neighbor stencils:

  * 2D: 6-neighborhood  (4 axis + the (+1,+1)/(-1,-1) diagonal)
  * 3D: 14-neighborhood (6 axis + 8 diagonal offsets along the main diagonal)

All comparisons use Simulation-of-Simplicity (SoS) total ordering
``(value, linear_index)`` so non-Morse (tied) inputs are handled exactly as
in the paper (Edelsbrunner & Muecke).

Everything here is expressed as dense shift-based stencils (pad + slice),
which XLA fuses well and which map 1:1 onto the Pallas TPU kernels in
``repro.kernels``.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Freudenthal stencils. Offsets come in +/- pairs: code(2k+1) = -code(2k).
OFFSETS_2D: Tuple[Tuple[int, ...], ...] = (
    (0, 1), (0, -1),
    (1, 0), (-1, 0),
    (1, 1), (-1, -1),
)
OFFSETS_3D: Tuple[Tuple[int, ...], ...] = (
    (0, 0, 1), (0, 0, -1),
    (0, 1, 0), (0, -1, 0),
    (1, 0, 0), (-1, 0, 0),
    (0, 1, 1), (0, -1, -1),
    (1, 0, 1), (-1, 0, -1),
    (1, 1, 0), (-1, -1, 0),
    (1, 1, 1), (-1, -1, -1),
)


def offsets_for(ndim: int) -> Tuple[Tuple[int, ...], ...]:
    if ndim == 2:
        return OFFSETS_2D
    if ndim == 3:
        return OFFSETS_3D
    raise ValueError(f"MSz supports 2D/3D piecewise-linear fields, got ndim={ndim}")


def n_neighbors(ndim: int) -> int:
    return len(offsets_for(ndim))


def self_code(ndim: int) -> int:
    """Direction code meaning 'self' (the vertex is an extremum)."""
    return n_neighbors(ndim)


def shift(x: jnp.ndarray, off: Sequence[int], fill) -> jnp.ndarray:
    """y[v] = x[v + off], with ``fill`` outside the domain."""
    pads = [(max(0, -o), max(0, o)) for o in off]
    xp = jnp.pad(x, pads, constant_values=fill)
    sl = tuple(slice(max(0, o), max(0, o) + s) for o, s in zip(off, x.shape))
    return xp[sl]


def linear_index(shape: Sequence[int]) -> jnp.ndarray:
    return jnp.arange(int(np.prod(shape)), dtype=jnp.int32).reshape(shape)


def _lex_gt(v1, i1, v2, i2):
    """SoS strict order: (v1, i1) > (v2, i2)."""
    return (v1 > v2) | ((v1 == v2) & (i1 > i2))


def _lex_lt(v1, i1, v2, i2):
    return (v1 < v2) | ((v1 == v2) & (i1 < i2))


@functools.partial(jax.jit, static_argnames=())
def steepest_dirs(f: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused 'update directions' + 'classify extrema' stencil.

    Returns ``(up_code, dn_code)`` int32 arrays of f's shape. ``up_code[v]``
    is the stencil code of the steepest SoS-ascending neighbor of ``v``
    (the first edge of v's ascending integral line), or ``self_code(ndim)``
    when ``v`` is a maximum. Symmetrically for ``dn_code`` / minima.

    This is the paper's dominant component ('updating directions', ~80% of
    CPU time, Table 1) fused with its 'find critical points' pass.
    """
    offs = offsets_for(f.ndim)
    lin = linear_index(f.shape)
    neg_inf = jnp.asarray(-jnp.inf, f.dtype)
    pos_inf = jnp.asarray(jnp.inf, f.dtype)

    up_v, up_i = f, lin
    up_c = jnp.full(f.shape, self_code(f.ndim), jnp.int32)
    dn_v, dn_i = f, lin
    dn_c = jnp.full(f.shape, self_code(f.ndim), jnp.int32)
    for k, off in enumerate(offs):
        nv = shift(f, off, neg_inf)
        ni = shift(lin, off, jnp.int32(-1))
        take = _lex_gt(nv, ni, up_v, up_i)
        up_v = jnp.where(take, nv, up_v)
        up_i = jnp.where(take, ni, up_i)
        up_c = jnp.where(take, jnp.int32(k), up_c)

        nv2 = shift(f, off, pos_inf)
        ni2 = shift(lin, off, jnp.int32(np.iinfo(np.int32).max))
        take2 = _lex_lt(nv2, ni2, dn_v, dn_i)
        dn_v = jnp.where(take2, nv2, dn_v)
        dn_i = jnp.where(take2, ni2, dn_i)
        dn_c = jnp.where(take2, jnp.int32(k), dn_c)
    return up_c, dn_c


def gather_dir(x: jnp.ndarray, code: jnp.ndarray) -> jnp.ndarray:
    """y[v] = x[v + offset(code[v])]; y[v] = x[v] where code==self."""
    offs = offsets_for(x.ndim)
    out = x
    zero = jnp.zeros((), x.dtype)
    for k, off in enumerate(offs):
        # fill value irrelevant — a valid code never points off-domain.
        out = jnp.where(code == k, shift(x, off, zero), out)
    return out


def dir_to_pointer(code: jnp.ndarray) -> jnp.ndarray:
    """Direction codes -> flattened next-vertex pointers (self at extrema)."""
    lin = linear_index(code.shape)
    nxt = gather_dir(lin, code)
    return nxt.reshape(-1)


def is_extremum(code: jnp.ndarray) -> jnp.ndarray:
    return code == self_code(code.ndim)
