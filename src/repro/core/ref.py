"""Pure-numpy brute-force oracle for the core topology primitives.

Independent implementation (per-vertex loops, explicit path walking) used
by unit/property tests to validate the vectorized JAX path and the Pallas
kernels. Deliberately simple and slow."""
from __future__ import annotations

import numpy as np

from .grid import offsets_for


def _neighbors(shape, v):
    """Yield (code, linear index) of in-domain stencil neighbors of v."""
    offs = offsets_for(len(shape))
    idx = np.unravel_index(v, shape)
    for k, off in enumerate(offs):
        nb = tuple(i + o for i, o in zip(idx, off))
        if all(0 <= c < s for c, s in zip(nb, shape)):
            yield k, int(np.ravel_multi_index(nb, shape))


def _gt(f, a, b):
    """SoS: vertex a > vertex b."""
    return (f[a], a) > (f[b], b)


def steepest_dirs_ref(field: np.ndarray):
    """(up_code, dn_code) matching grid.steepest_dirs, brute force."""
    f = field.reshape(-1)
    shape = field.shape
    K = len(offsets_for(field.ndim))
    up = np.full(f.shape, K, np.int32)
    dn = np.full(f.shape, K, np.int32)
    for v in range(f.size):
        best_up, best_dn = v, v
        up_code, dn_code = K, K
        for k, nb in _neighbors(shape, v):
            if _gt(f, nb, best_up):
                best_up, up_code = nb, k
            if _gt(f, best_dn, nb):
                best_dn, dn_code = nb, k
        up[v], dn[v] = up_code, dn_code
    return up.reshape(shape), dn.reshape(shape)


def mss_labels_ref(field: np.ndarray):
    """(M, m) labels by explicitly walking every integral line."""
    f = field.reshape(-1)
    shape = field.shape
    M = np.empty(f.shape, np.int32)
    m = np.empty(f.shape, np.int32)
    for v in range(f.size):
        cur = v
        while True:
            nxt = cur
            for _, nb in _neighbors(shape, cur):
                if _gt(f, nb, nxt):
                    nxt = nb
            if nxt == cur:
                break
            cur = nxt
        M[v] = cur
        cur = v
        while True:
            nxt = cur
            for _, nb in _neighbors(shape, cur):
                if _gt(f, nxt, nb):
                    nxt = nb
            if nxt == cur:
                break
            cur = nxt
        m[v] = cur
    return M.reshape(shape), m.reshape(shape)


def extrema_ref(field: np.ndarray):
    up, dn = steepest_dirs_ref(field)
    K = len(offsets_for(field.ndim))
    return up == K, dn == K


def apply_edits_ref(f_hat: np.ndarray, idx, val) -> np.ndarray:
    """Oracle edit application: ``g = f_hat`` with ``g.flat[i] += v`` one
    edit at a time, each addition performed in the field's own dtype —
    the bitwise reference for driver.apply_edits and the device scatter.
    The MSz edit stream addresses each site at most once; a duplicate
    (or out-of-range) index means a corrupt blob, so both raise."""
    idx = np.asarray(idx, np.int64).reshape(-1)
    val = np.asarray(val).reshape(-1)
    if idx.size != val.size:
        raise ValueError(
            f"edit stream length mismatch: {idx.size} indices vs "
            f"{val.size} values")
    if idx.size and (idx.min() < 0 or idx.max() >= f_hat.size):
        raise ValueError(
            f"edit index out of range for a field of {f_hat.size} sites")
    if np.unique(idx).size != idx.size:
        raise ValueError("duplicate edit indices: each site is edited at "
                         "most once per artifact")
    g = f_hat.copy()
    flat = g.reshape(-1)
    for i, v in zip(idx, val):
        # mszlint: disable=scatter-discipline -- i is one loop scalar and
        # the np.unique check above already rejected duplicate indices
        flat[i] += flat.dtype.type(v)
    return g


def labels_equal_ref(f: np.ndarray, g: np.ndarray) -> bool:
    """Whether f and g induce the SAME Morse-Smale segmentation, judged
    entirely by the oracle labeler (no JAX involved)."""
    Mf, mf = mss_labels_ref(np.asarray(f))
    Mg, mg = mss_labels_ref(np.asarray(g))
    return bool(np.array_equal(Mf, Mg) and np.array_equal(mf, mg))


def verify_preservation_ref(f: np.ndarray, g: np.ndarray, xi: float) -> dict:
    """Pure-numpy mirror of driver.verify_preservation: the same verdict
    dict, computed with the oracle labeler — the single source of truth
    the conformance suite checks the production verifier against."""
    f = np.asarray(f)
    if f.ndim not in (2, 3):
        raise ValueError(
            f"verify_preservation_ref takes one 2D/3D field (got shape "
            f"{f.shape})")
    g = np.asarray(g, f.dtype)
    Mf, mf = mss_labels_ref(f)
    Mg, mg = mss_labels_ref(g)
    max_label_ok = bool(np.array_equal(Mf, Mg))
    min_label_ok = bool(np.array_equal(mf, mg))
    err = float(np.max(np.abs(f.astype(np.float64) - g.astype(np.float64))))
    right = float(np.mean((Mf == Mg) & (mf == mg)))
    return dict(
        bound_ok=err <= xi * (1 + 1e-6),
        max_abs_err=err,
        max_labels_ok=max_label_ok,
        min_labels_ok=min_label_ok,
        mss_preserved=max_label_ok and min_label_ok,
        right_labeled_ratio=right,
    )
