"""Pure-numpy brute-force oracle for the core topology primitives.

Independent implementation (per-vertex loops, explicit path walking) used
by unit/property tests to validate the vectorized JAX path and the Pallas
kernels. Deliberately simple and slow."""
from __future__ import annotations

import numpy as np

from .grid import offsets_for


def _neighbors(shape, v):
    """Yield (code, linear index) of in-domain stencil neighbors of v."""
    offs = offsets_for(len(shape))
    idx = np.unravel_index(v, shape)
    for k, off in enumerate(offs):
        nb = tuple(i + o for i, o in zip(idx, off))
        if all(0 <= c < s for c, s in zip(nb, shape)):
            yield k, int(np.ravel_multi_index(nb, shape))


def _gt(f, a, b):
    """SoS: vertex a > vertex b."""
    return (f[a], a) > (f[b], b)


def steepest_dirs_ref(field: np.ndarray):
    """(up_code, dn_code) matching grid.steepest_dirs, brute force."""
    f = field.reshape(-1)
    shape = field.shape
    K = len(offsets_for(field.ndim))
    up = np.full(f.shape, K, np.int32)
    dn = np.full(f.shape, K, np.int32)
    for v in range(f.size):
        best_up, best_dn = v, v
        up_code, dn_code = K, K
        for k, nb in _neighbors(shape, v):
            if _gt(f, nb, best_up):
                best_up, up_code = nb, k
            if _gt(f, best_dn, nb):
                best_dn, dn_code = nb, k
        up[v], dn[v] = up_code, dn_code
    return up.reshape(shape), dn.reshape(shape)


def mss_labels_ref(field: np.ndarray):
    """(M, m) labels by explicitly walking every integral line."""
    f = field.reshape(-1)
    shape = field.shape
    M = np.empty(f.shape, np.int32)
    m = np.empty(f.shape, np.int32)
    for v in range(f.size):
        cur = v
        while True:
            nxt = cur
            for _, nb in _neighbors(shape, cur):
                if _gt(f, nb, nxt):
                    nxt = nb
            if nxt == cur:
                break
            cur = nxt
        M[v] = cur
        cur = v
        while True:
            nxt = cur
            for _, nb in _neighbors(shape, cur):
                if _gt(f, nxt, nb):
                    nxt = nb
            if nxt == cur:
                break
            cur = nxt
        m[v] = cur
    return M.reshape(shape), m.reshape(shape)


def extrema_ref(field: np.ndarray):
    up, dn = steepest_dirs_ref(field)
    K = len(offsets_for(field.ndim))
    return up == K, dn == K
