"""High-level MSz API: derive edits at compression time, apply at
decompression time, verify exact MSS preservation (the paper's Fig. 3
workflow around the C/R fix loops)."""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Literal, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import fixes, grid
from .backend import BackendLike, resolve_backend
from .labels import mss_labels


@dataclasses.dataclass
class MszResult:
    g: np.ndarray             # edited decompressed field (MSS == original's)
    edits_idx: np.ndarray     # int64 flat indices of edited vertices (sorted)
    edits_val: np.ndarray     # edit values delta_i  (g = f_hat + delta)
    iters: int                # fix-loop iterations to convergence
    converged: bool
    edit_ratio: float         # |edits| / V   (paper's 'edit ratio')
    max_abs_err: float        # max |f - g|   (must be <= xi)
    backend: str = ""         # stencil backend that executed the fix loop


Mode = Literal["fused", "paper"]


def _check_inputs(f, f_hat, xi: float):
    if f.shape != f_hat.shape:
        raise ValueError(f"shape mismatch {f.shape} vs {f_hat.shape}")
    if f.ndim not in (2, 3):
        raise ValueError("MSz operates on 2D/3D piecewise-linear scalar fields")
    if not jnp.issubdtype(f.dtype, jnp.floating):
        raise ValueError(
            f"MSz operates on floating-point fields, got dtype {f.dtype}")
    base_err = float(jnp.max(jnp.abs(f - f_hat)))
    if base_err > xi * (1 + 1e-6):
        raise ValueError(
            f"decompressed data violates the error bound before editing: "
            f"max|f-f_hat|={base_err:.3g} > xi={xi:.3g}")


def _package_result(f, f_hat, g, iters, ok, backend_name: str) -> MszResult:
    g = np.asarray(g)
    delta = g - np.asarray(f_hat)
    idx = np.flatnonzero(delta != 0.0)
    vals = delta.reshape(-1)[idx]
    return MszResult(
        g=g,
        edits_idx=idx.astype(np.int64),
        edits_val=vals,
        iters=int(iters),
        converged=bool(ok),
        edit_ratio=float(idx.size) / float(delta.size),
        max_abs_err=float(np.max(np.abs(np.asarray(f) - g))),
        backend=backend_name,
    )


def derive_edits(f, f_hat, xi: float, mode: Mode = "fused",
                 max_iters: int = 512,
                 backend: BackendLike = "auto", mesh=None) -> MszResult:
    """Compute the edit series {delta_i} such that f_hat + delta has exactly
    the MS segmentation of f, while |f - (f_hat+delta)| <= xi (Section 4).

    ``backend`` picks the stencil execution strategy for the fused loop
    ("auto" prefers the slab-sharded SPMD loop when ``mesh`` — or the
    active ``with mesh:`` context — has >= 2 data-axis devices, then the
    Pallas kernels, then the jnp reference; see core.backend). Paper mode
    always runs the reference stencils. Precondition (checked):
    |f - f_hat| <= xi, same shapes.
    """
    f = jnp.asarray(f)
    f_hat = jnp.asarray(f_hat, f.dtype)
    _check_inputs(f, f_hat, xi)

    topo = fixes.field_topology(f, xi)
    if mode == "fused":
        be = resolve_backend(backend, f.shape, f.dtype, mesh=mesh)
        g, iters, ok = fixes.fused_fix(f_hat, topo, max_iters=max_iters,
                                       backend=be)
        backend_name = be.name
    elif mode == "paper":
        g, iters, ok = fixes.paper_fix(f_hat, topo, max_iters=max_iters)
        backend_name = "reference"
    else:
        raise ValueError(f"unknown mode {mode!r}")

    return _package_result(f, f_hat, g, iters, ok, backend_name)


def derive_edits_batch(f, f_hat, xi: Union[float, Sequence[float]],
                       max_iters: int = 512,
                       backend: BackendLike = "auto",
                       mesh=None, batching: str = "auto",
                       compact_every: int = 8) -> List[MszResult]:
    """Batched ``derive_edits`` over a leading batch axis (fused mode).

    ``f``/``f_hat``: (B, *spatial) with spatial rank 2 or 3; ``xi`` is a
    scalar shared by every member or a per-member sequence of length B
    (each member's topology, and so its compaction trajectory, honors its
    own bound). The fix loops of all members run through the vmapped
    batch driver (fixes.fused_fix_batch), so many small fields pipeline
    through the stencil backend together instead of paying B sequential
    dispatches; ``batching``/``compact_every`` select its early-exit
    strategy — by default still-active members are compacted into
    power-of-two buckets every ``compact_every`` iterations, so members
    that converge early stop costing vmap lanes. Per-member results are
    bitwise identical to solo derive_edits calls under every strategy.
    """
    f = jnp.asarray(f)
    f_hat = jnp.asarray(f_hat, f.dtype)
    if f.shape != f_hat.shape:
        raise ValueError(f"shape mismatch {f.shape} vs {f_hat.shape}")
    if f.ndim not in (3, 4):
        raise ValueError(
            "derive_edits_batch expects (B, *spatial) with 2D/3D members; "
            f"got shape {f.shape}")
    B = f.shape[0]
    xi_arr = np.broadcast_to(np.asarray(xi, np.float64), (B,))
    for i in range(B):
        _check_inputs(f[i], f_hat[i], float(xi_arr[i]))

    topos = [fixes.field_topology(f[i], float(xi_arr[i])) for i in range(B)]
    topo_b = jax.tree_util.tree_map(lambda *leaves: jnp.stack(leaves), *topos)
    be = resolve_backend(backend, f.shape[1:], f.dtype, mesh=mesh)
    g_b, iters_b, ok_b = fixes.fused_fix_batch(f_hat, topo_b,
                                               max_iters=max_iters, backend=be,
                                               batching=batching,
                                               compact_every=compact_every)
    g_b = np.asarray(g_b)
    return [_package_result(f[i], f_hat[i], g_b[i], iters_b[i], ok_b[i],
                            be.name)
            for i in range(B)]


# --- device-side edit extraction (device-resident path, DESIGN.md §4) ------

@jax.jit
def _edit_count(f_hat: jnp.ndarray, g: jnp.ndarray):
    delta = g - f_hat
    return delta, jnp.sum(delta != 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("size",))
def _edit_compact(delta: jnp.ndarray, size: int):
    flat = delta.reshape(-1)
    idx = jnp.nonzero(flat != 0, size=size, fill_value=0)[0]
    return idx, flat[idx]


@functools.partial(jax.jit, static_argnames=("n",))
def _edit_slice(idx: jnp.ndarray, val: jnp.ndarray, n: int):
    return idx[:n], val[:n]


def extract_edits(f_hat: jnp.ndarray, g: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """On-device edit extraction: ``delta != 0`` mask, count, and
    compaction all run inside jit; only the edit count crosses to the
    host (to fix the compaction's static output size), so the returned
    (idx, val) device arrays are the ONLY edit-sized data a caller needs
    to pull. Ascending flat indices — identical to the host path's
    ``np.flatnonzero`` ordering. The compaction size is rounded up to the
    next power of two (then sliced back to the true count), capping jit
    specializations at ~log2(V) instead of one per distinct edit count.

    Runs eagerly, so the count sync is an explicit ``jax.device_get``
    and the final slice runs jitted with a static length — eager
    ``int(n)`` / ``arr[:n]`` would each be an implicit transfer under
    ``debug.no_transfers()`` (eager slicing ships its indices to the
    device per call; the jitted slice bakes them in at trace time, at
    the same one-compile-per-distinct-length cost the eager op paid)."""
    delta, n = _edit_count(f_hat, g)
    n = int(jax.device_get(n))
    if n == 0:
        empty = jnp.zeros(0, jnp.int32)
        return empty, jnp.zeros(0, f_hat.dtype)
    cap = 1 << (n - 1).bit_length()
    idx, val = _edit_compact(delta, cap)
    return _edit_slice(idx, val, n)


def apply_edits(f_hat, edits_idx, edits_val) -> np.ndarray:
    """Decompression-side reconstruction: g = f_hat + delta (Fig. 3 bottom).

    Duplicate indices ACCUMULATE (``np.add.at`` semantics — buffered fancy
    ``+=`` would keep only the last value and silently drop edits). The
    codec forbids duplicates (codec.encode_edits raises), so decoded
    streams take the fast vectorized path; unsorted/duplicated inputs from
    other callers still apply every edit."""
    g = np.array(f_hat, copy=True)
    flat = g.reshape(-1)
    idx = np.asarray(edits_idx).reshape(-1)
    val = np.asarray(edits_val).reshape(-1)
    if idx.size == 0:
        return g
    if idx.size == 1 or np.all(np.diff(idx) > 0):
        # mszlint: disable=scatter-discipline -- diff>0 proves uniqueness
        flat[idx] += val            # strictly increasing => no duplicates
    else:
        np.add.at(flat, idx, val)   # unbuffered: duplicates accumulate
    return g


@jax.jit
def _scatter_edits_jit(f_hat: jnp.ndarray, idx: jnp.ndarray,
                       val: jnp.ndarray) -> jnp.ndarray:
    flat = f_hat.reshape(-1)
    flat = flat.at[idx].add(val.astype(f_hat.dtype), mode="drop")
    return flat.reshape(f_hat.shape)


def apply_edits_device(f_hat: jnp.ndarray, edits_idx, edits_val
                       ) -> jnp.ndarray:
    """On-device twin of ``apply_edits``: one jitted scatter-add, so g
    never leaves the device (the decompression path's mirror of
    ``extract_edits``; DESIGN.md §5). Indices must be unique — the codec
    invariant — making the scatter order-free and the result bitwise
    equal to the host path's ``f_hat[idx] += val``. Out-of-range indices
    (the batched path's padding rows point one past the end) are dropped,
    never wrapped, so callers can pad edit streams to a common length."""
    return _scatter_edits_jit(jnp.asarray(f_hat),
                              jnp.asarray(edits_idx, jnp.int32),
                              jnp.asarray(edits_val))


def verify_preservation(f, g, xi: float) -> dict:
    """Check both paper constraints: global error bound + exact MSS.

    Single-field only (2D/3D); a stacked batch would silently verify the
    wrong thing (labels of the batch-as-one-field), so batched artifacts
    go through ``verify_preservation_batch``."""
    f = jnp.asarray(f)
    if f.ndim not in (2, 3):
        raise ValueError(
            f"verify_preservation takes one 2D/3D field (got shape "
            f"{tuple(f.shape)}); stacked batches verify member-by-member "
            "through verify_preservation_batch")
    g = jnp.asarray(g, f.dtype)
    Mf, mf = mss_labels(f)
    Mg, mg = mss_labels(g)
    max_label_ok = bool(jnp.all(Mf == Mg))
    min_label_ok = bool(jnp.all(mf == mg))
    err = float(jnp.max(jnp.abs(f - g)))
    right = float(jnp.mean(((Mf == Mg) & (mf == mg)).astype(jnp.float32)))
    return dict(
        bound_ok=err <= xi * (1 + 1e-6),
        max_abs_err=err,
        max_labels_ok=max_label_ok,
        min_labels_ok=min_label_ok,
        mss_preserved=max_label_ok and min_label_ok,
        right_labeled_ratio=right,
    )


def verify_preservation_batch(f_b, g_b, xi) -> list:
    """Member-wise ``verify_preservation`` over stacked batches: ``f_b``
    and ``g_b`` are (B, *spatial) with 2D/3D members, ``xi`` a scalar or
    per-member sequence. Returns one verdict dict per member."""
    f_b = np.asarray(f_b)
    g_b = np.asarray(g_b)
    if f_b.ndim not in (3, 4):
        raise ValueError(
            f"verify_preservation_batch takes a (B, *spatial) stack of "
            f"2D/3D fields (got shape {f_b.shape})")
    if f_b.shape != g_b.shape:
        raise ValueError(
            f"batch shapes disagree: f {f_b.shape} vs g {g_b.shape}")
    B = f_b.shape[0]
    xi_arr = np.broadcast_to(np.asarray(xi, np.float64), (B,))
    return [verify_preservation(f_b[i], g_b[i], float(xi_arr[i]))
            for i in range(B)]
