"""High-level MSz API: derive edits at compression time, apply at
decompression time, verify exact MSS preservation (the paper's Fig. 3
workflow around the C/R fix loops)."""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import fixes, grid
from .labels import mss_labels


@dataclasses.dataclass
class MszResult:
    g: np.ndarray             # edited decompressed field (MSS == original's)
    edits_idx: np.ndarray     # int64 flat indices of edited vertices (sorted)
    edits_val: np.ndarray     # edit values delta_i  (g = f_hat + delta)
    iters: int                # fix-loop iterations to convergence
    converged: bool
    edit_ratio: float         # |edits| / V   (paper's 'edit ratio')
    max_abs_err: float        # max |f - g|   (must be <= xi)


Mode = Literal["fused", "paper"]


def derive_edits(f, f_hat, xi: float, mode: Mode = "fused",
                 max_iters: int = 512) -> MszResult:
    """Compute the edit series {delta_i} such that f_hat + delta has exactly
    the MS segmentation of f, while |f - (f_hat+delta)| <= xi (Section 4).

    Precondition (checked): |f - f_hat| <= xi, same shapes.
    """
    f = jnp.asarray(f)
    f_hat = jnp.asarray(f_hat, f.dtype)
    if f.shape != f_hat.shape:
        raise ValueError(f"shape mismatch {f.shape} vs {f_hat.shape}")
    if f.ndim not in (2, 3):
        raise ValueError("MSz operates on 2D/3D piecewise-linear scalar fields")
    base_err = float(jnp.max(jnp.abs(f - f_hat)))
    if base_err > xi * (1 + 1e-6):
        raise ValueError(
            f"decompressed data violates the error bound before editing: "
            f"max|f-f_hat|={base_err:.3g} > xi={xi:.3g}")

    topo = fixes.field_topology(f, xi)
    if mode == "fused":
        g, iters, ok = fixes.fused_fix(f_hat, topo, max_iters=max_iters)
    elif mode == "paper":
        g, iters, ok = fixes.paper_fix(f_hat, topo, max_iters=max_iters)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    g = np.asarray(g)
    delta = g - np.asarray(f_hat)
    idx = np.flatnonzero(delta != 0.0)
    vals = delta.reshape(-1)[idx]
    return MszResult(
        g=g,
        edits_idx=idx.astype(np.int64),
        edits_val=vals,
        iters=int(iters),
        converged=bool(ok),
        edit_ratio=float(idx.size) / float(delta.size),
        max_abs_err=float(np.max(np.abs(np.asarray(f) - g))),
    )


def apply_edits(f_hat, edits_idx, edits_val) -> np.ndarray:
    """Decompression-side reconstruction: g = f_hat + delta (Fig. 3 bottom)."""
    g = np.array(f_hat, copy=True)
    flat = g.reshape(-1)
    flat[edits_idx] += edits_val
    return g


def verify_preservation(f, g, xi: float) -> dict:
    """Check both paper constraints: global error bound + exact MSS."""
    f = jnp.asarray(f)
    g = jnp.asarray(g, f.dtype)
    Mf, mf = mss_labels(f)
    Mg, mg = mss_labels(g)
    max_label_ok = bool(jnp.all(Mf == Mg))
    min_label_ok = bool(jnp.all(mf == mg))
    err = float(jnp.max(jnp.abs(f - g)))
    right = float(jnp.mean(((Mf == Mg) & (mf == mg)).astype(jnp.float32)))
    return dict(
        bound_ok=err <= xi * (1 + 1e-6),
        max_abs_err=err,
        max_labels_ok=max_label_ok,
        min_labels_ok=min_label_ok,
        mss_preserved=max_label_ok and min_label_ok,
        right_labeled_ratio=right,
    )
