"""Morse-Smale segmentation labels via parallel path compression.

Implements the pointer-doubling ('path compression' / pointer jumping)
MSS computation of Maack et al. used by the paper (Section 6.2): every
vertex stores the next vertex of its ascending (descending) integral line;
iterating ``nxt <- nxt[nxt]`` halves every path length per step, so the
label array converges in O(log(longest integral line)) gather sweeps.

The GPU lock-free worklist of the paper is replaced by dense fixpoint
iteration — on a vector machine the 'worklist' is simply the set of lanes
that still change, and the while_loop exits when none do.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import grid


def default_pointer_iters(n_vertices: int) -> int:
    """Doubling sweeps provably sufficient for any pointer chain over
    ``n_vertices``: an integral line visits each vertex at most once, so
    path lengths are < V, each sweep doubles the resolved hop distance,
    and ceil(log2(V)) sweeps reach every root; +1 lets the convergence
    check observe the fixed point. With this bound the while_loop can
    only exit converged — there is no silent truncation."""
    return max(math.ceil(math.log2(max(int(n_vertices), 2))), 1) + 1


def pointer_jump(nxt: jnp.ndarray,
                 max_iters: Optional[int] = None) -> jnp.ndarray:
    """Resolve next-pointers to root labels by pointer doubling.

    nxt: int32 [V], extrema are self-pointers (fixed points).
    Returns int32 [V]: the root (extremum) linear index for every vertex.

    ``max_iters=None`` (default) derives the sweep bound from the field
    size (``default_pointer_iters``), which guarantees convergence for
    every possible pointer field — including a single integral line
    snaking through all V vertices. Passing an explicit smaller bound is
    best-effort only: the loop then exits at the bound with unresolved
    labels and no error (the convergence check is part of the loop
    condition, not an output).
    """
    if max_iters is None:
        max_iters = default_pointer_iters(nxt.size)

    def cond(state):
        it, cur = state
        return (it < max_iters) & jnp.any(cur != jnp.take(cur, cur))

    def body(state):
        it, cur = state
        return it + 1, jnp.take(cur, cur)

    _, out = jax.lax.while_loop(cond, body, (jnp.int32(0), nxt))
    return out


@jax.jit
def mss_labels(f: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(max_label M, min_label m) per vertex — the full PLMSS of ``f``.

    ``M[v]`` is the linear index of the maximum reached by v's ascending
    integral line; ``m[v]`` the minimum reached descending. The MS
    segmentation of the paper is the pair ``<m, M>``.
    """
    up_c, dn_c = grid.steepest_dirs(f)
    M = pointer_jump(grid.dir_to_pointer(up_c)).reshape(f.shape)
    m = pointer_jump(grid.dir_to_pointer(dn_c)).reshape(f.shape)
    return M, m


@jax.jit
def labels_from_codes(up_c: jnp.ndarray, dn_c: jnp.ndarray):
    M = pointer_jump(grid.dir_to_pointer(up_c)).reshape(up_c.shape)
    m = pointer_jump(grid.dir_to_pointer(dn_c)).reshape(dn_c.shape)
    return M, m


def segmentation_accuracy(f: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """'Right labeled ratio' of the paper (Eq. 9): fraction of vertices whose
    <min,max> label pair matches between f and g."""
    Mf, mf = mss_labels(f)
    Mg, mg = mss_labels(g)
    right = (Mf == Mg) & (mf == mg)
    return jnp.mean(right.astype(jnp.float32))
