"""False-point detection and decreasing-edit fix passes.

Two execution modes:

* ``paper``  — faithful reproduction of the paper's workflow (Fig. 3):
  C-loops run the four sub-loops (FPmax, FPmin, FNmax, FNmin) sequentially
  to their individual fixpoints, then an R-pass computes the full MSS of the
  current edited field (pointer jumping), identifies troublemakers as the
  first label discrepancy along integral lines, and reroutes them; C- and
  R-loops alternate until convergence (Section 5.3).

* ``fused``  — our beyond-paper TPU formulation: all six fix conditions are
  *local stencil predicates*, applied simultaneously in one dense pass per
  iteration. The R-condition uses the local characterization
      troublemaker(t)  <=>  M_f[dir_up_g(t)] != M_f[t]   (t non-max)
  which avoids recomputing MSS labels inside the loop entirely (labels are
  only needed once on f, and once at the end for verification). All edits
  remain monotonically decreasing, so the paper's convergence argument
  (Lemma 1) applies verbatim.

Conflict resolution: the paper uses atomicCAS keeping the most significant
edit. All edits decrease, and the edit value ``(g+f-xi)/2`` depends only on
the *target* vertex, so concurrent edits to one vertex are identical — the
dense formulation (each vertex pulls edit requests from its stencil) is
conflict-free by construction and bitwise deterministic.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import grid
from .labels import labels_from_codes, pointer_jump


class FieldTopo(NamedTuple):
    """Static per-field topology of the ORIGINAL data (computed once)."""
    up_c: jnp.ndarray      # steepest ascending dir codes of f
    dn_c: jnp.ndarray      # steepest descending dir codes of f
    is_max: jnp.ndarray    # bool
    is_min: jnp.ndarray    # bool
    M: jnp.ndarray         # ascending (max) labels of f, int32, f.shape
    m: jnp.ndarray         # descending (min) labels of f
    lower: jnp.ndarray     # f - xi  (edit lower bound, Eq. 1)


def field_topology(f: jnp.ndarray, xi) -> FieldTopo:
    up_c, dn_c = grid.steepest_dirs(f)
    M, m = labels_from_codes(up_c, dn_c)
    sc = grid.self_code(f.ndim)
    return FieldTopo(up_c, dn_c, up_c == sc, dn_c == sc, M, m,
                     f - jnp.asarray(xi, f.dtype))


def _halve_toward_lower(g, lower, mask):
    """Eq. 2/3/4/5/6 decreasing edit, clamped so |f-g|<=xi holds exactly."""
    new = jnp.maximum((g + lower) * jnp.asarray(0.5, g.dtype), lower)
    return jnp.where(mask, new, g)


def _pull(src_mask: jnp.ndarray, code: jnp.ndarray) -> jnp.ndarray:
    """pulled[j] = OR_k ( src_mask[j - off_k] & code[j - off_k] == k ).

    Dense 'pull' equivalent of the paper's atomic scatter: a vertex j is an
    edit target iff some stencil neighbor i has ``src_mask[i]`` set and i's
    direction code points at j.
    """
    offs = grid.offsets_for(src_mask.ndim)
    out = jnp.zeros(src_mask.shape, bool)
    for k, off in enumerate(offs):
        noff = tuple(-o for o in off)
        m = grid.shift(src_mask, noff, False)
        c = grid.shift(code, noff, jnp.int32(-1))
        out = out | (m & (c == k))
    return out


# ---------------------------------------------------------------------------
# false-point predicates
# ---------------------------------------------------------------------------

class FalseMasks(NamedTuple):
    fpmax: jnp.ndarray
    fpmin: jnp.ndarray
    fnmax: jnp.ndarray
    fnmin: jnp.ndarray
    up_c_g: jnp.ndarray
    dn_c_g: jnp.ndarray


def false_critical_masks(g: jnp.ndarray, topo: FieldTopo) -> FalseMasks:
    """Definitions 1-3: the four false critical point classes."""
    up_c_g, dn_c_g = grid.steepest_dirs(g)
    sc = grid.self_code(g.ndim)
    is_max_g = up_c_g == sc
    is_min_g = dn_c_g == sc
    return FalseMasks(
        fpmax=is_max_g & ~topo.is_max,
        fpmin=is_min_g & ~topo.is_min,
        fnmax=~is_max_g & topo.is_max,
        fnmin=~is_min_g & topo.is_min,
        up_c_g=up_c_g,
        dn_c_g=dn_c_g,
    )


def trouble_masks(g_codes: FalseMasks, topo: FieldTopo):
    """Local R-loop predicates (our vectorized troublemaker test).

    trouble_max(t): t non-max in g and its g-ascending edge leaves t's
    original ascending region -> demote the wrong winner dir_up_g(t).
    trouble_min(t): symmetric on the descending side -> promote (decrease)
    the ORIGINAL descending neighbor dir_dn_f(t). Only decreasing edits can
    'promote' a descent target, hence the asymmetry (see DESIGN.md §2).
    """
    sc = grid.self_code(topo.M.ndim)
    nonmax_g = g_codes.up_c_g != sc
    nonmin_g = g_codes.dn_c_g != sc
    M_next = grid.gather_dir(topo.M, g_codes.up_c_g)
    m_next = grid.gather_dir(topo.m, g_codes.dn_c_g)
    trouble_max = nonmax_g & (M_next != topo.M)
    trouble_min = nonmin_g & (m_next != topo.m)
    return trouble_max, trouble_min


# ---------------------------------------------------------------------------
# fused mode — one dense pass applies every fix class at once
# ---------------------------------------------------------------------------

def fused_pass(g: jnp.ndarray, topo: FieldTopo):
    """One iteration of the fused fixed-point loop.

    Returns (g_next, n_violations). n_violations == 0 iff g already
    preserves the full MS segmentation of f (extrema + all labels).
    """
    fm = false_critical_masks(g, topo)
    trouble_max, trouble_min = trouble_masks(fm, topo)

    # self-edits: FPmax (Eq. 2) and FNmin (Eq. 5)
    self_edit = fm.fpmax | fm.fnmin
    # demote the wrong g-ascending winner: FNmax (Eq. 4) and max-label
    # troublemakers (Eq. 6, ascending case). FNmax is NOT subsumed by
    # trouble_max: if dir_up_g(t) happens to lead into t's own region,
    # trouble_max(t) is False while t still must be restored as a maximum.
    demote_src = fm.fnmax | trouble_max
    # promote (decrease) the original descending neighbor: FPmin (our
    # convergent variant of Eq. 3) and min-label troublemakers.
    promote_src = fm.fpmin | trouble_min

    target = (self_edit
              | _pull(demote_src, fm.up_c_g)
              | _pull(promote_src, topo.dn_c))
    g_next = _halve_toward_lower(g, topo.lower, target)
    n_viol = jnp.sum(self_edit) + jnp.sum(demote_src) + jnp.sum(promote_src)
    return g_next, n_viol.astype(jnp.int32)


@jax.jit
def fused_fix(g0: jnp.ndarray, topo: FieldTopo, max_iters: int = 512):
    """Run the fused loop to convergence. Returns (g, iters, converged)."""
    def cond(state):
        g, it, viol = state
        return (viol > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        g2, viol2 = fused_pass(g, topo)
        return g2, it + 1, viol2

    g1, viol1 = fused_pass(g0, topo)
    g, iters, viol = jax.lax.while_loop(cond, body, (g1, jnp.int32(1), viol1))
    return g, iters, viol == 0


# ---------------------------------------------------------------------------
# paper mode — sequential sub-loops, label recomputation in R-loops
# ---------------------------------------------------------------------------

def _subloop(g, topo, which: str, max_iters):
    """Run one false-critical-point class to its fixpoint (Section 5.1)."""
    def masks(g):
        fm = false_critical_masks(g, topo)
        return fm

    def target_of(fm):
        if which == "fpmax":      # Eq. 2: decrease the vertex itself
            return fm.fpmax
        if which == "fnmin":      # Eq. 5: decrease the vertex itself
            return fm.fnmin
        if which == "fpmin":
            # DEVIATION from Eq. 3 as printed ("decrease the maximal
            # neighbor"): that target can pin at its lower bound while
            # still above g_i (e.g. neighbors j: f_j >> f_i and k:
            # f_k < f_i — the fix never touches k), deadlocking the
            # sub-loop. We decrease the ORIGINAL steepest-descending
            # neighbor dir_dn_f(i) instead: f_c - xi < f_i - xi <= g_i
            # guarantees it eventually undercuts g_i. See DESIGN.md §2.
            return _pull(fm.fpmin, topo.dn_c)
        if which == "fnmax":      # Eq. 4: decrease i's maximal (g) neighbor
            return _pull(fm.fnmax, fm.up_c_g)
        raise ValueError(which)

    count_of = dict(fpmax=lambda fm: fm.fpmax, fnmin=lambda fm: fm.fnmin,
                    fpmin=lambda fm: fm.fpmin, fnmax=lambda fm: fm.fnmax)[which]

    def cond(state):
        g, it, n = state
        return (n > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        fm = masks(g)
        g2 = _halve_toward_lower(g, topo.lower, target_of(fm))
        fm2 = masks(g2)
        return g2, it + 1, jnp.sum(count_of(fm2)).astype(jnp.int32)

    fm0 = masks(g)
    n0 = jnp.sum(count_of(fm0)).astype(jnp.int32)
    g, it, _ = jax.lax.while_loop(cond, body, (g, jnp.int32(0), n0))
    return g, it


def _c_loop(g, topo, max_iters):
    """One C-loop: the four sub-loops in the paper's order, repeated until
    no false critical point remains."""
    def n_false(g):
        fm = false_critical_masks(g, topo)
        return (jnp.sum(fm.fpmax) + jnp.sum(fm.fpmin)
                + jnp.sum(fm.fnmax) + jnp.sum(fm.fnmin)).astype(jnp.int32)

    def cond(state):
        g, it, n = state
        return (n > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        for which in ("fpmax", "fpmin", "fnmax", "fnmin"):
            g, _ = _subloop(g, topo, which, max_iters)
        return g, it + 1, n_false(g)

    g, it, _ = jax.lax.while_loop(cond, body, (g, jnp.int32(0), n_false(g)))
    return g


def _r_pass(g, topo):
    """One R-pass (Section 5.2): recompute the MSS of g (the expensive
    pointer-jumping step the paper parallelizes), find falsely labeled
    regular points, locate troublemakers, reroute with one edit each."""
    fm = false_critical_masks(g, topo)
    Mg, mg = labels_from_codes(fm.up_c_g, fm.dn_c_g)
    wrong_max_lab = Mg != topo.M
    wrong_min_lab = mg != topo.m
    t_max, t_min = trouble_masks(fm, topo)
    # paper: troublemaker = FIRST discrepancy along a falsely-labeled
    # vertex's integral line == locally-diverging AND itself falsely labeled.
    t_max = t_max & wrong_max_lab
    t_min = t_min & wrong_min_lab
    target = _pull(t_max, fm.up_c_g) | _pull(t_min, topo.dn_c)
    g2 = _halve_toward_lower(g, topo.lower, target)
    n_wrong = (jnp.sum(wrong_max_lab) + jnp.sum(wrong_min_lab)).astype(jnp.int32)
    return g2, n_wrong


@functools.partial(jax.jit, static_argnames=("max_iters",))
def paper_fix(g0: jnp.ndarray, topo: FieldTopo, max_iters: int = 512):
    """Alternate C- and R-loops until no false critical/regular point
    (Section 5.3). Returns (g, outer_iters, converged)."""
    def cond(state):
        g, it, n = state
        return (n > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        g = _c_loop(g, topo, max_iters)
        g, n_wrong = _r_pass(g, topo)
        fm = false_critical_masks(g, topo)
        n = (n_wrong + jnp.sum(fm.fpmax) + jnp.sum(fm.fpmin)
             + jnp.sum(fm.fnmax) + jnp.sum(fm.fnmin)).astype(jnp.int32)
        return g, it + 1, n

    g, iters, n = jax.lax.while_loop(cond, body, (g0, jnp.int32(0), jnp.int32(1)))
    return g, iters, n == 0
