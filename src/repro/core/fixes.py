"""False-point detection and decreasing-edit fix passes.

Two execution modes:

* ``paper``  — faithful reproduction of the paper's workflow (Fig. 3):
  C-loops run the four sub-loops (FPmax, FPmin, FNmax, FNmin) sequentially
  to their individual fixpoints, then an R-pass computes the full MSS of the
  current edited field (pointer jumping), identifies troublemakers as the
  first label discrepancy along integral lines, and reroutes them; C- and
  R-loops alternate until convergence (Section 5.3).

* ``fused``  — our beyond-paper TPU formulation: all six fix conditions are
  *local stencil predicates*, applied simultaneously in one dense pass per
  iteration. The R-condition uses the local characterization
      troublemaker(t)  <=>  M_f[dir_up_g(t)] != M_f[t]   (t non-max)
  which avoids recomputing MSS labels inside the loop entirely (labels are
  only needed once on f, and once at the end for verification). All edits
  remain monotonically decreasing, so the paper's convergence argument
  (Lemma 1) applies verbatim.

The fused iteration itself is executed by a pluggable *stencil backend*
(``repro.core.backend``): ``reference`` (dense jnp, XLA-fused) or
``pallas`` (slab-decomposed TPU kernels, with Z-tiling for large fields).
Backends are bitwise-interchangeable; ``"auto"`` prefers pallas. The
stencil predicates themselves (false_critical_masks, trouble_masks, the
pull-based edit rule) live in backend.py and are re-exported here.

Conflict resolution: the paper uses atomicCAS keeping the most significant
edit. All edits decrease, and the edit value ``(g+f-xi)/2`` depends only on
the *target* vertex, so concurrent edits to one vertex are identical — the
dense formulation (each vertex pulls edit requests from its stencil) is
conflict-free by construction and bitwise deterministic (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import grid
from .backend import (BackendLike, FalseMasks, StencilMasks,  # noqa: F401
                      _halve_toward_lower, _pull, _device_scalar,
                      false_critical_masks, get_backend, resolve_backend,
                      trouble_masks)
from .labels import labels_from_codes, pointer_jump


class FieldTopo(NamedTuple):
    """Static per-field topology of the ORIGINAL data (computed once)."""
    up_c: jnp.ndarray      # steepest ascending dir codes of f
    dn_c: jnp.ndarray      # steepest descending dir codes of f
    is_max: jnp.ndarray    # bool
    is_min: jnp.ndarray    # bool
    M: jnp.ndarray         # ascending (max) labels of f, int32, f.shape
    m: jnp.ndarray         # descending (min) labels of f
    lower: jnp.ndarray     # f - xi  (edit lower bound, Eq. 1)


def field_topology(f: jnp.ndarray, xi) -> FieldTopo:
    """Precompute everything the fix loops need from the ORIGINAL
    field: steepest direction codes, extremum masks, ascending/
    descending MSS labels, and the per-vertex lower bound f - xi.

    Runs eagerly, so the two host scalars it consumes (the self code and
    ``xi``) cross via the explicit transfer seam — an implicit eager
    promotion would trip ``debug.no_transfers()`` on every call."""
    up_c, dn_c = grid.steepest_dirs(f)
    M, m = labels_from_codes(up_c, dn_c)
    sc = _device_scalar(grid.self_code(f.ndim), up_c.dtype)
    return FieldTopo(up_c, dn_c, up_c == sc, dn_c == sc, M, m,
                     f - _device_scalar(xi, f.dtype))


# ---------------------------------------------------------------------------
# fused mode — one dense pass applies every fix class at once, dispatched
# to a stencil backend
# ---------------------------------------------------------------------------

def fused_pass(g: jnp.ndarray, topo: FieldTopo,
               backend: BackendLike = "reference"):
    """One iteration of the fused fixed-point loop.

    Returns (g_next, n_violations). n_violations == 0 iff g already
    preserves the full MS segmentation of f (extrema + all labels).
    """
    return get_backend(backend).fused_step(g, topo)


def _bind(be):
    """Freeze call-time context (the active mesh, for the sharded backend)
    into the instance so jit caches key on it."""
    return be.bind() if hasattr(be, "bind") else be


@functools.partial(jax.jit, static_argnames=("max_iters", "backend"))
def _fused_fix_impl(g0: jnp.ndarray, topo: FieldTopo, max_iters: int,
                    backend):
    be = backend
    if hasattr(be, "fix_loop"):
        # distributed backends run the whole loop inside one shard_map
        # (topology halos exchanged once); trajectory is bitwise equal
        return be.fix_loop(g0, topo, max_iters=max_iters)
    if hasattr(be, "worklist_loop") and be.use_worklist(g0.shape):
        # dirty-slab worklist: re-runs the stencils only near last
        # iteration's edit targets; bitwise equal to the dense loop
        g, iters, ok, _ = be.worklist_loop(g0, topo, max_iters=max_iters)
        return g, iters, ok

    def cond(state):
        g, it, viol = state
        return (viol > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        g2, viol2 = be.fused_step(g, topo)
        return g2, it + 1, viol2

    g1, viol1 = be.fused_step(g0, topo)
    g, iters, viol = jax.lax.while_loop(cond, body, (g1, jnp.int32(1), viol1))
    return g, iters, viol == 0


def fused_fix(g0: jnp.ndarray, topo: FieldTopo, max_iters: int = 512,
              backend: BackendLike = "auto", mesh=None):
    """Run the fused loop to convergence. Returns (g, iters, converged).

    ``backend`` selects the stencil execution strategy (see
    core.backend); all backends produce bitwise-identical trajectories,
    so this choice affects speed only. ``mesh`` routes the loop through
    the slab-sharded SPMD backend (repro.distributed.shardfix) when it
    has >= 2 ``data``-axis devices and ``backend`` is "auto"/"sharded".
    """
    be = _bind(resolve_backend(backend, g0.shape, g0.dtype, mesh=mesh))
    return _fused_fix_impl(g0, topo, max_iters=max_iters, backend=be)


@functools.partial(jax.jit, static_argnames=("max_iters", "backend"))
def _worklist_fix_impl(g0: jnp.ndarray, topo: FieldTopo, max_iters: int,
                       backend):
    return backend.worklist_loop(g0, topo, max_iters=max_iters)


def fused_fix_worklist(g0: jnp.ndarray, topo: FieldTopo,
                       max_iters: int = 512,
                       backend: BackendLike = "pallas_worklist", mesh=None):
    """Run the fused loop through a backend's dirty-slab worklist driver
    (DESIGN.md §7), regardless of its auto-engage threshold. Returns
    (g, iters, converged, skipped_slabs) with the first three bitwise
    equal to ``fused_fix``; ``skipped_slabs`` counts slabs whose group
    was skipped, summed over iterations — the worklist's win metric,
    nonzero whenever violations stay localized for an iteration or more.
    """
    be = _bind(resolve_backend(backend, g0.shape, g0.dtype, mesh=mesh))
    if not hasattr(be, "worklist_loop"):
        raise ValueError(
            f"backend {be.name!r} has no dirty-slab worklist driver; "
            "use the pallas backend family")
    return _worklist_fix_impl(g0, topo, max_iters=max_iters, backend=be)


@functools.partial(jax.jit, static_argnames=("max_iters", "backend"))
def _fused_fix_batch_impl(g0: jnp.ndarray, topo: FieldTopo, max_iters: int,
                          backend):
    be = backend
    step = jax.vmap(be.fused_step, in_axes=(0, 0))

    def cond(state):
        g, it, iters_b, viol = state
        return jnp.any(viol > 0) & (it < max_iters)

    def body(state):
        g, it, iters_b, viol = state
        g2, viol2 = step(g, topo)
        active = viol > 0
        # a converged member has no fix targets, so g2 == g for it already;
        # the where is belt-and-braces freezing
        keep = active.reshape((-1,) + (1,) * (g.ndim - 1))
        return (jnp.where(keep, g2, g), it + 1,
                iters_b + active.astype(jnp.int32),
                jnp.where(active, viol2, viol))

    g1, viol1 = step(g0, topo)
    iters0 = jnp.ones(g0.shape[0], jnp.int32)
    g, _, iters_b, viol = jax.lax.while_loop(
        cond, body, (g1, jnp.int32(1), iters0, viol1))
    return g, iters_b, viol == 0


def _pow2_at_least(n: int) -> int:
    """Smallest power of two >= n (compaction bucket sizes; twin of
    compress.pipeline's helper, duplicated to keep core below compress)."""
    return 1 << max(int(n) - 1, 0).bit_length()


@functools.partial(jax.jit, static_argnames=("k", "backend"))
def _fused_fix_round_impl(g0: jnp.ndarray, topo: FieldTopo,
                          viol0: jnp.ndarray, k: int, backend):
    """Up to ``k`` iterations of the vmapped fused loop on one compaction
    bucket. ``viol0`` is each member's violation count entering the round
    (the first round passes a 1-sentinel so every member takes the dense
    loop's unconditional first step); members whose count hits 0 freeze,
    exactly as in ``_fused_fix_batch_impl``, so per-member trajectories
    stay bitwise equal to solo runs. Returns (g, iters_this_round, viol).
    """
    be = backend
    step = jax.vmap(be.fused_step, in_axes=(0, 0))

    def cond(state):
        _, it, _, viol = state
        return jnp.any(viol > 0) & (it < k)

    def body(state):
        g, it, iters_b, viol = state
        g2, viol2 = step(g, topo)
        active = viol > 0
        keep = active.reshape((-1,) + (1,) * (g.ndim - 1))
        return (jnp.where(keep, g2, g), it + 1,
                iters_b + active.astype(jnp.int32),
                jnp.where(active, viol2, viol))

    iters0 = jnp.zeros(g0.shape[0], jnp.int32)
    g, _, iters_b, viol = jax.lax.while_loop(
        cond, body, (g0, jnp.int32(0), iters0, viol0))
    return g, iters_b, viol


def _fused_fix_batch_compact(g0: jnp.ndarray, topo: FieldTopo,
                             max_iters: int, be, every: int):
    """Active-member compaction driver: the batched loop in host-driven
    rounds of ``every`` iterations, with still-active members gathered
    into a dense prefix between rounds so converged members stop costing
    vmap lanes. Buckets are padded to power-of-two sizes (repeating an
    active member; its result is discarded) so jit specializes on
    ~log2(B) bucket shapes, not one per occupancy. Per-member results are
    bitwise equal to ``_fused_fix_batch_impl``'s: gather/scatter move
    exact copies, the vmapped step is elementwise per member, and every
    member still in a bucket has run exactly the global iteration count.
    """
    B = g0.shape[0]
    g = g0
    viol = np.ones(B, np.int32)        # 1-sentinel: everyone steps once
    iters = np.zeros(B, np.int32)
    active = np.arange(B)
    it_done = 0
    while active.size and it_done < max_iters:
        k = min(every, max_iters - it_done)
        n = active.size
        cap = _pow2_at_least(n)
        # gather padding repeats active[0]; the scatter-back pads with B
        # (out of bounds, mode="drop") so only the n real lanes land —
        # a host-side [:n] slice would be an implicit transfer per round
        sel = np.concatenate([active, np.full(cap - n, active[0],
                                              active.dtype)])
        scat = np.concatenate([active, np.full(cap - n, B, active.dtype)])
        viol_a0 = np.concatenate([viol[active], np.zeros(cap - n, np.int32)])
        g, dit_a, viol_a = _compact_round(
            g, topo, jax.device_put(sel), jax.device_put(scat),
            jax.device_put(viol_a0), k=k, backend=be)
        # host sync: one small explicit pull per round (cap-padded)
        dit = jax.device_get(dit_a)[:n]
        viol_n = jax.device_get(viol_a)[:n]
        # mszlint: disable=scatter-discipline -- active is a flatnonzero
        # subset, unique by construction
        iters[active] += dit
        viol[active] = viol_n
        it_done += k
        active = active[viol_n > 0]
    return g, jax.device_put(iters), jax.device_put(viol == 0)


@functools.partial(jax.jit, static_argnames=("k", "backend"))
def _compact_round(g: jnp.ndarray, topo: FieldTopo, sel: jnp.ndarray,
                   scat: jnp.ndarray, viol_a: jnp.ndarray, k: int, backend):
    """One compaction round, fully jitted: gather the padded active
    bucket, run up to ``k`` iterations, scatter results back (padding
    lanes carry out-of-bounds indices and drop). Keeping the gather/
    scatter inside jit bakes every index constant in at trace time —
    eager ``take``/``at[].set`` would ship scalars per call, tripping
    ``debug.no_transfers()``."""
    g_a = jnp.take(g, sel, axis=0)
    topo_a = jax.tree_util.tree_map(lambda x: jnp.take(x, sel, axis=0), topo)
    g_a, dit_a, viol_a = _fused_fix_round_impl(g_a, topo_a, viol_a,
                                               k=k, backend=backend)
    return g.at[scat].set(g_a, mode="drop"), dit_a, viol_a


def fused_fix_batch(g0: jnp.ndarray, topo: FieldTopo, max_iters: int = 512,
                    backend: BackendLike = "auto", mesh=None,
                    batching: str = "auto", compact_every: int = 8):
    """Batched fused loop over a leading batch axis (many-field workloads:
    timestep series, ensemble members).

    ``g0``: (B, *spatial); every FieldTopo leaf carries the same leading
    batch axis. The per-iteration pass is vmapped across the batch and
    members that converge early stop costing work, so each member's
    (g, iters) is bitwise identical to a solo ``fused_fix`` run. Returns
    (g (B, *spatial), iters (B,), converged (B,) bool).

    ``batching`` picks the early-exit mechanism — the choice never
    changes results, only cost:

    * ``"compact"`` — active-member compaction (DESIGN.md §7): every
      ``compact_every`` iterations the still-active members are gathered
      into a power-of-two bucket and only that bucket runs the next
      round, so batch cost approaches sum(iters) instead of
      B x max(iters).
    * ``"fused"`` — the legacy single vmapped while_loop: converged
      members are frozen by a ``where`` but still occupy vmap lanes
      until the slowest member converges.
    * ``"auto"`` — compaction for B > 1, the plain loop for B == 1
      (a single member has nothing to compact away).

    With a sharded backend (``mesh`` with >= 2 data-axis devices, or
    backend="sharded") the members run sequentially through the mesh —
    each member still bitwise equal to its solo run; vmap over shard_map
    is not attempted and ``batching`` is ignored.
    """
    if batching not in ("auto", "compact", "fused"):
        raise ValueError(
            'batching must be "auto", "compact", or "fused"; '
            f"got {batching!r}")
    if compact_every < 1:
        raise ValueError(f"compact_every must be >= 1, got {compact_every}")
    be = _bind(resolve_backend(backend, g0.shape[1:], g0.dtype, mesh=mesh))
    if hasattr(be, "fix_loop"):
        outs = [_fused_fix_impl(g0[i],
                                jax.tree_util.tree_map(lambda x: x[i], topo),
                                max_iters=max_iters, backend=be)
                for i in range(g0.shape[0])]
        return (jnp.stack([g for g, _, _ in outs]),
                jnp.stack([it for _, it, _ in outs]),
                jnp.stack([ok for _, _, ok in outs]))
    if batching == "auto":
        batching = "compact" if g0.shape[0] > 1 else "fused"
    if batching == "compact":
        return _fused_fix_batch_compact(jax.device_put(g0), topo, max_iters,
                                        be, compact_every)
    return _fused_fix_batch_impl(g0, topo, max_iters=max_iters, backend=be)


# ---------------------------------------------------------------------------
# paper mode — sequential sub-loops, label recomputation in R-loops
# ---------------------------------------------------------------------------

def _subloop(g, topo, which: str, max_iters):
    """Run one false-critical-point class to its fixpoint (Section 5.1)."""
    def masks(g):
        fm = false_critical_masks(g, topo)
        return fm

    def target_of(fm):
        if which == "fpmax":      # Eq. 2: decrease the vertex itself
            return fm.fpmax
        if which == "fnmin":      # Eq. 5: decrease the vertex itself
            return fm.fnmin
        if which == "fpmin":
            # DEVIATION from Eq. 3 as printed ("decrease the maximal
            # neighbor"): that target can pin at its lower bound while
            # still above g_i (e.g. neighbors j: f_j >> f_i and k:
            # f_k < f_i — the fix never touches k), deadlocking the
            # sub-loop. We decrease the ORIGINAL steepest-descending
            # neighbor dir_dn_f(i) instead: f_c - xi < f_i - xi <= g_i
            # guarantees it eventually undercuts g_i. See DESIGN.md §2.
            return _pull(fm.fpmin, topo.dn_c)
        if which == "fnmax":      # Eq. 4: decrease i's maximal (g) neighbor
            return _pull(fm.fnmax, fm.up_c_g)
        raise ValueError(which)

    count_of = dict(fpmax=lambda fm: fm.fpmax, fnmin=lambda fm: fm.fnmin,
                    fpmin=lambda fm: fm.fpmin, fnmax=lambda fm: fm.fnmax)[which]

    def cond(state):
        g, it, n = state
        return (n > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        fm = masks(g)
        g2 = _halve_toward_lower(g, topo.lower, target_of(fm))
        fm2 = masks(g2)
        return g2, it + 1, jnp.sum(count_of(fm2)).astype(jnp.int32)

    fm0 = masks(g)
    n0 = jnp.sum(count_of(fm0)).astype(jnp.int32)
    g, it, _ = jax.lax.while_loop(cond, body, (g, jnp.int32(0), n0))
    return g, it


def _c_loop(g, topo, max_iters):
    """One C-loop: the four sub-loops in the paper's order, repeated until
    no false critical point remains."""
    def n_false(g):
        fm = false_critical_masks(g, topo)
        return (jnp.sum(fm.fpmax) + jnp.sum(fm.fpmin)
                + jnp.sum(fm.fnmax) + jnp.sum(fm.fnmin)).astype(jnp.int32)

    def cond(state):
        g, it, n = state
        return (n > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        for which in ("fpmax", "fpmin", "fnmax", "fnmin"):
            g, _ = _subloop(g, topo, which, max_iters)
        return g, it + 1, n_false(g)

    g, it, _ = jax.lax.while_loop(cond, body, (g, jnp.int32(0), n_false(g)))
    return g


def _r_pass(g, topo):
    """One R-pass (Section 5.2): recompute the MSS of g (the expensive
    pointer-jumping step the paper parallelizes), find falsely labeled
    regular points, locate troublemakers, reroute with one edit each."""
    fm = false_critical_masks(g, topo)
    Mg, mg = labels_from_codes(fm.up_c_g, fm.dn_c_g)
    wrong_max_lab = Mg != topo.M
    wrong_min_lab = mg != topo.m
    t_max, t_min = trouble_masks(fm, topo)
    # paper: troublemaker = FIRST discrepancy along a falsely-labeled
    # vertex's integral line == locally-diverging AND itself falsely labeled.
    t_max = t_max & wrong_max_lab
    t_min = t_min & wrong_min_lab
    target = _pull(t_max, fm.up_c_g) | _pull(t_min, topo.dn_c)
    g2 = _halve_toward_lower(g, topo.lower, target)
    n_wrong = (jnp.sum(wrong_max_lab) + jnp.sum(wrong_min_lab)).astype(jnp.int32)
    return g2, n_wrong


@functools.partial(jax.jit, static_argnames=("max_iters",))
def paper_fix(g0: jnp.ndarray, topo: FieldTopo, max_iters: int = 512):
    """Alternate C- and R-loops until no false critical/regular point
    (Section 5.3). Returns (g, outer_iters, converged)."""
    def cond(state):
        g, it, n = state
        return (n > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        g = _c_loop(g, topo, max_iters)
        g, n_wrong = _r_pass(g, topo)
        fm = false_critical_masks(g, topo)
        n = (n_wrong + jnp.sum(fm.fpmax) + jnp.sum(fm.fpmin)
             + jnp.sum(fm.fnmax) + jnp.sum(fm.fnmin)).astype(jnp.int32)
        return g, it + 1, n

    g, iters, n = jax.lax.while_loop(cond, body, (g0, jnp.int32(0), jnp.int32(1)))
    return g, iters, n == 0
