"""False-point detection and decreasing-edit fix passes.

Two execution modes:

* ``paper``  — faithful reproduction of the paper's workflow (Fig. 3):
  C-loops run the four sub-loops (FPmax, FPmin, FNmax, FNmin) sequentially
  to their individual fixpoints, then an R-pass computes the full MSS of the
  current edited field (pointer jumping), identifies troublemakers as the
  first label discrepancy along integral lines, and reroutes them; C- and
  R-loops alternate until convergence (Section 5.3).

* ``fused``  — our beyond-paper TPU formulation: all six fix conditions are
  *local stencil predicates*, applied simultaneously in one dense pass per
  iteration. The R-condition uses the local characterization
      troublemaker(t)  <=>  M_f[dir_up_g(t)] != M_f[t]   (t non-max)
  which avoids recomputing MSS labels inside the loop entirely (labels are
  only needed once on f, and once at the end for verification). All edits
  remain monotonically decreasing, so the paper's convergence argument
  (Lemma 1) applies verbatim.

The fused iteration itself is executed by a pluggable *stencil backend*
(``repro.core.backend``): ``reference`` (dense jnp, XLA-fused) or
``pallas`` (slab-decomposed TPU kernels, with Z-tiling for large fields).
Backends are bitwise-interchangeable; ``"auto"`` prefers pallas. The
stencil predicates themselves (false_critical_masks, trouble_masks, the
pull-based edit rule) live in backend.py and are re-exported here.

Conflict resolution: the paper uses atomicCAS keeping the most significant
edit. All edits decrease, and the edit value ``(g+f-xi)/2`` depends only on
the *target* vertex, so concurrent edits to one vertex are identical — the
dense formulation (each vertex pulls edit requests from its stencil) is
conflict-free by construction and bitwise deterministic (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import grid
from .backend import (BackendLike, FalseMasks, StencilMasks,  # noqa: F401
                      _halve_toward_lower, _pull, false_critical_masks,
                      get_backend, resolve_backend, trouble_masks)
from .labels import labels_from_codes, pointer_jump


class FieldTopo(NamedTuple):
    """Static per-field topology of the ORIGINAL data (computed once)."""
    up_c: jnp.ndarray      # steepest ascending dir codes of f
    dn_c: jnp.ndarray      # steepest descending dir codes of f
    is_max: jnp.ndarray    # bool
    is_min: jnp.ndarray    # bool
    M: jnp.ndarray         # ascending (max) labels of f, int32, f.shape
    m: jnp.ndarray         # descending (min) labels of f
    lower: jnp.ndarray     # f - xi  (edit lower bound, Eq. 1)


def field_topology(f: jnp.ndarray, xi) -> FieldTopo:
    """Precompute everything the fix loops need from the ORIGINAL
    field: steepest direction codes, extremum masks, ascending/
    descending MSS labels, and the per-vertex lower bound f - xi."""
    up_c, dn_c = grid.steepest_dirs(f)
    M, m = labels_from_codes(up_c, dn_c)
    sc = grid.self_code(f.ndim)
    return FieldTopo(up_c, dn_c, up_c == sc, dn_c == sc, M, m,
                     f - jnp.asarray(xi, f.dtype))


# ---------------------------------------------------------------------------
# fused mode — one dense pass applies every fix class at once, dispatched
# to a stencil backend
# ---------------------------------------------------------------------------

def fused_pass(g: jnp.ndarray, topo: FieldTopo,
               backend: BackendLike = "reference"):
    """One iteration of the fused fixed-point loop.

    Returns (g_next, n_violations). n_violations == 0 iff g already
    preserves the full MS segmentation of f (extrema + all labels).
    """
    return get_backend(backend).fused_step(g, topo)


def _bind(be):
    """Freeze call-time context (the active mesh, for the sharded backend)
    into the instance so jit caches key on it."""
    return be.bind() if hasattr(be, "bind") else be


@functools.partial(jax.jit, static_argnames=("max_iters", "backend"))
def _fused_fix_impl(g0: jnp.ndarray, topo: FieldTopo, max_iters: int,
                    backend):
    be = backend
    if hasattr(be, "fix_loop"):
        # distributed backends run the whole loop inside one shard_map
        # (topology halos exchanged once); trajectory is bitwise equal
        return be.fix_loop(g0, topo, max_iters=max_iters)

    def cond(state):
        g, it, viol = state
        return (viol > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        g2, viol2 = be.fused_step(g, topo)
        return g2, it + 1, viol2

    g1, viol1 = be.fused_step(g0, topo)
    g, iters, viol = jax.lax.while_loop(cond, body, (g1, jnp.int32(1), viol1))
    return g, iters, viol == 0


def fused_fix(g0: jnp.ndarray, topo: FieldTopo, max_iters: int = 512,
              backend: BackendLike = "auto", mesh=None):
    """Run the fused loop to convergence. Returns (g, iters, converged).

    ``backend`` selects the stencil execution strategy (see
    core.backend); all backends produce bitwise-identical trajectories,
    so this choice affects speed only. ``mesh`` routes the loop through
    the slab-sharded SPMD backend (repro.distributed.shardfix) when it
    has >= 2 ``data``-axis devices and ``backend`` is "auto"/"sharded".
    """
    be = _bind(resolve_backend(backend, g0.shape, g0.dtype, mesh=mesh))
    return _fused_fix_impl(g0, topo, max_iters=max_iters, backend=be)


@functools.partial(jax.jit, static_argnames=("max_iters", "backend"))
def _fused_fix_batch_impl(g0: jnp.ndarray, topo: FieldTopo, max_iters: int,
                          backend):
    be = backend
    step = jax.vmap(be.fused_step, in_axes=(0, 0))

    def cond(state):
        g, it, iters_b, viol = state
        return jnp.any(viol > 0) & (it < max_iters)

    def body(state):
        g, it, iters_b, viol = state
        g2, viol2 = step(g, topo)
        active = viol > 0
        # a converged member has no fix targets, so g2 == g for it already;
        # the where is belt-and-braces freezing
        keep = active.reshape((-1,) + (1,) * (g.ndim - 1))
        return (jnp.where(keep, g2, g), it + 1,
                iters_b + active.astype(jnp.int32),
                jnp.where(active, viol2, viol))

    g1, viol1 = step(g0, topo)
    iters0 = jnp.ones(g0.shape[0], jnp.int32)
    g, _, iters_b, viol = jax.lax.while_loop(
        cond, body, (g1, jnp.int32(1), iters0, viol1))
    return g, iters_b, viol == 0


def fused_fix_batch(g0: jnp.ndarray, topo: FieldTopo, max_iters: int = 512,
                    backend: BackendLike = "auto", mesh=None):
    """Batched fused loop over a leading batch axis (many-field workloads:
    timestep series, ensemble members).

    ``g0``: (B, *spatial); every FieldTopo leaf carries the same leading
    batch axis. The per-iteration pass is vmapped across the batch and the
    loop runs until every member converges; members that converge early
    are frozen, so each member's (g, iters) is bitwise identical to a solo
    ``fused_fix`` run. Returns (g (B, *spatial), iters (B,), converged
    (B,) bool).

    With a sharded backend (``mesh`` with >= 2 data-axis devices, or
    backend="sharded") the members run sequentially through the mesh —
    each member still bitwise equal to its solo run; vmap over shard_map
    is not attempted.
    """
    be = _bind(resolve_backend(backend, g0.shape[1:], g0.dtype, mesh=mesh))
    if hasattr(be, "fix_loop"):
        outs = [_fused_fix_impl(g0[i],
                                jax.tree_util.tree_map(lambda x: x[i], topo),
                                max_iters=max_iters, backend=be)
                for i in range(g0.shape[0])]
        return (jnp.stack([g for g, _, _ in outs]),
                jnp.stack([it for _, it, _ in outs]),
                jnp.stack([ok for _, _, ok in outs]))
    return _fused_fix_batch_impl(g0, topo, max_iters=max_iters, backend=be)


# ---------------------------------------------------------------------------
# paper mode — sequential sub-loops, label recomputation in R-loops
# ---------------------------------------------------------------------------

def _subloop(g, topo, which: str, max_iters):
    """Run one false-critical-point class to its fixpoint (Section 5.1)."""
    def masks(g):
        fm = false_critical_masks(g, topo)
        return fm

    def target_of(fm):
        if which == "fpmax":      # Eq. 2: decrease the vertex itself
            return fm.fpmax
        if which == "fnmin":      # Eq. 5: decrease the vertex itself
            return fm.fnmin
        if which == "fpmin":
            # DEVIATION from Eq. 3 as printed ("decrease the maximal
            # neighbor"): that target can pin at its lower bound while
            # still above g_i (e.g. neighbors j: f_j >> f_i and k:
            # f_k < f_i — the fix never touches k), deadlocking the
            # sub-loop. We decrease the ORIGINAL steepest-descending
            # neighbor dir_dn_f(i) instead: f_c - xi < f_i - xi <= g_i
            # guarantees it eventually undercuts g_i. See DESIGN.md §2.
            return _pull(fm.fpmin, topo.dn_c)
        if which == "fnmax":      # Eq. 4: decrease i's maximal (g) neighbor
            return _pull(fm.fnmax, fm.up_c_g)
        raise ValueError(which)

    count_of = dict(fpmax=lambda fm: fm.fpmax, fnmin=lambda fm: fm.fnmin,
                    fpmin=lambda fm: fm.fpmin, fnmax=lambda fm: fm.fnmax)[which]

    def cond(state):
        g, it, n = state
        return (n > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        fm = masks(g)
        g2 = _halve_toward_lower(g, topo.lower, target_of(fm))
        fm2 = masks(g2)
        return g2, it + 1, jnp.sum(count_of(fm2)).astype(jnp.int32)

    fm0 = masks(g)
    n0 = jnp.sum(count_of(fm0)).astype(jnp.int32)
    g, it, _ = jax.lax.while_loop(cond, body, (g, jnp.int32(0), n0))
    return g, it


def _c_loop(g, topo, max_iters):
    """One C-loop: the four sub-loops in the paper's order, repeated until
    no false critical point remains."""
    def n_false(g):
        fm = false_critical_masks(g, topo)
        return (jnp.sum(fm.fpmax) + jnp.sum(fm.fpmin)
                + jnp.sum(fm.fnmax) + jnp.sum(fm.fnmin)).astype(jnp.int32)

    def cond(state):
        g, it, n = state
        return (n > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        for which in ("fpmax", "fpmin", "fnmax", "fnmin"):
            g, _ = _subloop(g, topo, which, max_iters)
        return g, it + 1, n_false(g)

    g, it, _ = jax.lax.while_loop(cond, body, (g, jnp.int32(0), n_false(g)))
    return g


def _r_pass(g, topo):
    """One R-pass (Section 5.2): recompute the MSS of g (the expensive
    pointer-jumping step the paper parallelizes), find falsely labeled
    regular points, locate troublemakers, reroute with one edit each."""
    fm = false_critical_masks(g, topo)
    Mg, mg = labels_from_codes(fm.up_c_g, fm.dn_c_g)
    wrong_max_lab = Mg != topo.M
    wrong_min_lab = mg != topo.m
    t_max, t_min = trouble_masks(fm, topo)
    # paper: troublemaker = FIRST discrepancy along a falsely-labeled
    # vertex's integral line == locally-diverging AND itself falsely labeled.
    t_max = t_max & wrong_max_lab
    t_min = t_min & wrong_min_lab
    target = _pull(t_max, fm.up_c_g) | _pull(t_min, topo.dn_c)
    g2 = _halve_toward_lower(g, topo.lower, target)
    n_wrong = (jnp.sum(wrong_max_lab) + jnp.sum(wrong_min_lab)).astype(jnp.int32)
    return g2, n_wrong


@functools.partial(jax.jit, static_argnames=("max_iters",))
def paper_fix(g0: jnp.ndarray, topo: FieldTopo, max_iters: int = 512):
    """Alternate C- and R-loops until no false critical/regular point
    (Section 5.3). Returns (g, outer_iters, converged)."""
    def cond(state):
        g, it, n = state
        return (n > 0) & (it < max_iters)

    def body(state):
        g, it, _ = state
        g = _c_loop(g, topo, max_iters)
        g, n_wrong = _r_pass(g, topo)
        fm = false_critical_masks(g, topo)
        n = (n_wrong + jnp.sum(fm.fpmax) + jnp.sum(fm.fpmin)
             + jnp.sum(fm.fnmax) + jnp.sum(fm.fnmin)).astype(jnp.int32)
        return g, it + 1, n

    g, iters, n = jax.lax.while_loop(cond, body, (g0, jnp.int32(0), jnp.int32(1)))
    return g, iters, n == 0
