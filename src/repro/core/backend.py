"""Unified stencil-backend dispatch for the MSz fix loop and the
device-resident base transform.

One protocol, many execution strategies (see DESIGN.md §3): every
backend exposes the two stencil stages of the fused fix iteration,

  * ``extrema_masks(g, topo)``  — 'update directions' + 'find false
    critical points' fused (the paper's two dominant components, Table 1)
  * ``fix_pass(g, topo, masks)`` — the pull-based conflict-free edit
    application (DESIGN.md §2)

plus ``fused_step`` composing them into one (g_next, n_violations)
iteration, and — since the device-resident compression path
(DESIGN.md §4) — the SZ-like base transform pair,

  * ``transform(f, step)``          — quantize + integer Lorenzo
    -> int32 residual codes (the cuSZ dual-quantization forward pass)
  * ``reconstruct(r, step, dtype)`` — d nested int32 cumsums + dequant
    -> f_hat, bitwise equal to the host codec's ``sz_decompress`` of the
    same codes (int32 range precondition: szlike.check_int32_range)

so ``f_hat`` flows from residual codes straight into the fix loop
without leaving the device, and — since the device-resident
DECOMPRESSION path (DESIGN.md §5) — the read-side mirror,

  * ``scatter_edits(f_hat, idx, val)`` — jitted scatter-add of the edit
    deltas, g = f_hat + delta, bitwise equal to the host path's
    ``driver.apply_edits`` (unique indices; OOB indices drop, so batched
    callers can pad edit streams)

Registered implementations:

  * ``reference`` — pure-jnp dense stencils (XLA-fused; the former
    ``fixes.fused_pass`` body lives here)
  * ``pallas``    — the slab-decomposed Pallas TPU kernels
    (``kernels.extrema`` / ``kernels.fixpass``), interpret mode off-TPU,
    with pMSz-style Z-tiling for fields above a VMEM slab budget
  * ``sharded``   — the same kernels distributed over the ``data`` axis
    of a device mesh under shard_map with per-iteration ppermute halo
    exchange (``repro.distributed.shardfix``, registered lazily)

Backends must be bitwise-interchangeable: same g trajectory, same
violation counts, same iteration count (tests/test_backend.py and
tests/test_shardfix.py enforce this). ``resolve_backend("auto", ...)``
picks ``sharded`` when a mesh with >= 2 data-axis devices is given or
active, else ``pallas`` whenever the input is supported, and falls back
to ``reference`` otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from . import grid


def _device_scalar(step, dtype) -> jnp.ndarray:
    """The quantization step as a device scalar of ``dtype``.

    ``transform``/``reconstruct`` are called eagerly (outside jit) with a
    host float, and an eager ``jnp.asarray(step, dtype)`` is an IMPLICIT
    host->device transfer — it trips ``debug.no_transfers()``. Route the
    host case through the explicit ``jax.device_put`` API instead
    (identical dtype canonicalization, so the codes are bitwise
    unchanged); values already on device just cast in place."""
    if isinstance(step, jnp.ndarray):
        return step.astype(dtype)
    import numpy as _np
    return jax.device_put(_np.asarray(step, dtype))


# ---------------------------------------------------------------------------
# shared stencil predicates (pure jnp — also reused by the paper-mode loops
# in fixes.py)
# ---------------------------------------------------------------------------

class FalseMasks(NamedTuple):
    fpmax: jnp.ndarray
    fpmin: jnp.ndarray
    fnmax: jnp.ndarray
    fnmin: jnp.ndarray
    up_c_g: jnp.ndarray
    dn_c_g: jnp.ndarray


def false_critical_masks(g: jnp.ndarray, topo) -> FalseMasks:
    """Definitions 1-3: the four false critical point classes."""
    up_c_g, dn_c_g = grid.steepest_dirs(g)
    sc = grid.self_code(g.ndim)
    is_max_g = up_c_g == sc
    is_min_g = dn_c_g == sc
    return FalseMasks(
        fpmax=is_max_g & ~topo.is_max,
        fpmin=is_min_g & ~topo.is_min,
        fnmax=~is_max_g & topo.is_max,
        fnmin=~is_min_g & topo.is_min,
        up_c_g=up_c_g,
        dn_c_g=dn_c_g,
    )


def trouble_masks(g_codes: FalseMasks, topo):
    """Local R-loop predicates (our vectorized troublemaker test).

    trouble_max(t): t non-max in g and its g-ascending edge leaves t's
    original ascending region -> demote the wrong winner dir_up_g(t).
    trouble_min(t): symmetric on the descending side -> promote (decrease)
    the ORIGINAL descending neighbor dir_dn_f(t). Only decreasing edits can
    'promote' a descent target, hence the asymmetry (see DESIGN.md §2).
    """
    sc = grid.self_code(topo.M.ndim)
    nonmax_g = g_codes.up_c_g != sc
    nonmin_g = g_codes.dn_c_g != sc
    M_next = grid.gather_dir(topo.M, g_codes.up_c_g)
    m_next = grid.gather_dir(topo.m, g_codes.dn_c_g)
    trouble_max = nonmax_g & (M_next != topo.M)
    trouble_min = nonmin_g & (m_next != topo.m)
    return trouble_max, trouble_min


def _halve_toward_lower(g, lower, mask):
    """Eq. 2/3/4/5/6 decreasing edit, clamped so |f-g|<=xi holds exactly."""
    new = jnp.maximum((g + lower) * jnp.asarray(0.5, g.dtype), lower)
    return jnp.where(mask, new, g)


def _pull(src_mask: jnp.ndarray, code: jnp.ndarray) -> jnp.ndarray:
    """pulled[j] = OR_k ( src_mask[j - off_k] & code[j - off_k] == k ).

    Dense 'pull' equivalent of the paper's atomic scatter: a vertex j is an
    edit target iff some stencil neighbor i has ``src_mask[i]`` set and i's
    direction code points at j.
    """
    offs = grid.offsets_for(src_mask.ndim)
    out = jnp.zeros(src_mask.shape, bool)
    for k, off in enumerate(offs):
        noff = tuple(-o for o in off)
        m = grid.shift(src_mask, noff, False)
        c = grid.shift(code, noff, jnp.int32(-1))
        out = out | (m & (c == k))
    return out


# ---------------------------------------------------------------------------
# the backend protocol
# ---------------------------------------------------------------------------

class StencilMasks(NamedTuple):
    """Outputs of one extrema/false-point classification pass.

    ``dn_c_f`` is the ORIGINAL field's descending codes (copied out of
    the topo so ``fix_pass`` needs only (g, topo, masks)); the fix-source
    masks follow the fused formulation of fixes.py: self_edit = FPmax |
    FNmin, demote_src = FNmax | trouble_max, promote_src = FPmin |
    trouble_min.
    """
    up_c_g: jnp.ndarray
    dn_c_g: jnp.ndarray
    self_edit: jnp.ndarray
    demote_src: jnp.ndarray
    promote_src: jnp.ndarray
    dn_c_f: jnp.ndarray

    @property
    def n_violations(self) -> jnp.ndarray:
        """Total fix sources — 0 iff the fused loop has converged."""
        return (jnp.sum(self.self_edit) + jnp.sum(self.demote_src)
                + jnp.sum(self.promote_src)).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class ReferenceBackend:
    """Dense pure-jnp stencils (the seed implementation, XLA-fused)."""
    name: str = "reference"

    def supports(self, shape: Tuple[int, ...], dtype) -> bool:
        """Any 2D/3D field (the dense stencils are shape-agnostic)."""
        return len(shape) in (2, 3)

    def extrema_masks(self, g: jnp.ndarray, topo) -> StencilMasks:
        """Classification pass: direction codes + the fused fix-source
        masks of one iteration (see StencilMasks)."""
        fm = false_critical_masks(g, topo)
        t_max, t_min = trouble_masks(fm, topo)
        return StencilMasks(
            up_c_g=fm.up_c_g,
            dn_c_g=fm.dn_c_g,
            self_edit=fm.fpmax | fm.fnmin,
            demote_src=fm.fnmax | t_max,
            promote_src=fm.fpmin | t_min,
            dn_c_f=topo.dn_c,
        )

    def fix_pass(self, g: jnp.ndarray, topo, masks: StencilMasks):
        """Conflict-free pull-based edit application (DESIGN.md §2):
        (g_next, n_violations)."""
        target = ((masks.self_edit != 0)
                  | _pull(masks.demote_src != 0, masks.up_c_g)
                  | _pull(masks.promote_src != 0, masks.dn_c_f))
        return _halve_toward_lower(g, topo.lower, target), masks.n_violations

    def fused_step(self, g: jnp.ndarray, topo):
        """One fused fix iteration: (g_next, n_violations)."""
        masks = self.extrema_masks(g, topo)
        return self.fix_pass(g, topo, masks)

    # -- device-resident base transform (DESIGN.md §4) ----------------
    def transform(self, f: jnp.ndarray, step) -> jnp.ndarray:
        """Quantize + integer Lorenzo -> int32 residual codes."""
        from ..compress.szlike import _sz_transform_jit
        return _sz_transform_jit(f, _device_scalar(step, f.dtype))

    def reconstruct(self, r: jnp.ndarray, step, dtype) -> jnp.ndarray:
        """int32 residual codes -> f_hat in ``dtype`` (bitwise equal to
        the host codec's reconstruction of the same codes)."""
        from ..compress.szlike import sz_inverse
        return sz_inverse(r, _device_scalar(step, dtype))

    # -- device-resident decompression path (DESIGN.md §5) ------------
    def scatter_edits(self, f_hat: jnp.ndarray, idx, val) -> jnp.ndarray:
        """g = f_hat + delta via one jitted scatter-add (XLA-native; a
        Pallas kernel buys nothing for an irregular sparse scatter)."""
        from .driver import apply_edits_device
        return apply_edits_device(f_hat, idx, val)

    # -- on-device entropy codec (DESIGN.md §8) ------------------------
    def pack_codes(self, r: jnp.ndarray):
        """int32 residual codes -> chunked-bitplane stream
        ``(words, bits, n_words)`` (pure-jnp codec; see
        ``repro.kernels.pack``)."""
        from ..kernels.pack import pack_codes_jnp
        return pack_codes_jnp(r)

    def unpack_codes(self, words, bits, shape: Tuple[int, ...]
                     ) -> jnp.ndarray:
        """Inverse of ``pack_codes``: the int32 code array of ``shape``
        from a packed stream."""
        from ..kernels.pack import unpack_codes_jnp
        return unpack_codes_jnp(words, bits, tuple(shape))


@dataclasses.dataclass(frozen=True)
class PallasBackend:
    """Slab-decomposed Pallas kernels (kernels.extrema / kernels.fixpass).

    ``z_tile``: slabs per tile for pMSz-style Z-tiling (None = tile only
    when the field exceeds ``vmem_slab_budget`` slabs per pallas_call).
    Tiled and untiled runs are bitwise identical: each iteration re-slices
    every tile with a fresh 2-slab input halo (halo re-exchange), the
    kernels evaluate boundaries in global coordinates, and only interior
    slabs are kept.

    ``interpret``: None = auto (lowered on TPU/GPU, interpreted
    elsewhere; ``MSZ_PALLAS_INTERPRET`` overrides — see
    ``kernels.extrema.default_interpret``).

    ``worklist`` / ``worklist_group`` / ``worklist_min_slabs``: the
    dirty-slab worklist loop (DESIGN.md §7). ``None`` engages it
    automatically for solo fix loops on fields of at least
    ``worklist_min_slabs`` slabs; True/False force it. The slab axis is
    split into groups of ``worklist_group`` slabs, and each iteration
    re-runs the stencils only on groups within 2 slabs of an edit target
    of the previous iteration (``lax.cond`` keeps the skip inside jit) —
    bitwise identical to the dense loop, because a slab's fresh masks are
    a function of g on its 2-slab neighborhood and untouched
    neighborhoods reproduce last iteration's masks exactly.
    """
    name: str = "pallas"
    z_tile: Optional[int] = None
    vmem_slab_budget: int = 256
    interpret: Optional[bool] = None
    worklist: Optional[bool] = None
    worklist_group: int = 8
    worklist_min_slabs: int = 64

    def supports(self, shape: Tuple[int, ...], dtype) -> bool:
        """Non-empty 2D/3D floating-point fields (slab kernels)."""
        return (len(shape) in (2, 3) and min(shape) >= 1
                and jnp.issubdtype(jnp.dtype(dtype), jnp.floating))

    def _interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        from ..kernels.extrema import default_interpret
        return default_interpret()

    # -- untiled protocol methods -------------------------------------
    def extrema_masks(self, g: jnp.ndarray, topo, *,
                      slab_lo: int = 0,
                      n_slabs_total: Optional[int] = None) -> StencilMasks:
        """Classification pass via the slab kernel; ``slab_lo`` /
        ``n_slabs_total`` place a tile in global coordinates."""
        from ..kernels.extrema import extrema_masks_pallas
        up_c, dn_c, selfe, dem, pro = extrema_masks_pallas(
            g, topo.M, topo.m,
            topo.is_max.astype(jnp.int32), topo.is_min.astype(jnp.int32),
            interpret=self._interpret(), slab_lo=slab_lo,
            n_slabs_total=n_slabs_total)
        return StencilMasks(up_c, dn_c, selfe, dem, pro, topo.dn_c)

    def fix_pass(self, g: jnp.ndarray, topo, masks: StencilMasks):
        """Pull-based edit application via the slab kernel:
        (g_next, n_violations)."""
        from ..kernels.fixpass import fix_pass_pallas
        g2, viol, _ = fix_pass_pallas(
            g, topo.lower, masks.self_edit, masks.demote_src,
            masks.promote_src, masks.up_c_g, masks.dn_c_f,
            interpret=self._interpret())
        return g2, jnp.sum(viol).astype(jnp.int32)

    # -- fused iteration, tiled when needed ---------------------------
    def _pick_tile(self, n_slabs: int) -> int:
        if self.z_tile is not None:
            return max(int(self.z_tile), 1)
        return n_slabs if n_slabs <= self.vmem_slab_budget \
            else self.vmem_slab_budget

    def fused_step(self, g: jnp.ndarray, topo):
        """One fused fix iteration: (g_next, n_violations), Z-tiled
        when the field exceeds the VMEM slab budget."""
        tile = self._pick_tile(g.shape[0])
        if tile >= g.shape[0]:
            masks = self.extrema_masks(g, topo)
            return self.fix_pass(g, topo, masks)
        return self._tiled_step(g, topo, tile)

    # -- device-resident base transform (DESIGN.md §4) ----------------
    def transform(self, f: jnp.ndarray, step) -> jnp.ndarray:
        """Quantize + integer Lorenzo via the slab kernel. No Z-tiling:
        the pallas_call grid already streams slab pairs through VMEM, so
        the footprint is ~2 slabs regardless of field height."""
        from ..kernels.lorenzo import lorenzo_quant_pallas
        return lorenzo_quant_pallas(f, _device_scalar(step, f.dtype),
                                    interpret=self._interpret())

    def reconstruct(self, r: jnp.ndarray, step, dtype) -> jnp.ndarray:
        """Inverse stays XLA-level (kernels.lorenzo docstring) —
        identical arithmetic to the reference backend."""
        from ..compress.szlike import sz_inverse
        return sz_inverse(r, _device_scalar(step, dtype))

    # -- device-resident decompression path (DESIGN.md §5) ------------
    def scatter_edits(self, f_hat: jnp.ndarray, idx, val) -> jnp.ndarray:
        """Same XLA-native scatter-add as the reference backend (sparse
        irregular scatter has no slab structure to exploit)."""
        from .driver import apply_edits_device
        return apply_edits_device(f_hat, idx, val)

    # -- on-device entropy codec (DESIGN.md §8) ------------------------
    def pack_codes(self, r: jnp.ndarray):
        """int32 residual codes -> chunked-bitplane stream
        ``(words, bits, n_words)`` via the per-chunk Pallas transpose
        kernel (bitwise identical to the jnp and host codecs)."""
        from ..kernels.pack import pack_codes_pallas
        return pack_codes_pallas(r, interpret=self._interpret())

    def unpack_codes(self, words, bits, shape: Tuple[int, ...]
                     ) -> jnp.ndarray:
        """Inverse of ``pack_codes`` via the Pallas unpack kernel."""
        from ..kernels.pack import unpack_codes_pallas
        return unpack_codes_pallas(words, bits, tuple(shape),
                                   interpret=self._interpret())

    def _tiled_step(self, g: jnp.ndarray, topo, tile: int):
        """pMSz-style block-decomposed iteration over the slab axis.

        Each tile [z0, z1) reads g with a 2-slab halo (the extrema masks
        of the 1-slab fix halo need g one slab further out), runs both
        kernels in global coordinates, and keeps only [z0, z1) of the
        result. Tiles all read the pre-iteration g, so the update stays
        the dense simultaneous one — bitwise equal to untiled.
        """
        from ..kernels.fixpass import fix_pass_pallas
        n = g.shape[0]
        interp = self._interpret()
        outs = []
        viol = jnp.int32(0)
        for z0 in range(0, n, tile):
            z1 = min(z0 + tile, n)
            a, b = max(z0 - 2, 0), min(z1 + 2, n)
            ext = slice(a, b)
            masks = self.extrema_masks(
                g[ext],
                type(topo)(topo.up_c[ext], topo.dn_c[ext],
                           topo.is_max[ext], topo.is_min[ext],
                           topo.M[ext], topo.m[ext], topo.lower[ext]),
                slab_lo=a, n_slabs_total=n)
            c, d = max(z0 - 1, 0), min(z1 + 1, n)
            ss = slice(c - a, d - a)
            g2, _, _ = fix_pass_pallas(
                g[c:d], topo.lower[c:d],
                masks.self_edit[ss], masks.demote_src[ss],
                masks.promote_src[ss], masks.up_c_g[ss], topo.dn_c[c:d],
                interpret=interp, slab_lo=c, n_slabs_total=n)
            outs.append(g2[z0 - c:z0 - c + (z1 - z0)])
            tp = slice(z0 - a, z1 - a)  # tile proper: each slab counted once
            viol = viol + (jnp.sum(masks.self_edit[tp])
                           + jnp.sum(masks.demote_src[tp])
                           + jnp.sum(masks.promote_src[tp])).astype(jnp.int32)
        return jnp.concatenate(outs, axis=0), viol

    # -- dirty-slab worklist loop (DESIGN.md §7) -----------------------
    def use_worklist(self, shape: Tuple[int, ...]) -> bool:
        """Whether a solo fix loop on ``shape`` should run through
        ``worklist_loop``. Explicit ``worklist=True/False`` wins; auto
        (None) engages above ``worklist_min_slabs`` slabs, where the
        per-group ``lax.cond`` overhead is small against the stencil
        work a converged group saves."""
        if len(shape) not in (2, 3):
            return False
        if self.worklist is not None:
            return bool(self.worklist) and shape[0] >= 2
        return shape[0] >= self.worklist_min_slabs

    def worklist_loop(self, g0: jnp.ndarray, topo, *, max_iters: int):
        """The fused fix loop with per-slab-group early exit: returns
        (g, iters, converged, skipped_slabs), the first three bitwise
        equal to the dense loop's.

        Iteration state carries the previous pass's per-slab fix-source
        and edit-target counts. A group of slabs re-runs the stencils iff
        any slab within 2 slabs of the group carried an edit target last
        iteration; other groups reuse their g slice (unchanged by
        construction) and their stale — still exact — source counts. The
        2-slab radius is the stencil dependency depth: a slab's fix
        output reads masks one slab out, and those masks read g one slab
        further (DESIGN.md §7 gives the induction). Convergence tests the
        summed source counts, identical to the dense loop's violation
        count, so iteration counts match too. ``skipped_slabs``
        accumulates slabs whose group was skipped, summed over
        iterations (the benchmark's worklist-win metric).
        """
        from ..kernels.fixpass import fix_pass_pallas
        n = g0.shape[0]
        wg = max(int(self.worklist_group), 1)
        groups = tuple((z0, min(z0 + wg, n)) for z0 in range(0, n, wg))
        interp = self._interpret()

        def tile_step(g, gi):
            z0, z1 = groups[gi]
            a, b = max(z0 - 2, 0), min(z1 + 2, n)
            ext = slice(a, b)
            masks = self.extrema_masks(
                g[ext], jax.tree_util.tree_map(lambda x: x[ext], topo),
                slab_lo=a, n_slabs_total=n)
            c, d = max(z0 - 1, 0), min(z1 + 1, n)
            ss = slice(c - a, d - a)
            g2, src, tgt = fix_pass_pallas(
                g[c:d], topo.lower[c:d],
                masks.self_edit[ss], masks.demote_src[ss],
                masks.promote_src[ss], masks.up_c_g[ss], topo.dn_c[c:d],
                interpret=interp, slab_lo=c, n_slabs_total=n)
            tp = slice(z0 - c, z0 - c + (z1 - z0))
            return g2[tp], src[tp], tgt[tp]

        def body(state):
            g, it, src, tgt, skipped = state
            dirty = tgt > 0
            run_slab = dirty
            for s in (1, 2):        # dilate by the 2-slab stencil radius
                run_slab = (run_slab
                            | jnp.pad(dirty[s:], (0, s))
                            | jnp.pad(dirty[:-s], (s, 0)))
            parts_g, parts_s, parts_t = [], [], []
            for gi, (z0, z1) in enumerate(groups):
                run = jnp.any(run_slab[z0:z1])

                def compute(ops, gi=gi):
                    return tile_step(ops[0], gi)

                def reuse(ops, z0=z0, z1=z1):
                    return (jax.lax.slice_in_dim(ops[0], z0, z1),
                            jax.lax.slice_in_dim(ops[1], z0, z1),
                            jnp.zeros(z1 - z0, jnp.int32))

                tg, ts, tt = jax.lax.cond(run, compute, reuse, (g, src))
                parts_g.append(tg)
                parts_s.append(ts)
                parts_t.append(tt)
                skipped = skipped + jnp.where(run, 0, z1 - z0)
            return (jnp.concatenate(parts_g, axis=0), it + 1,
                    jnp.concatenate(parts_s, axis=0),
                    jnp.concatenate(parts_t, axis=0), skipped)

        def cond(state):
            _, it, src, _, _ = state
            return (jnp.sum(src) > 0) & (it < max_iters)

        # first iteration unconditionally runs every group (tgt
        # sentinel 1s), mirroring the dense loop's step-then-while shape
        state0 = (g0, jnp.int32(0), jnp.zeros(n, jnp.int32),
                  jnp.ones(n, jnp.int32), jnp.int32(0))
        g, it, src, tgt, skipped = jax.lax.while_loop(cond, body,
                                                      body(state0))
        return g, it, jnp.sum(src) == 0, skipped


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

BackendLike = Union[str, ReferenceBackend, PallasBackend]

_REGISTRY: Dict[str, object] = {}

# backends living in higher layers register themselves on import; naming
# one here pulls its module in on demand so `get_backend("sharded")` works
# without the caller importing repro.distributed first
_LAZY_MODULES: Dict[str, str] = {"sharded": "repro.distributed.shardfix"}


def register_backend(backend, name: Optional[str] = None) -> None:
    """Register a backend instance under ``name`` (default: backend.name)."""
    _REGISTRY[name or backend.name] = backend


def _ensure_lazy_backends() -> None:
    import importlib
    for name, module in _LAZY_MODULES.items():
        if name not in _REGISTRY:
            importlib.import_module(module)


def available_backends() -> Tuple[str, ...]:
    """Sorted names of every registered stencil backend (lazy
    higher-layer backends are imported first so the list is total)."""
    _ensure_lazy_backends()
    return tuple(sorted(_REGISTRY))


def get_backend(spec: BackendLike):
    """Resolve a backend name or pass an instance through."""
    if isinstance(spec, str):
        if spec == "auto":
            raise ValueError(
                "'auto' needs field shape/dtype — use resolve_backend()")
        if spec not in _REGISTRY and spec in _LAZY_MODULES:
            _ensure_lazy_backends()
        try:
            return _REGISTRY[spec]
        except KeyError:
            raise ValueError(
                f"unknown stencil backend {spec!r}; "
                f"available: {available_backends()}") from None
    if not hasattr(spec, "fused_step"):
        raise TypeError(f"not a stencil backend: {spec!r}")
    return spec


def _auto_sharded(shape, dtype, mesh):
    """The 'sharded' backend bound to ``mesh`` when it (or the active
    ``with mesh:`` context) has >= 2 data-axis devices, else None."""
    be = get_backend("sharded")          # lazy-registers via _LAZY_MODULES
    if mesh is not None:
        be = be.with_mesh(mesh)
    else:
        try:
            be = be.bind()               # resolve the active mesh context
        except ValueError:
            return None
    if be.n_data_devices() < 2 or not be.supports(shape, dtype):
        return None
    return be


def resolve_backend(spec: BackendLike, shape: Tuple[int, ...], dtype,
                    mesh=None):
    """Like get_backend, but 'auto' picks the best supported backend —
    'sharded' when a mesh with >= 2 data-axis devices is given or active,
    else 'pallas', else 'reference'. An explicitly named backend raises on
    unsupported inputs instead of silently falling back; ``mesh`` is bound
    into a mesh-less sharded backend when provided."""
    if isinstance(spec, str) and spec == "auto":
        be = _auto_sharded(shape, dtype, mesh)
        if be is not None:
            return be
        be = _REGISTRY["pallas"]
        if be.supports(shape, dtype):
            return be
        return _REGISTRY["reference"]
    be = get_backend(spec)
    if mesh is not None and hasattr(be, "with_mesh") \
            and getattr(be, "mesh", None) is None:
        be = be.with_mesh(mesh)
    if not be.supports(shape, dtype):
        if hasattr(be, "bind"):
            be.bind()   # raises the 'needs a mesh' error when that is why
        raise ValueError(
            f"backend {be.name!r} does not support fields of shape {shape} "
            f"dtype {dtype}; use backend='auto' for automatic fallback")
    return be


register_backend(ReferenceBackend())
register_backend(PallasBackend())
# small fixed tile: exercises the halo-exchange path on modest fields
register_backend(PallasBackend(name="pallas_tiled", z_tile=8))
# worklist always on with small groups: exercises the dirty-slab loop
# (and its skip path) on modest fields
register_backend(PallasBackend(name="pallas_worklist", worklist=True,
                               worklist_group=4))
