"""repro.core — the paper's contribution: MSz, an edit-based parallel
algorithm preserving Morse-Smale segmentations through error-bounded lossy
compression (Li et al., 2024), reformulated for TPU/JAX."""
from .grid import (OFFSETS_2D, OFFSETS_3D, offsets_for, n_neighbors,
                   self_code, steepest_dirs, gather_dir, dir_to_pointer,
                   shift, linear_index)
from .labels import (mss_labels, pointer_jump, default_pointer_iters,
                     segmentation_accuracy, labels_from_codes)
from .backend import (StencilMasks, ReferenceBackend, PallasBackend,
                      register_backend, available_backends, get_backend,
                      resolve_backend)
from .fixes import (FieldTopo, field_topology, false_critical_masks,
                    trouble_masks, fused_pass, fused_fix, fused_fix_batch,
                    fused_fix_worklist, paper_fix)
from .driver import (MszResult, derive_edits, derive_edits_batch, apply_edits,
                     verify_preservation, verify_preservation_batch)

__all__ = [
    "OFFSETS_2D", "OFFSETS_3D", "offsets_for", "n_neighbors", "self_code",
    "steepest_dirs", "gather_dir", "dir_to_pointer", "shift", "linear_index",
    "mss_labels", "pointer_jump", "default_pointer_iters",
    "segmentation_accuracy", "labels_from_codes",
    "StencilMasks", "ReferenceBackend", "PallasBackend",
    "register_backend", "available_backends", "get_backend", "resolve_backend",
    "FieldTopo", "field_topology", "false_critical_masks", "trouble_masks",
    "fused_pass", "fused_fix", "fused_fix_batch", "fused_fix_worklist",
    "paper_fix",
    "MszResult", "derive_edits", "derive_edits_batch", "apply_edits",
    "verify_preservation", "verify_preservation_batch",
]
