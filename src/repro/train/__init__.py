"""repro.train — optimizer, loss, train-step factory."""
from .optimizer import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                        cosine_schedule, global_norm)
from .step import (TrainState, TrainStepConfig, cross_entropy, make_loss_fn,
                   make_train_step, init_train_state)

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm", "TrainState", "TrainStepConfig",
           "cross_entropy", "make_loss_fn", "make_train_step",
           "init_train_state"]
