"""Train-step factory: cross-entropy loss, microbatched gradient
accumulation (lax.scan, so DP all-reduce of microbatch k overlaps compute
of k+1 under XLA latency hiding), remat policy, optional compressed
cross-pod gradient sync (shard_map manual over 'pod', auto elsewhere)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.compression import compressed_psum_tree
from ..models import forward as model_forward
from ..models.config import ArchConfig
from ..models.sharding import MeshAxes
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    n_microbatches: int = 1
    remat: bool = True
    aux_loss_weight: float = 0.01
    grad_compress: bool = False     # compressed cross-pod all-reduce
    grad_compress_bound: float = 1e-3
    grad_compress_bits: int = 16
    n_pods: int = 1
    z_loss: float = 1e-4            # logit normalizer regularizer


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 0.0):
    """logits (B,S,V) f32, labels (B,S) int32; label -1 masks the position.
    Returns (mean_loss, n_tokens)."""
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    loss = jnp.sum(nll)
    if z_loss:
        loss = loss + z_loss * jnp.sum(jnp.square(lse) * mask)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    return loss / n, n


def chunked_cross_entropy(hidden: jnp.ndarray, unembed: jnp.ndarray,
                          labels: jnp.ndarray, *, softcap: Optional[float],
                          z_loss: float = 0.0, chunk: int = 512):
    """CE from final hidden states, scanning sequence chunks so the full
    (B,S,V) f32 logits tensor is never resident (it does not fit for the
    150k-vocab MoE archs at S=4k). Returns (mean_loss, n_tokens)."""
    from ..models import layers as _L
    B, S, _ = hidden.shape
    c = chunk
    while S % c:
        c -= 1
    nc = S // c
    hs = hidden.reshape(B, nc, c, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, c).transpose(1, 0, 2)

    def step(carry, inp):
        loss_acc, n_acc = carry
        h, lab = inp
        logits = jnp.einsum("bsd,dv->bsv", h, unembed,
                            preferred_element_type=jnp.float32)
        logits = _L.softcap(logits, softcap)
        mask = (lab >= 0).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
        loss = jnp.sum((lse - ll) * mask)
        if z_loss:
            loss = loss + z_loss * jnp.sum(jnp.square(lse) * mask)
        return (loss_acc + loss, n_acc + jnp.sum(mask)), None

    (loss, n), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32),
                                       jnp.zeros((), jnp.float32)), (hs, ls))
    n = jnp.maximum(n, 1.0)
    return loss / n, n


def make_loss_fn(cfg: ArchConfig, tcfg: TrainStepConfig) -> Callable:
    """Build the per-batch LM loss (z-loss + label smoothing per
    ``tcfg``; image-token positions excluded for VLM configs)."""
    def loss_fn(params, batch):
        out = model_forward(cfg, params, batch)
        logits = out.logits
        labels = batch["labels"]
        if cfg.n_img_tokens and "image_embeds" in batch:
            # image positions carry no LM loss: logits for them are dropped
            logits = logits[:, cfg.n_img_tokens:]
        loss, n = cross_entropy(logits, labels, tcfg.z_loss)
        total = loss + tcfg.aux_loss_weight * out.aux_loss
        return total, {"loss": loss, "aux_loss": out.aux_loss, "tokens": n}
    return loss_fn


def make_train_step(cfg: ArchConfig, tcfg: TrainStepConfig,
                    opt_cfg: AdamWConfig,
                    axes: Optional[MeshAxes] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    The returned function is jit-able; shard via pjit in/out shardings at
    the call site (see repro.launch). With tcfg.grad_compress, wrap with
    shard_map(axis_names={'pod'}) so the explicit quantized psum replaces
    the partitioner's f32 cross-pod all-reduce.
    """
    loss_fn = make_loss_fn(cfg, tcfg)
    if tcfg.remat:
        loss_fn = jax.checkpoint(
            loss_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.n_microbatches <= 1:
            (l, aux), g = grad_fn(params, batch)
            return g, aux

        def mb(carry, mbatch):
            gacc = carry
            (_, aux), g = grad_fn(params, mbatch)
            return jax.tree.map(jnp.add, gacc, g), aux

        def split(x):
            return x.reshape((tcfg.n_microbatches,
                              x.shape[0] // tcfg.n_microbatches)
                             + x.shape[1:])
        mbatches = jax.tree.map(split, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, auxs = jax.lax.scan(mb, zeros, mbatches)
        g = jax.tree.map(lambda x: x / tcfg.n_microbatches, gsum)
        aux = jax.tree.map(lambda a: a[-1], auxs)
        return g, aux

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if tcfg.grad_compress and tcfg.n_pods > 1:
            # manual over 'pod' (data/model stay auto): gradients are
            # pod-local partials, synced by the paper's error-bounded
            # quantizer — int codes psum exactly, bytes on the slow
            # cross-pod links drop 2x (int16) or 4x (int8) vs f32.
            def pod_region(params, batch_shard):
                grads, aux = compute_grads(params, batch_shard)
                grads = compressed_psum_tree(
                    grads, "pod", tcfg.grad_compress_bound,
                    tcfg.grad_compress_bits, n_shards=tcfg.n_pods)
                grads = jax.tree.map(lambda g: g / tcfg.n_pods, grads)
                aux = jax.tree.map(lambda a: jax.lax.pmean(a, "pod"), aux)
                return grads, aux

            grads, aux = jax.shard_map(
                pod_region,
                in_specs=(P(), P("pod")),
                out_specs=(P(), P()),
                axis_names={"pod"}, check_vma=False,
            )(state.params, batch)
        else:
            grads, aux = compute_grads(state.params, batch)
        params, opt, om = adamw_update(opt_cfg, state.opt, state.params, grads)
        metrics = {**aux, **om}
        return TrainState(params, opt), metrics

    return train_step


def init_train_state(cfg: ArchConfig, key) -> TrainState:
    """Fresh params + optimizer state for one architecture config."""
    from ..models import init_params
    params = init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params))
