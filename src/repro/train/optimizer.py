"""AdamW + schedules + global-norm clipping, from scratch (no optax).

Optimizer state is a pytree mirroring params (m, v in f32), shardable with
ZeRO-1 specs from repro.models.sharding (pass zero1=True to
tree_param_specs for the state pytrees)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # int32 scalar
    m: Any                     # f32 pytree like params
    v: Any                     # f32 pytree like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear-warmup + cosine-decay learning rate at ``step``."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr_peak * jnp.where(s < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> AdamWState:
    """Fresh AdamW state (f32 zero moments) for ``params``."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jnp.ndarray:
    """L2 norm over every leaf of ``tree`` (f32 accumulation)."""
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, state: AdamWState, params: Any,
                 grads: Any) -> Tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay (skip 1-D params: norms/biases)
        if p.ndim > 1:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
