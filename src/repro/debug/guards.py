"""Reusable runtime-sanitizer guards (DESIGN.md §10).

Two context managers back the repo's device-path contracts at runtime:

* ``no_transfers()`` — inside the block, any *implicit* host<->device
  transfer raises at the offending call site (``jax.transfer_guard``
  under the hood). The pipeline's audited crossings
  (``pipeline._h2d`` / ``pipeline._d2h``) use the explicit
  ``jax.device_put`` / ``jax.device_get`` APIs, which the guard
  deliberately permits — so the block asserts "every crossing is a
  tracked, audited one", the mechanical form of the ONE-h2d/ONE-d2h
  claim of DESIGN.md §4–§5. Untracked crossings the guard catches
  include host scalars handed straight to a jitted callable (one
  implicit h2d per dispatch) and device values scalarized mid-stage.

* ``no_recompiles()`` — inside the block, more than ``max_compiles``
  XLA compilations raise ``RecompileError`` (``jax.log_compiles``
  under the hood, counted via a logging handler). This is the loud
  version of the compile-cache discipline: a jit cache key that churns
  per call (the PR 7 calibration-cache bug class) re-traces silently
  and only shows up as a perf cliff; under the guard it fails.

``sanitizers_enabled()`` reads the ``MSZ_SANITIZERS`` environment knob
that the sanitizer tier-1 CI leg sets: production hot paths (the stream
scheduler's device stage) wrap themselves in ``no_transfers`` when it is
on, so the "zero host compute for device-pack batches" claim of
DESIGN.md §8 is asserted on every dispatch, not narrated.

Both guards are thread-local (jax config context managers), so a
guarded scheduler thread never constrains worker threads running host
entropy coding.
"""
from __future__ import annotations

import contextlib
import logging
import os
from typing import Iterator, List, Optional

ENV_VAR = "MSZ_SANITIZERS"

#: loggers that emit the compile/trace records ``jax.log_compiles``
#: enables; attaching to the package root catches both via propagation
_JAX_LOGGER = "jax"
#: one "Compiling <name> with global shapes..." record is emitted per
#: actual XLA compilation (re-traces that hit the lowering cache emit
#: only "Finished tracing" records and are not counted)
_COMPILE_PREFIX = "Compiling "


def sanitizers_enabled() -> bool:
    """Whether the ``MSZ_SANITIZERS`` environment knob is on (the
    sanitizer tier-1 CI leg sets ``MSZ_SANITIZERS=1``): hot paths that
    claim transfer discipline wrap themselves in ``no_transfers`` when
    it is, turning the claims into per-dispatch assertions."""
    env = os.environ.get(ENV_VAR, "").strip().lower()
    if env in ("", "0", "false", "no", "off"):
        return False
    if env in ("1", "true", "yes", "on"):
        return True
    raise ValueError(
        f"{ENV_VAR}={env!r} not understood; use one of 1/true/yes/on "
        "(sanitizers on) or 0/false/no/off (off)")


@contextlib.contextmanager
def no_transfers(*, h2d: bool = True, d2h: bool = True) -> Iterator[None]:
    """Assert that no *implicit* host<->device transfer happens inside
    the block: one raises ``jaxlib...XlaRuntimeError`` at the offending
    call site. Explicit transfers — ``jax.device_put`` /
    ``jax.device_get``, i.e. the pipeline's audited ``_h2d`` / ``_d2h``
    seams — stay permitted, so the device paths' ONE-h2d/ONE-d2h
    contract can be asserted while the contracted crossings still run.

    ``h2d=False`` / ``d2h=False`` narrow the guard to one direction.
    Device->device movement (the sharded backends re-shard committed
    inputs) is never guarded.

    Notes for test authors: run one warm-up call before entering the
    guard — compilation itself may transfer constants — and expect the
    guard to be strictest on non-CPU backends (on CPU, zero-copy
    host<->device aliasing means some conversions never hit the
    transfer machinery; implicit jit-argument transfers are caught on
    every backend). Combine with ``pipeline._transfer_hook`` counting
    for the exact ONE-each-way assertion.
    """
    import jax

    with contextlib.ExitStack() as stack:
        if h2d:
            stack.enter_context(jax.transfer_guard_host_to_device("disallow"))
        if d2h:
            stack.enter_context(jax.transfer_guard_device_to_host("disallow"))
        yield


def sanitize_transfers():
    """``no_transfers()`` when the ``MSZ_SANITIZERS`` knob is on, else a
    no-op context — the wrapper production device-stage code puts around
    its dispatch region so the sanitizer CI leg asserts the transfer
    contract on every batch without costing the default path anything."""
    if sanitizers_enabled():
        return no_transfers()
    return contextlib.nullcontext()


class RecompileError(RuntimeError):
    """Raised by ``no_recompiles`` when a block compiled more programs
    than its budget — the loud form of a jit cache-key regression."""


class _RecordList(logging.Handler):
    """Capture handler: appends every record's rendered message."""

    def __init__(self, sink: List[str]):
        super().__init__(level=logging.DEBUG)
        self._sink = sink

    def emit(self, record: logging.LogRecord) -> None:  # noqa: D102
        try:
            self._sink.append(record.getMessage())
        except Exception:       # noqa: BLE001 — a guard must never crash
            pass


@contextlib.contextmanager
def no_recompiles(max_compiles: int = 0, *,
                  label: Optional[str] = None) -> Iterator[List[str]]:
    """Assert that at most ``max_compiles`` XLA compilations happen
    inside the block (default: none), else raise ``RecompileError``
    naming every compiled program. Yields the live list of captured
    jax compile-log messages for callers that want to inspect it.

    Callers warm their jitted functions up *before* entering the block,
    then run the steady-state calls inside it — a stable cache key
    compiles nothing; a churning one (the PR 7
    ``calibrate.fused_fix_threshold`` interpret-policy bug class)
    re-compiles per call and fails here instead of silently re-tracing.

    If the block itself raises, that exception propagates unchanged
    (the compile budget is only checked on clean exit).
    """
    import jax

    messages: List[str] = []
    handler = _RecordList(messages)
    logger = logging.getLogger(_JAX_LOGGER)
    logger.addHandler(handler)
    try:
        with jax.log_compiles(True):
            yield messages
    finally:
        logger.removeHandler(handler)
    compiles = [m for m in messages if m.startswith(_COMPILE_PREFIX)]
    if len(compiles) > max_compiles:
        what = f" in {label}" if label else ""
        detail = "\n  ".join(compiles)
        raise RecompileError(
            f"{len(compiles)} XLA compilation(s){what} where at most "
            f"{max_compiles} were budgeted — a jit cache key is churning "
            f"(retrace per call). Compiled programs:\n  {detail}")
