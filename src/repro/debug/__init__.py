"""Runtime sanitizers (DESIGN.md §10).

The static half of the repo's contract enforcement lives in
``tools/mszlint``; this package holds the runtime half — context
managers that turn the device-path transfer discipline ("ONE h2d / ONE
d2h", DESIGN.md §4–§5) and the compile-cache discipline (stable jit
keys, DESIGN.md §7) from narrated claims into assertions that fail
loudly: ``no_transfers`` wraps ``jax.transfer_guard`` so an untracked
host<->device crossing raises at the offending call site, and
``no_recompiles`` wraps ``jax.log_compiles`` so a cache-key regression
(a silent per-call retrace) raises instead of just running slow.
"""
from .guards import (RecompileError, no_recompiles, no_transfers,
                     sanitize_transfers, sanitizers_enabled)

__all__ = ["no_transfers", "no_recompiles", "RecompileError",
           "sanitize_transfers", "sanitizers_enabled"]
