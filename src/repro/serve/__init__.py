"""repro.serve — serving layers: the request-batched topology-preserving
compression service (``repro.serve.compression``, DESIGN.md §6) and the
LM prefill/decode serving steps with KV & recurrent caches."""
from .step import make_serve_step, make_prefill, greedy_generate
from .compression import (CompressionService, ServiceConfig,
                          ServiceOverloaded, start_stats_server)

__all__ = ["make_serve_step", "make_prefill", "greedy_generate",
           "CompressionService", "ServiceConfig", "ServiceOverloaded",
           "start_stats_server"]
