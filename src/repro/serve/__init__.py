"""repro.serve — prefill/decode serving steps with KV & recurrent caches."""
from .step import make_serve_step, make_prefill, greedy_generate

__all__ = ["make_serve_step", "make_prefill", "greedy_generate"]
