"""Serving: prefill (populate caches from a prompt) and serve_step (one
batched decode step). serve_step is what the decode_* / long_* dry-run
shapes lower."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import decode_step, forward, init_decode_cache
from ..models.config import ArchConfig


def make_serve_step(cfg: ArchConfig) -> Callable:
    """serve_step(params, cache, tokens (B,1), t) -> (next_tokens, logits,
    cache). Greedy argmax sampling (temperature handled by caller)."""
    def serve_step(params, cache, tokens, t):
        logits, cache = decode_step(cfg, params, cache, tokens, t)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache
    return serve_step


def make_prefill(cfg: ArchConfig, max_len: int) -> Callable:
    """prefill(params, batch) -> (cache, last_logits). Populates KV caches
    (attention families) by running the full forward with return_cache and
    scattering per-layer K/V into the preallocated cache buffers."""
    def prefill(params, batch):
        out = forward(cfg, params, batch, return_cache=True)
        B = batch["tokens"].shape[0]
        cache = init_decode_cache(cfg, B, max_len)
        if cfg.family == "ssm":
            # recurrent prefill: replay through decode steps is O(S); for
            # the serving example we instead run forward then re-derive
            # states by a single scan pass (cache stays zeros here, states
            # are produced by decode-from-scratch in greedy_generate).
            return cache, out.logits[:, -1:]
        kv = out.cache.get("kv") if isinstance(out.cache, dict) else None
        if kv is not None and "k" in cache:
            k, v = kv                      # (L, B, S, Hk, Dh)
            S = k.shape[2]
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, 2)
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, 2)
        if cfg.enc_dec and isinstance(out.cache, dict):
            cache["enc_out"] = out.cache["enc_out"]
        return cache, out.logits[:, -1:]
    return prefill


def greedy_generate(cfg: ArchConfig, params, prompt: jnp.ndarray,
                    n_new: int, max_len: Optional[int] = None) -> jnp.ndarray:
    """Reference end-to-end generation loop (token-by-token from position 0
    — exercises only the decode path, so it works for every family)."""
    B, S0 = prompt.shape
    max_len = max_len or (S0 + n_new)
    cache = init_decode_cache(cfg, B, max_len)
    step = jax.jit(make_serve_step(cfg))
    toks = prompt
    cur = prompt[:, :1]
    out = []
    for t in range(S0 + n_new - 1):
        cur = toks[:, t:t + 1] if t < S0 else cur
        nxt, _, cache = step(params, cache, cur, jnp.int32(t))
        if t >= S0 - 1:
            out.append(nxt)
            cur = nxt
    return jnp.concatenate(out, axis=1) if out else prompt[:, :0]
