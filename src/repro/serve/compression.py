"""repro.serve.compression — the request-batched topology-preserving
compression service (DESIGN.md §6).

``CompressionService`` is the layer the ROADMAP's "serve heavy traffic"
north star asks for on top of the streaming scheduler
(``repro.compress.stream``): concurrent callers submit compress and
decompress requests with per-request error bounds (``xi``) and base
codec selection; the service coalesces same-shape/same-dtype requests
inside a bounded window into batched device dispatches, applies
backpressure when the window fills (block or reject, per config), and
exposes a stats surface — fields/sec, batch occupancy, transfer bytes,
cache hit rates — as a dict and, via ``start_stats_server``, as a
plain-HTTP JSON endpoint.

Requests are served by the same pipeline the one-shot API uses, so every
artifact and every decompressed field is byte-identical to a solo
``compress_preserving_mss`` / ``decompress_preserving_mss`` call; the
service only changes *when* work runs, never *what* it computes.

    service = CompressionService(ServiceConfig(window=16, max_batch=4))
    fut = service.submit_compress(field, xi=1e-3)
    art = fut.result()
    g = service.decompress(art)
    print(service.stats()["compress"]["fields_per_sec"])
    service.close()
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from concurrent.futures import Future
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from ..compress import pipeline
from ..compress.stream import (CompressStream, DecompressStream,
                               StreamBackpressure)
from ..core.backend import BackendLike

__all__ = ["ServiceConfig", "ServiceOverloaded", "CompressionService",
           "start_stats_server"]


class ServiceOverloaded(RuntimeError):
    """Raised by submit calls when the in-flight window is full and the
    service runs with ``overload="reject"`` (the HTTP-429 analogue);
    ``overload="block"`` applies backpressure by waiting instead."""


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Tuning knobs of one ``CompressionService``.

    ``window``
        In-flight request bound per direction (compress / decompress).
        This is the backpressure contract: at most ``window`` requests
        hold memory at once; producers beyond it block or get
        ``ServiceOverloaded`` (see ``overload``).
    ``max_batch``
        Dynamic-batching limit: up to this many same-(shape, dtype,
        codec) requests coalesce into one batched device dispatch.
    ``coalesce_ms``
        How long a sub-full batch lingers for stragglers before
        dispatching — the service's latency/occupancy trade-off.
    ``backend`` / ``mesh`` / ``device_path`` / ``max_iters``
        Forwarded to the pipeline (see ``compress_preserving_mss``);
        a mesh with >= 2 data-axis devices serves stream members
        slab-sharded across the device mesh.
    ``workers``
        Host worker threads per stream for entropy coding/decoding
        (default: scales with ``max_batch``). Device-pack requests
        (``entropy="device-pack"``) never touch these workers — their
        entropy streams are built on the device (DESIGN.md §8).
    ``cache_size``
        LRU capacity of each stream's dispatch-spec cache
        (``repro.compress.stream.SpecCache``).
    ``pad_pow2``
        Pad coalesced batches to power-of-two member counts so the
        vmapped dispatches specialize on ~log2(window) batch sizes.
    ``fix_batching``
        ``"fused"`` runs each batch's fix loops as one batched
        while_loop, ``"pipelined"`` as per-member solo loops behind a
        shared vmapped transform; ``"auto"`` fuses members up to a
        voxel threshold (see ``CompressStream``).
    ``fused_fix_voxels``
        The "auto" policy's voxel threshold. ``None`` (default) derives
        it from the one-shot machine calibration in
        ``repro.compress.calibrate`` (cached per backend/dtype/platform;
        ``MSZ_FUSED_FIX_VOXELS`` overrides); an explicit integer pins
        it. The per-batch decisions appear under ``fix_modes`` in
        ``stats()``.
    ``overload``
        ``"block"``: submits wait for a window slot (backpressure);
        ``"reject"``: submits raise ``ServiceOverloaded`` immediately.
    """
    window: int = 16
    max_batch: int = 4
    coalesce_ms: float = 2.0
    backend: BackendLike = "auto"
    mesh: Optional[object] = None
    device_path: pipeline.DevicePath = "auto"
    max_iters: int = 512
    workers: Optional[int] = None
    cache_size: int = 32
    pad_pow2: bool = True
    fix_batching: str = "auto"
    fused_fix_voxels: Optional[int] = None
    overload: str = "block"

    def __post_init__(self):
        if self.overload not in ("block", "reject"):
            raise ValueError(
                f'overload must be "block" or "reject", got {self.overload!r}')


class CompressionService:
    """Request queue + dynamic batching + backpressure around one
    ``CompressStream`` and one ``DecompressStream`` (DESIGN.md §6).

    Thread-safe: any number of producer threads may submit concurrently;
    results arrive on ``concurrent.futures.Future``s. Close with
    ``close()`` (or use as a context manager) to drain in-flight work.
    """

    def __init__(self, config: ServiceConfig = ServiceConfig()):
        self.config = config
        kw = dict(window=config.window, max_batch=config.max_batch,
                  linger_ms=config.coalesce_ms, backend=config.backend,
                  mesh=config.mesh, device_path=config.device_path,
                  max_iters=config.max_iters, workers=config.workers,
                  cache_size=config.cache_size, pad_pow2=config.pad_pow2,
                  fix_batching=config.fix_batching,
                  fused_fix_voxels=config.fused_fix_voxels)
        self._compress = CompressStream(**kw)
        self._decompress = DecompressStream(**kw)
        self._t_start = time.perf_counter()
        self._lock = threading.Lock()
        # one-shot interior/boundary timing probe, keyed on the probed
        # (shape, dtype, mesh) class; filled by shard_timings(), which
        # the stats endpoint may hit from concurrent server threads
        self._shard_probe: Optional[tuple] = None  # guarded-by: self._lock

    # -- submission ---------------------------------------------------
    def _guard(self, submit, *args, **kw) -> Future:
        try:
            return submit(*args, block=self.config.overload == "block", **kw)
        except StreamBackpressure as exc:
            raise ServiceOverloaded(
                f"service window full ({self.config.window} in-flight "
                "requests); retry later or configure overload='block'"
            ) from exc

    def submit_compress(self, field: np.ndarray, xi: float, *,
                        base: pipeline.BaseName = "szlike",
                        edit_value_dtype: str = "auto",
                        entropy: str = "deflate",
                        codec: Optional[str] = None) -> Future:
        """Queue a field; the Future resolves to its
        ``CompressedArtifact`` (byte-identical to the one-shot call).
        ``xi``, ``base``, and ``entropy`` ("deflate" | "device-pack",
        DESIGN.md §8) are free per request — only same-(shape, dtype,
        base, entropy) requests share a batch. ``codec`` is the
        pipeline's alias for ``base`` (any name registered through
        ``compress.preserve``; overrides ``base`` when given — non-szlike
        codecs batch through the host correction path, DESIGN.md §11).
        Device-pack batches do their residual entropy coding on the
        device, bypassing the host worker pool entirely; ``stats()``
        breaks traffic down per codec under ``entropy_codecs``."""
        if codec is not None:
            base = codec
        return self._guard(self._compress.submit, field, xi, base=base,
                           edit_value_dtype=edit_value_dtype,
                           entropy=entropy)

    def submit_decompress(self, art: pipeline.CompressedArtifact) -> Future:
        """Queue an artifact; the Future resolves to the decompressed
        field g with MSS(g) == MSS(f)."""
        return self._guard(self._decompress.submit, art)

    # -- sync conveniences --------------------------------------------
    def compress(self, field: np.ndarray, xi: float, *,
                 base: pipeline.BaseName = "szlike",
                 edit_value_dtype: str = "auto",
                 entropy: str = "deflate",
                 codec: Optional[str] = None
                 ) -> pipeline.CompressedArtifact:
        """Blocking ``submit_compress(...).result()``."""
        return self.submit_compress(
            field, xi, base=base, edit_value_dtype=edit_value_dtype,
            entropy=entropy, codec=codec).result()

    def decompress(self, art: pipeline.CompressedArtifact) -> np.ndarray:
        """Blocking ``submit_decompress(...).result()``."""
        return self.submit_decompress(art).result()

    # -- observability ------------------------------------------------
    def shard_timings(self, *, refresh: bool = False
                      ) -> Optional[Dict[str, object]]:
        """Measure one sharded fix iteration's interior pass, ghost
        exchange, and full step on the last sharded request class (the
        compute/communication-overlap surface of DESIGN.md §9). Runs a
        real timed probe (compiled, synthetic data of the recorded
        shape/dtype) the first time — and again only with ``refresh`` —
        then serves the cached result; None when no sharded dispatch has
        happened yet or no data mesh is reachable."""
        shard = self._compress.stats().get("shard") or {}
        meta = shard.get("last")
        if not meta:
            return None
        from ..distributed.shardfix import active_data_mesh, time_step_parts
        mesh = self.config.mesh
        if mesh is None:
            mesh = active_data_mesh()
        if mesh is None:
            return None
        shape = tuple(meta["shape"])
        key = (shape, meta["dtype"], tuple(mesh.axis_names),
               tuple(mesh.devices.shape))
        with self._lock:
            probe = self._shard_probe
        if probe is not None and not refresh and probe[0] == key:
            return probe[1]
        from ..core import field_topology
        rng = np.random.default_rng(0)
        f = rng.normal(size=shape).astype(meta["dtype"])
        topo = field_topology(jnp.asarray(f), 0.1)
        timings = time_step_parts(jnp.asarray(f), topo, mesh)
        doc = dict(shape=list(shape), dtype=meta["dtype"], **timings)
        with self._lock:
            self._shard_probe = (key, doc)
        return doc

    def stats(self) -> Dict[str, object]:
        """The service stats document (what the HTTP endpoint serves):
        uptime plus one ``repro.compress.stream`` counter snapshot per
        direction — fields/sec, batch occupancy, in-flight depth,
        transfer bytes, spec-cache hit/miss/eviction counts, the
        straggler policy's live coalescing scale, and per-mesh-axis
        halo-exchange bytes for sharded dispatches. ``shard_timings``
        carries the cached interior/boundary probe when one has run
        (``shard_timings()`` or ``GET /stats?probe=1`` triggers it)."""
        return dict(
            uptime_s=time.perf_counter() - self._t_start,
            config=dict(window=self.config.window,
                        max_batch=self.config.max_batch,
                        coalesce_ms=self.config.coalesce_ms,
                        overload=self.config.overload),
            compress=self._compress.stats(),
            decompress=self._decompress.stats(),
            shard_timings=self._shard_timings_snapshot(),
        )

    def _shard_timings_snapshot(self) -> Optional[Dict[str, object]]:
        with self._lock:
            return self._shard_probe[1] if self._shard_probe else None

    # -- lifecycle ----------------------------------------------------
    def flush(self) -> None:
        """Block until every in-flight request (both directions) has
        completed or failed."""
        self._compress.flush()
        self._decompress.flush()

    def close(self) -> None:
        """Drain in-flight work and stop both streams (idempotent)."""
        self._compress.close()
        self._decompress.close()

    def __enter__(self) -> "CompressionService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def start_stats_server(service: CompressionService, port: int = 0,
                       host: str = "127.0.0.1"):
    """Serve ``service.stats()`` as JSON over plain HTTP on a daemon
    thread: ``GET /stats`` returns the live stats document,
    ``GET /healthz`` returns ``ok``. Returns the running
    ``ThreadingHTTPServer`` (``.server_address`` carries the bound port
    when ``port=0``); call ``.shutdown()`` to stop it."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):              # noqa: N802 (http.server API)
            if self.path == "/healthz":
                body, ctype = b"ok\n", "text/plain"
            elif self.path.split("?")[0] in ("/", "/stats"):
                if "probe=1" in (self.path.split("?") + [""])[1]:
                    try:
                        service.shard_timings()
                    except Exception:   # noqa: BLE001 — stats must not 500
                        pass
                body = (json.dumps(service.stats(), indent=2) + "\n").encode()
                ctype = "application/json"
            else:
                self.send_error(404, "unknown path (try /stats)")
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):  # quiet: stats polls are chatty
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True,
                              name="compression-stats-http")
    thread.start()
    return server
