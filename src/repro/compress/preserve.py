"""Codec-agnostic topology correction: the ``PreservingCodec`` seam
(DESIGN.md §11).

The paper's central claim is that the MSz edit derivation needs nothing
from the base compressor beyond ``(f, xi) -> f_hat`` with
``max|f - f_hat| <= xi``: the fix loop, edit extraction, and the edit
codec never look inside the payload. This module makes that seam
explicit:

* ``PreservingCodec`` — the contract a base codec signs to become
  topology-preserving: a ``compress``/``decompress`` byte codec, its
  payload magics (first four blob bytes), and the magics of *retired*
  formats it must refuse rather than misdecode.
* a registry (``register_preserving_codec`` / ``get_preserving_codec``)
  holding the built-in ``szlike`` and ``zfplike`` codecs; the pipeline's
  ``compress_preserving_mss(codec=...)`` routes through it.
* magic negotiation (``payload_codec`` / ``check_artifact``): readers
  dispatch on the payload's leading magic, cross-checked against the
  artifact's recorded base, and REFUSE retired magics (``SZJ1``,
  ``ZFJ1``) with an explanation instead of silently reconstructing a
  different field.
* the generic host correction path (``compress_host`` /
  ``compress_host_batch``): base codec round-trip, the shared fix loop
  (``core.driver.derive_edits``), checked edit encoding, one artifact
  format — identical for every registered codec.
* edit-value dtype policy (``resolve_edit_dtype``): ``"auto"`` stores
  edit deltas in the field's own precision (f4 for f32 fields, f8 for
  f64) so edit application is exact per dtype; lossy choices (bf16, or
  f4 on an f64 field) re-verify preservation after decode and fall back
  to the exact dtype when rounding breaks it.

``CompressedArtifact`` lives here (artifact version 4 records
``base_magic``, the payload's leading magic, so readers can route
without touching the byte stream); ``compress.pipeline`` re-exports it
and adds the device-resident szlike paths on top.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.driver import (MszResult, apply_edits, derive_edits,
                           derive_edits_batch, verify_preservation)
from . import codec, szlike, zfplike

__all__ = [
    "ARTIFACT_VERSION", "CompressedArtifact", "PreservingCodec",
    "register_preserving_codec", "get_preserving_codec",
    "available_preserving_codecs", "payload_magic", "payload_codec",
    "check_artifact", "decode_payload", "resolve_edit_dtype",
    "exact_edit_dtype", "encode_edits_checked", "encode_edits_checked_dev",
    "compress_host", "compress_host_batch",
]

#: v4: ``base_magic`` records the payload's leading four bytes so the
#: read side can negotiate the base codec without sniffing the stream
ARTIFACT_VERSION = 4


@dataclasses.dataclass
class CompressedArtifact:
    """One MSS-preserving compression result: the base codec's payload
    plus the MSz edit blob, with the metadata both read paths need."""
    base: str
    base_payload: bytes
    edit_payload: bytes
    shape: tuple
    dtype: str
    xi: float
    # bookkeeping for the paper's metrics
    t_base: float = 0.0          # base compressor seconds (t_comp)
    t_fix: float = 0.0           # MSz fix seconds (t_fix)
    edit_ratio: float = 0.0
    fix_iters: int = 0
    backend: str = ""            # stencil backend that ran the fix loop
    # versioned header (v2): which path produced the artifact, and the
    # device base-transform time separated out of t_base (0.0 host-side)
    version: int = ARTIFACT_VERSION
    path: str = "host"           # "host" | "device"
    t_transform: float = 0.0     # device quantize+Lorenzo+reconstruct secs
    # v3: which residual entropy codec the base payload carries
    # (szlike.ENTROPIES; redundant with the blob magic but lets readers
    # route without touching the byte stream)
    entropy: str = "deflate"     # "deflate" | "device-pack"
    # v4: the payload's leading magic (ascii, e.g. "SZJ2"/"SZP1"/"ZFJ2")
    # — the read side's codec negotiation key, cross-checked against
    # ``base`` by ``check_artifact``
    base_magic: str = ""

    @property
    def nbytes(self) -> int:
        """Total compressed bytes: base payload + edit blob."""
        return len(self.base_payload) + len(self.edit_payload)


@dataclasses.dataclass(frozen=True)
class PreservingCodec:
    """The contract a base codec signs to be topology-corrected.

    ``compress(f, xi) -> payload`` must produce a self-describing blob
    whose ``decompress(payload)`` returns ``f_hat`` in the FIELD'S dtype
    with ``max|f - f_hat| <= xi`` (the fix loop's precondition; the
    derivation re-checks it and raises on violation). ``magics`` are the
    leading four bytes of every blob format the codec reads; ``refused``
    maps RETIRED magics to the reason they must not be decoded (the read
    side raises that message instead of misdecoding). Codecs whose
    transform the stencil backends also implement on device set
    ``device_transform`` so the pipeline can route them through the
    device-resident path.
    """
    name: str
    compress: Callable[..., bytes]
    decompress: Callable[[bytes], np.ndarray]
    magics: Tuple[bytes, ...]
    refused: Mapping[bytes, str] = dataclasses.field(default_factory=dict)
    device_transform: bool = False


_REGISTRY: Dict[str, PreservingCodec] = {}


def register_preserving_codec(pc: PreservingCodec) -> PreservingCodec:
    """Register ``pc`` under its name (later registrations win, so a
    test can shadow a built-in); returns ``pc`` for chaining."""
    if not pc.magics:
        raise ValueError(f"codec {pc.name!r} declares no payload magics")
    for m in tuple(pc.magics) + tuple(pc.refused):
        if len(m) != 4:
            raise ValueError(
                f"codec {pc.name!r}: payload magic {m!r} must be 4 bytes")
    _REGISTRY[pc.name] = pc
    return pc


def get_preserving_codec(name: str) -> PreservingCodec:
    """Look up a registered codec by name; raises KeyError with the
    available names otherwise."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown preserving codec {name!r}; registered: "
            f"{available_preserving_codecs()}") from None


def available_preserving_codecs() -> Tuple[str, ...]:
    """Names of the registered preserving codecs, sorted."""
    return tuple(sorted(_REGISTRY))


register_preserving_codec(PreservingCodec(
    name="szlike",
    compress=szlike.sz_compress,
    decompress=szlike.sz_decompress,
    magics=(b"SZJ2", b"SZP1"),
    refused={b"SZJ1": (
        "SZJ1 blobs predate the shared host/device dequantization "
        "contract (f64-multiply-then-cast) and would silently "
        "reconstruct a different f_hat; re-compress with the current "
        "codec")},
    device_transform=True,
))

register_preserving_codec(PreservingCodec(
    name="zfplike",
    compress=zfplike.zfp_compress,
    decompress=zfplike.zfp_decompress,
    magics=(b"ZFJ2",),
    refused={b"ZFJ1": (
        "ZFJ1 blobs record no field dtype and always decode to float32, "
        "so an f64 artifact would silently lose the precision its error "
        "bound was derived in; re-compress with the current codec")},
))


def payload_magic(payload: bytes) -> bytes:
    """The leading four bytes of a base payload (its format magic)."""
    if len(payload) < 4:
        raise ValueError(
            f"base payload too short for a magic: {len(payload)} bytes")
    return bytes(payload[:4])


def payload_codec(payload: bytes) -> PreservingCodec:
    """Negotiate the codec that reads ``payload`` from its magic.

    Retired magics raise the registering codec's refusal message (old
    blobs are REFUSED, never misdecoded); unknown magics raise with the
    full set of readable formats."""
    magic = payload_magic(payload)
    for pc in _REGISTRY.values():
        if magic in pc.magics:
            return pc
        if magic in pc.refused:
            raise ValueError(
                f"refusing retired {magic.decode('ascii', 'replace')!r} "
                f"payload: {pc.refused[magic]}")
    known = sorted(m.decode("ascii", "replace")
                   for pc in _REGISTRY.values() for m in pc.magics)
    raise ValueError(
        f"unknown base payload magic {magic!r}; readable formats: {known}")


def check_artifact(art: CompressedArtifact) -> PreservingCodec:
    """Cross-check ``art.base`` against the payload's actual magic and
    return the codec that reads it. A mismatch means the artifact
    metadata and its byte stream disagree — corruption or a mis-assembled
    artifact — and raises instead of trusting either side."""
    pc = get_preserving_codec(art.base)
    magic = payload_magic(art.base_payload)
    if magic not in pc.magics:
        sniffed = payload_codec(art.base_payload)   # raises on retired/unknown
        raise ValueError(
            f"artifact records base={art.base!r} but its payload magic "
            f"{magic!r} belongs to codec {sniffed.name!r}")
    return pc


def decode_payload(art: CompressedArtifact) -> np.ndarray:
    """Magic-negotiated base decode of an artifact: ``f_hat`` in the
    artifact's recorded dtype. Both built-in codecs record the dtype in
    the blob; a disagreement with the artifact metadata raises."""
    pc = check_artifact(art)
    f_hat = pc.decompress(art.base_payload)
    want = np.dtype(art.dtype)
    if f_hat.dtype != want:
        raise ValueError(
            f"artifact records dtype {art.dtype} but the {pc.name!r} "
            f"payload decodes to {f_hat.dtype}")
    return f_hat


# ---------------------------------------------------------------------------
# edit-value dtype policy + checked encoding (shared by every path)
# ---------------------------------------------------------------------------

#: edit-value storage dtypes the pipeline accepts ("auto" resolves to
#: the field's exact dtype; the rest name codec.encode_edits formats)
EDIT_VALUE_DTYPES = ("auto", "f4", "f8", "bf16")


def exact_edit_dtype(field_dtype) -> str:
    """The edit-value storage dtype that round-trips the field's deltas
    bit-exactly: "f8" for f64 fields, "f4" otherwise."""
    return "f8" if np.dtype(field_dtype) == np.float64 else "f4"


def resolve_edit_dtype(edit_value_dtype: str, field_dtype) -> str:
    """Resolve the pipeline's ``edit_value_dtype`` parameter for a field:
    "auto" becomes the field's exact dtype, explicit names pass through
    (unknown names raise)."""
    if edit_value_dtype not in EDIT_VALUE_DTYPES:
        raise ValueError(
            f"unknown edit_value_dtype {edit_value_dtype!r}; expected one "
            f"of {EDIT_VALUE_DTYPES}")
    if edit_value_dtype == "auto":
        return exact_edit_dtype(field_dtype)
    return edit_value_dtype


def encode_edits_checked(f: np.ndarray, f_hat: np.ndarray, res: MszResult,
                         xi: float, edit_value_dtype: str) -> bytes:
    """Edit codec with the lossy-storage safety net (beyond-paper): any
    edit dtype that cannot represent the field's deltas exactly (bf16,
    or f4 on an f64 field) must re-verify exactness and the error bound
    after a decode round-trip; fall back to the exact dtype when
    rounding breaks either."""
    evd = resolve_edit_dtype(edit_value_dtype, f.dtype)
    blob = codec.encode_edits(res.edits_idx, res.edits_val, evd)
    if evd != exact_edit_dtype(f.dtype):
        idx2, val2 = codec.decode_edits(blob)
        g2 = apply_edits(f_hat, idx2, val2)
        v = verify_preservation(f, g2, xi)
        if not (v["mss_preserved"] and v["bound_ok"]):
            blob = codec.encode_edits(res.edits_idx, res.edits_val,
                                      exact_edit_dtype(f.dtype))
    return blob


def encode_edits_checked_dev(fj: jnp.ndarray, f_hat: jnp.ndarray,
                             idx: np.ndarray, val: np.ndarray, xi: float,
                             edit_value_dtype: str) -> bytes:
    """Device-path twin of ``encode_edits_checked``: the re-verification
    of a lossy edit dtype runs on DEVICE arrays (f_hat never visits the
    host), with the same predicate — so both paths make the same
    fallback decision and stay bitwise identical."""
    evd = resolve_edit_dtype(edit_value_dtype, f_hat.dtype)
    blob = codec.encode_edits(idx, val, evd)
    if evd != exact_edit_dtype(f_hat.dtype):
        idx2, val2 = codec.decode_edits(blob)
        delta2 = (jnp.zeros(f_hat.size, f_hat.dtype).at[idx2].add(val2)
                  .reshape(f_hat.shape))
        v = verify_preservation(fj, f_hat + delta2, xi)
        if not (v["mss_preserved"] and v["bound_ok"]):
            blob = codec.encode_edits(idx, val,
                                      exact_edit_dtype(f_hat.dtype))
    return blob


# ---------------------------------------------------------------------------
# the generic host correction path (any registered codec)
# ---------------------------------------------------------------------------

def _make_artifact(f: np.ndarray, payload: bytes, blob: bytes, xi: float,
                   base: str, res: MszResult, t_base: float,
                   t_fix: float) -> CompressedArtifact:
    return CompressedArtifact(
        base=base, base_payload=payload, edit_payload=blob,
        shape=f.shape, dtype=str(f.dtype), xi=xi,
        t_base=t_base, t_fix=t_fix,
        edit_ratio=res.edit_ratio, fix_iters=res.iters,
        backend=res.backend,
        base_magic=payload_magic(payload).decode("ascii", "replace"),
    )


def compress_host(name: str, f: np.ndarray, xi: float, *,
                  compressor: Callable[..., bytes] = None,
                  mode: str = "fused", edit_value_dtype: str = "auto",
                  max_iters: int = 512, backend="auto",
                  mesh=None) -> CompressedArtifact:
    """The codec-agnostic host compression path: base round-trip through
    the registered codec ``name`` (or ``compressor``, a pre-bound
    variant of it — e.g. szlike with a non-default entropy codec), the
    shared fix loop (``core.driver.derive_edits``), checked edit
    encoding, one artifact format. Everything after the base round-trip
    is identical for every codec — the PreservingCodec seam."""
    pc = get_preserving_codec(name)
    f = np.asarray(f)
    comp = compressor if compressor is not None else pc.compress
    t0 = time.perf_counter()
    payload = comp(f, xi)
    f_hat = pc.decompress(payload)
    t1 = time.perf_counter()
    res = derive_edits(f, f_hat, xi, mode=mode, max_iters=max_iters,
                       backend=backend, mesh=mesh)
    if not res.converged:
        raise RuntimeError("MSz fix loops did not converge within max_iters")
    t2 = time.perf_counter()
    blob = encode_edits_checked(f, f_hat, res, xi, edit_value_dtype)
    return _make_artifact(f, payload, blob, xi, pc.name, res, t1 - t0,
                          t2 - t1)


def compress_host_batch(name: str, fields: List[np.ndarray],
                        xi_arr: np.ndarray, *,
                        compressor: Callable[..., bytes] = None,
                        edit_value_dtype: str = "auto",
                        max_iters: int = 512, backend="auto",
                        mesh=None) -> List[CompressedArtifact]:
    """Batch form of ``compress_host``: per-member base round-trips, then
    ONE batched fix loop over the stacked members
    (``core.driver.derive_edits_batch``) — the same machinery the szlike
    device batch rides, so zfplike batches share the vmapped fix loop
    even though their transform stays host-side. Per-member artifacts are
    bitwise identical to solo ``compress_host`` calls."""
    pc = get_preserving_codec(name)
    comp = compressor if compressor is not None else pc.compress
    payloads, fhats, t_bases = [], [], []
    for fi, xi_i in zip(fields, xi_arr):
        t0 = time.perf_counter()
        payload = comp(fi, float(xi_i))
        fhats.append(pc.decompress(payload))
        t_bases.append(time.perf_counter() - t0)
        payloads.append(payload)

    t0 = time.perf_counter()
    results = derive_edits_batch(np.stack(fields), np.stack(fhats), xi_arr,
                                 max_iters=max_iters, backend=backend,
                                 mesh=mesh)
    t_fix_each = (time.perf_counter() - t0) / max(len(fields), 1)

    arts = []
    for fi, xi_i, payload, f_hat, res, t_base in zip(
            fields, xi_arr, payloads, fhats, results, t_bases):
        if not res.converged:
            raise RuntimeError(
                "MSz fix loops did not converge within max_iters")
        blob = encode_edits_checked(fi, f_hat, res, float(xi_i),
                                    edit_value_dtype)
        arts.append(_make_artifact(fi, payload, blob, float(xi_i), pc.name,
                                   res, t_base, t_fix_each))
    return arts
