"""repro.compress — error-bounded lossy base compressors (the paper's
SZ3/ZFP baselines, reimplemented in JAX) plus the lossless edit codec of
Section 6.3 and the end-to-end MSz-corrected compression pipeline."""
from .szlike import sz_compress, sz_decompress, sz_roundtrip
from .zfplike import zfp_compress, zfp_decompress, zfp_roundtrip
from .codec import (encode_edits, decode_edits, lossless_bytes,
                    gzip_like, zstd_like)
from .pipeline import (CompressedArtifact, compress_preserving_mss,
                       compress_preserving_mss_batch, decompress_artifact,
                       overall_compression_ratio, overall_bit_rate, psnr)

__all__ = [
    "sz_compress", "sz_decompress", "sz_roundtrip",
    "zfp_compress", "zfp_decompress", "zfp_roundtrip",
    "encode_edits", "decode_edits", "lossless_bytes", "gzip_like", "zstd_like",
    "CompressedArtifact", "compress_preserving_mss",
    "compress_preserving_mss_batch", "decompress_artifact",
    "overall_compression_ratio", "overall_bit_rate", "psnr",
]
