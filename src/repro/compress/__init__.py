"""repro.compress — error-bounded lossy base compressors (the paper's
SZ3/ZFP baselines, reimplemented in JAX) plus the lossless edit codec of
Section 6.3 and the end-to-end MSz-corrected compression pipeline."""
from .szlike import (check_int32_range, effective_step, sz_compress,
                     sz_decompress, sz_inverse, sz_roundtrip, sz_transform)
from .zfplike import zfp_compress, zfp_decompress, zfp_roundtrip
from .codec import (encode_edits, decode_edits, decode_edits_batch,
                    lossless_bytes, gzip_like, zstd_like)
from .preserve import (PreservingCodec, register_preserving_codec,
                       get_preserving_codec, available_preserving_codecs,
                       payload_codec, payload_magic, check_artifact,
                       decode_payload, resolve_edit_dtype, exact_edit_dtype)
from .pipeline import (CompressedArtifact, compress_preserving_mss,
                       compress_preserving_mss_batch, decompress_artifact,
                       decompress_artifact_batch, decompress_preserving_mss,
                       overall_compression_ratio, overall_bit_rate, psnr)
from .stream import (CompressStream, DecompressStream, SpecCache,
                     StreamBackpressure, StreamClosed)

__all__ = [
    "CompressStream", "DecompressStream", "SpecCache",
    "StreamBackpressure", "StreamClosed",
    "sz_compress", "sz_decompress", "sz_roundtrip",
    "sz_transform", "sz_inverse", "check_int32_range", "effective_step",
    "zfp_compress", "zfp_decompress", "zfp_roundtrip",
    "encode_edits", "decode_edits", "decode_edits_batch",
    "lossless_bytes", "gzip_like", "zstd_like",
    "PreservingCodec", "register_preserving_codec", "get_preserving_codec",
    "available_preserving_codecs", "payload_codec", "payload_magic",
    "check_artifact", "decode_payload", "resolve_edit_dtype",
    "exact_edit_dtype",
    "CompressedArtifact", "compress_preserving_mss",
    "compress_preserving_mss_batch", "decompress_artifact",
    "decompress_artifact_batch", "decompress_preserving_mss",
    "overall_compression_ratio", "overall_bit_rate", "psnr",
]
