"""Lossless compression of MSz edits (paper Section 6.3).

Each edit is a (vertex index, float value) pair. Indices are sorted
ascending and delta-encoded (the paper's observation: edits form
'sparsely distributed yet continuous patches', so deltas are tiny and
RLE/varint-friendly), varint-packed, then DEFLATE'd. Values are stored as
f32, f64 (the exact dtype for f64 fields), or bf16 (the bound-tight
beyond-paper mode) and DEFLATE'd separately. DEFLATE = LZ77 + Huffman, i.e. the paper's Huffman+GZIP stage.
"""
from __future__ import annotations

import struct
import zlib
from typing import Optional, Tuple

import numpy as np

_MAGIC = b"MSE1"


def _varint_encode(a: np.ndarray) -> bytes:
    """LEB128 varint pack of a non-negative int64 array (vectorized)."""
    if a.size == 0:
        return b""
    a = a.astype(np.uint64)
    # max 10 bytes each; build columns of 7-bit groups
    cols = []
    rest = a.copy()
    more = np.ones(a.shape, bool)
    out_bytes = []
    while more.any():
        b7 = (rest & np.uint64(0x7F)).astype(np.uint8)
        rest = rest >> np.uint64(7)
        cont = (rest != 0) & more
        byte = np.where(cont, b7 | np.uint8(0x80), b7)
        out_bytes.append((byte, more.copy()))
        more = cont
    # interleave per-element in order
    n = a.size
    parts = []
    arr = np.zeros((len(out_bytes), n), np.uint8)
    mask = np.zeros((len(out_bytes), n), bool)
    for i, (byte, m) in enumerate(out_bytes):
        arr[i] = byte
        mask[i] = m
    flat = arr.T[mask.T]  # bytes of element 0, element 1, ... in order
    return flat.tobytes()


def _varint_decode(buf: bytes, count: int) -> np.ndarray:
    """Vectorized LEB128 decode (numpy scan — the former per-byte Python
    loop cost O(stream bytes) interpreter time, seconds on million-edit
    blobs). Value boundaries come from the continuation bits; each byte's
    7-bit group is shifted by 7x its position within its value and the
    groups are summed per value with one ``np.add.reduceat``.

    The stream must hold EXACTLY ``count`` values: a short stream is
    truncation, and trailing bytes beyond value ``count`` mean the
    caller's framing disagrees with the payload — both are corruption,
    and both raise instead of decoding what happens to fit (the old
    behavior, which let a mis-framed blob decode to plausible-looking
    indices)."""
    if count == 0:
        if len(buf):
            raise ValueError(
                f"varint stream carries {len(buf)} bytes but 0 values "
                "were promised")
        return np.zeros(0, np.int64)
    data = np.frombuffer(buf, np.uint8)
    ends = np.flatnonzero((data & 0x80) == 0)      # last byte of each value
    if ends.size < count:
        raise ValueError(
            f"truncated varint stream: {ends.size} terminated values, "
            f"expected {count}")
    if ends.size > count or int(ends[-1]) != data.size - 1:
        raise ValueError(
            f"over-long varint stream: {ends.size} terminated values and "
            f"{data.size - 1 - int(ends[-1])} dangling bytes, expected "
            f"exactly {count} values")
    starts = np.empty(count, np.int64)
    starts[0] = 0
    starts[1:] = ends[:-1] + 1
    n_bytes = int(ends[-1]) + 1
    data = data[:n_bytes]
    owner = np.zeros(n_bytes, np.int64)                 # value of each byte
    owner[1:] = np.cumsum((data[:-1] & 0x80) == 0)      # exclusive end scan
    pos = (np.arange(n_bytes) - starts[owner]).astype(np.uint64)
    contrib = (data & np.uint8(0x7F)).astype(np.uint64) << (np.uint64(7) * pos)
    return np.add.reduceat(contrib, starts).astype(np.int64)


def _f32_to_bf16(val: np.ndarray) -> np.ndarray:
    """f32 -> bf16 (top 16 bits) with IEEE round-to-nearest-even.

    The former ``(v32 + 0x8000) >> 16`` rounded halfway cases away from
    zero (a systematic up-bias on tie points like 1.0 + 2^-8), promoted
    NaNs with small payloads to Inf (the +0x8000 carry rippled into the
    exponent), and wrapped sign-bit-set NaNs to +0 via uint32 overflow.
    RNE adds ``0x7FFF + lsb-of-result`` instead (carry in uint64 so it
    cannot wrap), and non-finite values bypass rounding entirely: Inf
    truncates to Inf, NaN truncates with the quiet bit forced so a
    payload living only in the dropped low mantissa bits cannot decay
    to Inf."""
    v32 = val.view(np.uint32).astype(np.uint64)
    bias = np.uint64(0x7FFF) + ((v32 >> np.uint64(16)) & np.uint64(1))
    rounded = ((v32 + bias) >> np.uint64(16)).astype(np.uint16)
    top = (v32 >> np.uint64(16)).astype(np.uint16)
    special = (v32 & np.uint64(0x7F800000)) == np.uint64(0x7F800000)
    is_nan = special & ((v32 & np.uint64(0x007FFFFF)) != 0)
    return np.where(special,
                    np.where(is_nan, top | np.uint16(0x0040), top),
                    rounded)


def encode_edits(idx: np.ndarray, val: np.ndarray, value_dtype="f4") -> bytes:
    """Pack sorted edit indices + values. value_dtype: 'f4', 'f8', or
    'bf16' ('f8' stores full f64 deltas — the exact dtype for f64
    fields, where an f32-rounded delta could perturb a tie-break).

    Unsorted indices are sorted (order carries no information); DUPLICATE
    indices are a hard error. One vertex never receives two edits — the
    fix loop produces one delta per vertex — so a duplicate means the
    caller's edit extraction is broken, and the delta coding + the
    decompression scatter would otherwise mask it (re-sorting used to
    swallow duplicates silently; ``apply_edits`` would then drop or
    double-apply them depending on the path)."""
    if value_dtype not in ("f4", "f8", "bf16"):
        raise ValueError(
            f"unknown edit value_dtype {value_dtype!r}; expected "
            "'f4', 'f8', or 'bf16'")
    idx = np.asarray(idx, np.int64)
    val = np.asarray(val, np.float64 if value_dtype == "f8" else np.float32)
    if idx.size != val.size:
        raise ValueError("idx/val length mismatch")
    if idx.size and np.any(np.diff(idx) <= 0):
        order = np.argsort(idx, kind="stable")
        idx, val = idx[order], val[order]
        if np.any(np.diff(idx) == 0):
            dup = int(idx[np.flatnonzero(np.diff(idx) == 0)[0]])
            raise ValueError(
                f"duplicate edit index {dup}: edits must target each vertex "
                "at most once (broken upstream edit extraction?)")
    deltas = np.diff(idx, prepend=np.int64(0))
    key_stream = zlib.compress(_varint_encode(deltas), 9)
    if value_dtype == "bf16":
        vb = _f32_to_bf16(val)
        val_stream = zlib.compress(vb.tobytes(), 9)
        dt = 1
    elif value_dtype == "f8":
        val_stream = zlib.compress(val.tobytes(), 9)
        dt = 2
    else:
        val_stream = zlib.compress(val.tobytes(), 9)
        dt = 0
    hdr = struct.pack("<4sBQQQ", _MAGIC, dt, idx.size,
                      len(key_stream), len(val_stream))
    return hdr + key_stream + val_stream


def decode_edits(blob: bytes) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of ``encode_edits``: (sorted int64 indices, values) of
    one edit blob — f32 values for the 'f4'/'bf16' codings (bf16 widens
    back to f32), f64 for 'f8'.

    The header's stream lengths are validated against ``len(blob)``
    before any slice: Python slicing silently clips, so a truncated
    blob used to flow into ``zlib.decompress`` (surfacing, at best, as
    a confusing zlib error — or decoding a prefix that happens to be
    well-formed), and trailing garbage after the promised streams was
    silently ignored. Both now raise ``ValueError`` here."""
    hdr = struct.calcsize("<4sBQQQ")
    if len(blob) < hdr:
        raise ValueError(
            f"truncated edit blob: {len(blob)} bytes, header needs {hdr}")
    magic, dt, n, lk, lv = struct.unpack_from("<4sBQQQ", blob, 0)
    if magic != _MAGIC:
        raise ValueError("not an MSz edit blob")
    if len(blob) != hdr + lk + lv:
        raise ValueError(
            f"edit blob length mismatch: header promises {hdr + lk + lv} "
            f"bytes ({lk} key + {lv} value), got {len(blob)}")
    off = hdr
    keys = zlib.decompress(blob[off:off + lk]); off += lk
    vals = zlib.decompress(blob[off:off + lv])
    deltas = _varint_decode(keys, n)
    idx = np.cumsum(deltas, dtype=np.int64)
    if dt == 1:
        if len(vals) != 2 * n:
            raise ValueError(
                f"edit value stream decodes to {len(vals)} bytes, "
                f"expected {2 * n} (bf16 x {n})")
        v16 = np.frombuffer(vals, np.uint16).astype(np.uint32) << 16
        val = v16.view(np.float32)
    elif dt == 2:
        if len(vals) != 8 * n:
            raise ValueError(
                f"edit value stream decodes to {len(vals)} bytes, "
                f"expected {8 * n} (f64 x {n})")
        val = np.frombuffer(vals, np.float64)
    elif dt == 0:
        if len(vals) != 4 * n:
            raise ValueError(
                f"edit value stream decodes to {len(vals)} bytes, "
                f"expected {4 * n} (f32 x {n})")
        val = np.frombuffer(vals, np.float32)
    else:
        raise ValueError(f"unknown edit value dtype code {dt}")
    return idx, val.copy()


def iter_decode_blobs(decode, blobs, max_workers: Optional[int] = None,
                      window: Optional[int] = None):
    """Lazily yield ``decode(blob)`` results in blob order from a thread
    pool.

    DEFLATE decompression (and the numpy post-processing around it)
    releases the GIL, so worker threads scale the host-side decode of a
    batch across cores while the consumer processes already-decoded
    members — the batched read path overlaps entropy decode with device
    dispatch this way. At most ``window`` (default 2x workers) decodes
    are in flight or undelivered, so resident memory stays O(window)
    decoded blobs however large the batch. Single-element (or empty)
    batches skip the pool."""
    n = len(blobs)
    if n <= 1:
        for b in blobs:
            yield decode(b)
        return
    import os
    from collections import deque
    from concurrent.futures import ThreadPoolExecutor
    workers = max_workers or min(n, os.cpu_count() or 1)
    window = window or 2 * workers
    with ThreadPoolExecutor(max_workers=workers) as ex:
        pending = deque()
        i = 0
        while i < n or pending:
            while i < n and len(pending) < window:
                pending.append(ex.submit(decode, blobs[i]))
                i += 1
            yield pending.popleft().result()


def decode_blobs_parallel(decode, blobs, max_workers: Optional[int] = None):
    """Eager form of ``iter_decode_blobs``: the full result list."""
    return list(iter_decode_blobs(decode, blobs, max_workers))


def decode_edits_batch(blobs, fill_idx: Optional[int] = None):
    """Stream-decode many edit blobs in one call.

    With ``fill_idx=None`` returns the list of per-blob ``(idx, val)``
    pairs. With ``fill_idx`` set (the field size, one past the last valid
    flat index) returns the dense layout the batched device scatter
    consumes: ``(idx_b, val_b, counts)`` where ``idx_b``/``val_b`` are
    (B, L) arrays padded to the longest member — indices with
    ``fill_idx`` (out-of-range, dropped by the scatter's OOB semantics)
    and values with 0 — and ``counts`` holds each member's true edit
    count. Padding keeps every row sorted ascending.
    """
    pairs = decode_blobs_parallel(decode_edits, blobs)
    if fill_idx is None:
        return pairs
    B = len(pairs)
    L = max((i.size for i, _ in pairs), default=0)
    idx_b = np.full((B, L), np.int64(fill_idx), np.int64)
    # widest member value dtype wins (f8-coded blobs promote the batch
    # to f64; the scatter casts to the field dtype member-wise)
    vdt = np.result_type(np.float32, *(v.dtype for _, v in pairs)) \
        if pairs else np.dtype(np.float32)
    val_b = np.zeros((B, L), vdt)
    counts = np.zeros(B, np.int64)
    for i, (idx, val) in enumerate(pairs):
        idx_b[i, :idx.size] = idx
        val_b[i, :idx.size] = val
        counts[i] = idx.size
    return idx_b, val_b, counts


# --- lossless baselines (Table 2's GZIP / ZSTD columns) --------------------

def gzip_like(data: np.ndarray) -> int:
    """DEFLATE level 6 ~ gzip default; returns compressed size in bytes."""
    return len(zlib.compress(np.asarray(data).tobytes(), 6))


def zstd_like(data: np.ndarray) -> int:
    """Stronger LZ backend as the ZSTD stand-in (lzma preset 1: fast-ish,
    better matches zstd's ratio than DEFLATE)."""
    import lzma
    return len(lzma.compress(np.asarray(data).tobytes(), preset=1))


def lossless_bytes(data: np.ndarray, codec: str = "gzip") -> int:
    """Compressed byte size of ``data`` under the named lossless
    baseline codec (Table 2's GZIP / ZSTD columns)."""
    return gzip_like(data) if codec == "gzip" else zstd_like(data)
