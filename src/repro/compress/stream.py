"""Streaming topology-preserving compression (DESIGN.md §6).

The one-shot pipeline (``compress_preserving_mss`` and friends) serves a
single call at a time: the caller pays base transform, fix loop, and
entropy coding sequentially per field. In the streaming settings pMSz
targets — timestep series and ensemble members arriving continuously —
that serialization wastes both the device (idle while zlib runs) and the
host (idle while the fix loop runs). This module overlaps the three:

* ``CompressStream`` / ``DecompressStream`` — double-buffered async
  schedulers over a bounded window of in-flight fields. A scheduler
  thread owns the DEVICE stage (one batched transform + fix-loop + edit
  extraction dispatch per coalesced batch, ``pipeline._device_batch_stage``);
  host entropy coding of batch *k* runs on worker threads while the
  scheduler is already dispatching batch *k+1*'s device stage, and jax's
  async dispatch overlaps the h2d/d2h transfers with both.
* **dynamic batching** — same-spec requests (shape, dtype, base codec;
  ``xi`` is free per request) queued at dispatch time coalesce into ONE
  ``*_batch`` call, padded to a power-of-two member count so the vmapped
  fix loop specializes on ~log2(window) batch sizes instead of one per
  occupancy (the PR-4 pad-to-pow2 trick applied to the batch axis).
  Mixed-spec traffic batches separately; ``strict_uniform=True`` rejects
  it at submit instead. Whether a batch's fix loops then run fused
  (one batched while_loop with active-member compaction) or pipelined
  (per-member solo loops) is decided by a measured per-machine voxel
  threshold (``compress.calibrate``), not a hardcoded size cutoff; the
  decision taken per batch is visible in ``stats()['fix_modes']``.
* **backpressure** — ``window`` bounds in-flight requests; ``submit``
  blocks (or raises ``StreamBackpressure`` with ``block=False``) until a
  slot frees, so memory stays O(window · field) however fast producers
  run.
* ``SpecCache`` — an LRU of dispatch specializations keyed by
  ``(shape, dtype, xi, backend)``. Values hold the resolved, mesh-bound
  stencil backend, so every batch of a cached spec reuses ONE backend
  instance and jit's compilation cache keys stay stable (jax owns the
  compiled code itself; this cache bounds and *observes* the dispatch
  specs — hits/misses/evictions feed the service stats).

Every artifact (and decompressed field) is byte-identical to its
one-shot ``compress_preserving_mss`` / ``decompress_preserving_mss``
counterpart: the stream reorders and overlaps work, never changes it.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from ..core import fixes
from ..core.backend import BackendLike, resolve_backend
from ..debug import sanitize_transfers
from ..distributed.straggler import StepWatchdog
from . import calibrate, pipeline, szlike

#: mesh axis names the sharded backend decomposes fields over — the
#: stream's compile-cache key and shard stats group by these (kept in
#: sync with distributed.shardfix.ALL_DATA_AXES without importing the
#: heavier module at stream-import time).
_DATA_AXIS_NAMES = ("data", "data_z", "data_y", "data_x")


class StreamBackpressure(RuntimeError):
    """Raised by a non-blocking ``submit`` when the in-flight window is
    full (the stream's bounded-memory contract; block=True waits
    instead)."""


class StreamClosed(RuntimeError):
    """Raised by ``submit`` after ``close()`` — a closed stream drains
    its in-flight work but accepts no new requests."""


# ---------------------------------------------------------------------------
# specialization cache
# ---------------------------------------------------------------------------

class SpecCache:
    """LRU cache of dispatch specializations, keyed by
    ``(shape, dtype, xi, backend)`` (plus the mesh width when sharded).

    The cached value is the resolved, mesh-bound stencil backend for that
    request class. Reusing one bound instance per spec keeps
    ``jax.jit``'s static-argument cache keys stable across batches (a
    fresh ``bind()`` per call would be a new hashable every time) and
    gives the stream an observable cache surface: ``hits`` / ``misses``
    / ``evictions`` counters feed the service stats endpoint. Thread-safe.

    Note the xi component: the cached backend itself is xi-independent,
    so traffic that varies xi per request creates one (cheap-to-rebuild)
    entry per distinct bound — the key deliberately identifies the full
    request class the stats observe, trading some LRU churn under
    many-bound traffic for a cache population that mirrors the workload.
    """

    def __init__(self, maxsize: int = 32):
        if maxsize < 1:
            raise ValueError(f"cache maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        # guarded-by: self._lock
        self._data: "collections.OrderedDict[Hashable, object]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0                        # guarded-by: self._lock
        self.misses = 0                      # guarded-by: self._lock
        self.evictions = 0                   # guarded-by: self._lock

    def get(self, key: Hashable, build: Callable[[], object]) -> object:
        """The cached value for ``key``, building (and possibly evicting
        the least-recently-used entry) on a miss.

        Concurrent misses of one key both ``build()`` (the lock is
        released around the build, which may trace/compile), but exactly
        ONE winner's instance is kept and returned to every racer — a
        loser inserting its own copy would hand callers two distinct
        backend instances for one spec and silently churn jit's
        static-argument cache keys. The losing thread's call is
        reclassified as a hit (it returns the cached winner)."""
        with self._lock:
            if key in self._data:
                self.hits += 1
                self._data.move_to_end(key)
                return self._data[key]
            self.misses += 1
        value = build()          # outside the lock: build may trace/compile
        with self._lock:
            if key in self._data:        # lost a build race: keep the winner
                self.hits += 1
                self.misses -= 1
                self._data.move_to_end(key)
                return self._data[key]
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)
                self.evictions += 1
        return value

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def stats(self) -> Dict[str, int]:
        """Counter snapshot: hits, misses, evictions, size, maxsize."""
        with self._lock:
            return dict(hits=self.hits, misses=self.misses,
                        evictions=self.evictions, size=len(self._data),
                        maxsize=self.maxsize)


@dataclasses.dataclass
class _Request:
    """One queued stream request: the payload, its coalescing spec, and
    the Future the caller holds."""
    item: object
    spec: Tuple
    xi: float
    future: Future
    t_submit: float


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

class _StreamBase:
    """Shared scheduler machinery of ``CompressStream`` and
    ``DecompressStream``: the bounded window, the coalescing queue, the
    worker pool, and the stats. Subclasses implement ``_dispatch`` (one
    coalesced same-spec batch) and ``_spec_of`` (the coalescing key)."""

    def __init__(self, *, window: int = 8, max_batch: int = 4,
                 linger_ms: float = 2.0,
                 backend: BackendLike = "auto", mesh=None,
                 device_path: pipeline.DevicePath = "auto",
                 max_iters: int = 512,
                 workers: Optional[int] = None,
                 strict_uniform: bool = False,
                 pad_pow2: bool = True,
                 fix_batching: str = "auto",
                 fused_fix_voxels: Optional[int] = None,
                 cache_size: int = 32,
                 start: bool = True):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if fix_batching not in ("auto", "fused", "pipelined"):
            raise ValueError(
                'fix_batching must be "auto", "fused", or "pipelined"; '
                f"got {fix_batching!r}")
        self.window = window
        self.max_batch = max_batch
        self.linger_s = max(linger_ms, 0.0) / 1e3
        self._backend = backend
        self._mesh = mesh
        self._device_path = device_path
        self._max_iters = max_iters
        self._strict = strict_uniform
        self._pad_pow2 = pad_pow2
        self._fix_batching = fix_batching
        # None => derive the fused-vs-pipelined threshold from the
        # one-shot machine calibration (compress.calibrate) on first use
        self._fused_fix_voxels = fused_fix_voxels
        self._fix_mode_counts: Dict[str, int] = {}
        self._codec_stats: Dict[str, List[int]] = {}   # name -> [count, bytes]
        self.cache = SpecCache(cache_size)

        # straggler policy (DESIGN.md §9): the dormant StepWatchdog is
        # folded into the scheduler — a batch whose device time blows
        # past the EWMA deadline widens the coalescing window (x2 per
        # flag, capped) instead of stalling the service, so a slow
        # shard amortizes its next dispatch over more members; healthy
        # batches decay the scale back toward 1
        self._watchdog = StepWatchdog()
        self._linger_scale = 1.0
        self._linger_scale_max = 8.0
        self._watchdog_verdicts: Dict[str, int] = {}

        # sharded-dispatch accounting: per-mesh-axis halo bytes moved by
        # the fix loops (analytic halo_plan x observed iteration counts)
        self._halo_bytes: Dict[str, int] = {}
        self._halo_iters = 0
        self._shard_meta: Optional[Dict[str, object]] = None

        self._slots = threading.Semaphore(window)
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)   # scheduler wake-ups
        self._done = threading.Condition(self._lock)   # flush() wake-ups
        # guarded-by: self._lock
        self._pending: "collections.deque[_Request]" = collections.deque()
        self._closed = False                 # guarded-by: self._lock
        self._spec0: Optional[Tuple] = None  # guarded-by: self._lock

        # stats counters, each # guarded-by: self._lock (mszlint verifies
        # every write below sits inside the critical section — PR 7 race)
        self._submitted = 0                  # guarded-by: self._lock
        self._completed = 0                  # guarded-by: self._lock
        self._failed = 0                     # guarded-by: self._lock
        self._in_flight = 0                  # guarded-by: self._lock
        self._max_in_flight = 0              # guarded-by: self._lock
        self._batches = 0                    # guarded-by: self._lock
        self._members_real = 0               # guarded-by: self._lock
        self._members_padded = 0             # guarded-by: self._lock
        self._nbytes_h2d = 0                 # guarded-by: self._lock
        self._nbytes_d2h = 0                 # guarded-by: self._lock
        self._t_device = 0.0                 # guarded-by: self._lock
        self._t_encode = 0.0                 # guarded-by: self._lock
        # guarded-by: self._lock
        self._t_first_submit: Optional[float] = None
        # guarded-by: self._lock
        self._t_last_done: Optional[float] = None

        self._pool = ThreadPoolExecutor(
            max_workers=workers or max(2, min(8, max_batch)),
            thread_name_prefix=type(self).__name__ + "-worker")
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=type(self).__name__)
        self._started = False
        if start:
            self.start()

    # -- lifecycle ----------------------------------------------------
    def start(self) -> None:
        """Start the scheduler thread (idempotent; ``start=False``
        constructors queue requests without draining until called)."""
        if not self._started:
            self._started = True
            self._thread.start()

    def close(self) -> None:
        """Drain every in-flight request, then stop the scheduler and
        worker pool — no Future is ever abandoned (a never-started
        stream is started so its queue drains too). Safe to call twice;
        submits afterwards raise ``StreamClosed``."""
        with self._lock:
            self._closed = True
            self._wake.notify_all()
        self.start()        # a start=False stream still owes its queue
        self._thread.join()
        self._pool.shutdown(wait=True)
        with self._lock:
            self._t_last_done = self._t_last_done or time.perf_counter()

    def __enter__(self) -> "_StreamBase":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ---------------------------------------------------
    def _submit(self, item, xi: float, spec: Tuple, *, block: bool = True,
                timeout: Optional[float] = None) -> Future:
        if self._closed:
            raise StreamClosed("stream is closed")
        if self._strict:
            with self._lock:
                if self._spec0 is None:
                    self._spec0 = spec
                elif spec != self._spec0:
                    raise ValueError(
                        f"strict_uniform stream pinned to spec {self._spec0}; "
                        f"got {spec} (submit to a second stream, or drop "
                        "strict_uniform to batch mixed specs separately)")
        if block:
            ok = self._slots.acquire() if timeout is None \
                else self._slots.acquire(timeout=timeout)
        else:
            ok = self._slots.acquire(blocking=False)
        if not ok:
            raise StreamBackpressure(
                f"in-flight window full ({self.window} requests); "
                "block=True waits for a slot instead")
        fut: Future = Future()
        req = _Request(item=item, spec=spec, xi=xi, future=fut,
                       t_submit=time.perf_counter())
        with self._lock:
            if self._closed:           # closed while we held the slot
                self._slots.release()
                raise StreamClosed("stream is closed")
            self._submitted += 1
            self._in_flight += 1
            self._max_in_flight = max(self._max_in_flight, self._in_flight)
            if self._t_first_submit is None:
                self._t_first_submit = req.t_submit
            self._pending.append(req)
            self._wake.notify()
        return fut

    def flush(self) -> None:
        """Block until every submitted request has completed or failed."""
        with self._lock:
            while self._in_flight > 0:
                self._done.wait()

    # -- completion bookkeeping --------------------------------------
    def _finish(self, req: _Request, result=None, exc=None) -> None:
        # counters first (a caller woken by set_result must see them
        # settled), then the result, then the flush()/slot wake-ups —
        # so fut.done() holds by the time flush() returns
        with self._lock:
            if exc is not None:
                self._failed += 1
            else:
                self._completed += 1
            self._in_flight -= 1
            self._t_last_done = time.perf_counter()
        try:
            if exc is not None:
                req.future.set_exception(exc)
            else:
                req.future.set_result(result)
        except Exception:       # cancelled under our feet: belt-and-braces
            pass
        with self._lock:
            self._done.notify_all()
        self._slots.release()

    def _begin(self, req: _Request) -> bool:
        """Transition a popped request's Future to RUNNING. False when
        the caller already cancelled it — the request is dropped with
        its slot freed, and the Future can no longer be cancelled once
        its batch dispatches (so result delivery cannot race a
        cancellation)."""
        if req.future.set_running_or_notify_cancel():
            return True
        with self._lock:
            self._failed += 1
            self._in_flight -= 1
            self._t_last_done = time.perf_counter()
            self._done.notify_all()
        self._slots.release()
        return False

    def _fail_batch(self, batch: List[_Request], exc: BaseException) -> None:
        for req in batch:
            self._finish(req, exc=exc)

    # -- the scheduler loop -------------------------------------------
    def _run(self) -> None:
        while True:
            batch = self._take_batch()
            if batch is None:
                return
            batch = [req for req in batch if self._begin(req)]
            if not batch:
                continue
            try:
                self._dispatch(batch)
            except BaseException as exc:            # noqa: BLE001
                self._fail_batch(batch, exc)

    def _take_batch(self) -> Optional[List[_Request]]:
        """Pop the next coalesced same-spec batch (up to ``max_batch``
        members), lingering ``linger_ms`` for stragglers when the queue
        drains below a full batch. None = closed and fully drained."""
        with self._lock:
            while not self._pending and not self._closed:
                self._wake.wait()
            if not self._pending:
                return None
            spec = self._pending[0].spec
            batch = self._pop_spec_locked(spec)
            deadline = time.perf_counter() + self.linger_s * self._linger_scale
            while (len(batch) < self.max_batch and not self._closed):
                remaining = deadline - time.perf_counter()
                if remaining <= 0 or not self._wake.wait(timeout=remaining):
                    break
                batch.extend(self._pop_spec_locked(
                    spec, self.max_batch - len(batch)))
            return batch

    def _pop_spec_locked(self, spec: Tuple,  # guarded-by: self._lock
                         limit: Optional[int] = None) -> List[_Request]:
        limit = self.max_batch if limit is None else limit
        taken: List[_Request] = []
        rest: List[_Request] = []
        for req in self._pending:
            if req.spec == spec and len(taken) < limit:
                taken.append(req)
            else:
                rest.append(req)
        self._pending = collections.deque(rest)
        return taken

    # -- stats --------------------------------------------------------
    def _note_batch(self, real: int, padded: int, nbytes_h2d: int,
                    nbytes_d2h: int, t_device: float) -> None:
        with self._lock:
            self._batches += 1
            self._members_real += real
            self._members_padded += padded
            self._nbytes_h2d += nbytes_h2d
            self._nbytes_d2h += nbytes_d2h
            self._t_device += t_device
            if t_device > 0.0:
                verdict = self._watchdog.observe(t_device)
                self._watchdog_verdicts[verdict] = \
                    self._watchdog_verdicts.get(verdict, 0) + 1
                if verdict == "ok":
                    self._linger_scale = max(1.0, self._linger_scale * 0.5)
                else:       # 'slow' / 'rebalance': widen, don't stall
                    self._linger_scale = min(self._linger_scale_max,
                                             self._linger_scale * 2.0)

    def _note_shard(self, be, shape, dtype, iters: int) -> None:
        """Record one sharded dispatch: fold ``iters`` fix iterations of
        analytic per-axis halo traffic (``be.halo_plan``) into the live
        byte counters the service /stats endpoint surfaces."""
        try:
            plan = be.halo_plan(tuple(shape), dtype)
        except Exception:       # noqa: BLE001 — stats must never fail a batch
            return
        with self._lock:
            self._halo_iters += int(iters)
            for ax, nbytes in plan.items():
                self._halo_bytes[ax] = \
                    self._halo_bytes.get(ax, 0) + int(nbytes) * int(iters)
            self._shard_meta = dict(shape=tuple(int(s) for s in shape),
                                    dtype=str(np.dtype(dtype)),
                                    backend=getattr(be, "name", "sharded"))

    def _note_fix_mode(self, mode: str) -> None:
        """Record which fix-loop strategy one dispatched batch took
        ("fused" / "pipelined" / "host") — surfaced per-mode in
        ``stats()['fix_modes']`` so the service /stats endpoint exposes
        the calibrated policy's actual decisions, not just its
        threshold."""
        with self._lock:
            self._fix_mode_counts[mode] = self._fix_mode_counts.get(mode, 0) + 1

    def _note_codec(self, name: str, nbytes: int) -> None:
        """Record one member's entropy codec and base-payload size —
        surfaced per-codec in ``stats()['entropy_codecs']`` so mixed
        deflate / device-pack traffic stays attributable."""
        with self._lock:
            ent = self._codec_stats.setdefault(name, [0, 0])
            ent[0] += 1
            ent[1] += nbytes

    def stats(self) -> Dict[str, object]:
        """Live counter snapshot — the service stats endpoint surfaces
        this dict as JSON. ``fields_per_sec`` covers first submit to last
        completion; ``batch_occupancy`` is real members / dispatched
        member slots (padding included in the denominator)."""
        with self._lock:
            elapsed = None
            if self._t_first_submit is not None:
                end = self._t_last_done if self._in_flight == 0 and \
                    self._t_last_done else time.perf_counter()
                elapsed = max(end - self._t_first_submit, 1e-9)
            dispatched = self._members_real + self._members_padded
            return dict(
                submitted=self._submitted,
                completed=self._completed,
                failed=self._failed,
                in_flight=self._in_flight,
                max_in_flight=self._max_in_flight,
                window=self.window,
                batches=self._batches,
                max_batch=self.max_batch,
                mean_batch=(self._members_real / self._batches
                            if self._batches else 0.0),
                batch_occupancy=(self._members_real / dispatched
                                 if dispatched else 0.0),
                padded_members=self._members_padded,
                nbytes_h2d=self._nbytes_h2d,
                nbytes_d2h=self._nbytes_d2h,
                t_device_s=self._t_device,
                t_encode_s=self._t_encode,
                fields_per_sec=(self._completed / elapsed
                                if elapsed and self._completed else 0.0),
                fix_modes=dict(self._fix_mode_counts),
                entropy_codecs={k: dict(count=v[0], bytes=v[1])
                                for k, v in self._codec_stats.items()},
                fused_fix_voxels=self._fused_fix_voxels,
                cache=self.cache.stats(),
                straggler=dict(
                    linger_scale=self._linger_scale,
                    steps=self._watchdog.steps,
                    flagged_steps=self._watchdog.flagged_steps,
                    verdicts=dict(self._watchdog_verdicts),
                ),
                shard=dict(
                    halo_bytes_by_axis=dict(self._halo_bytes),
                    halo_bytes_total=sum(self._halo_bytes.values()),
                    fix_iters=self._halo_iters,
                    last=dict(self._shard_meta) if self._shard_meta else None,
                ),
            )

    # -- subclass hooks -----------------------------------------------
    def _dispatch(self, batch: List[_Request]) -> None:
        raise NotImplementedError

    def _backend_key_part(self) -> Tuple:
        name = self._backend if isinstance(self._backend, str) \
            else getattr(self._backend, "name", str(self._backend))
        if self._mesh is None:
            return (name, ())
        # the full per-axis (name, size) layout, not just a device count:
        # a (2, 4) block mesh and an 8-way slab chain compile different
        # programs and must occupy different SpecCache slots
        data_axes = tuple((ax, int(s))
                          for ax, s in zip(self._mesh.axis_names,
                                           self._mesh.devices.shape)
                          if ax in _DATA_AXIS_NAMES)
        return (name, data_axes)

    def _resolved_backend(self, shape: Tuple[int, ...], dtype, xi: float):
        """The mesh-bound stencil backend for one request class, through
        the LRU ``SpecCache`` (key: shape, dtype, xi, backend, mesh)."""
        key = (tuple(shape), str(dtype), float(xi), *self._backend_key_part())
        return self.cache.get(key, lambda: fixes._bind(
            resolve_backend(self._backend, tuple(shape), np.dtype(dtype),
                            mesh=self._mesh)))


# ---------------------------------------------------------------------------
# write side
# ---------------------------------------------------------------------------

class CompressStream(_StreamBase):
    """Double-buffered streaming ``compress_preserving_mss`` (DESIGN.md §6).

    ``submit(field, xi)`` returns a ``concurrent.futures.Future`` that
    resolves to the ``CompressedArtifact`` — byte-identical to the
    one-shot call. Same-(shape, dtype, base, entropy) requests coalesce
    into one batched device dispatch (per-request ``xi`` rides along);
    a deflate batch's entropy coding runs on worker threads while the
    scheduler dispatches the next batch, while a device-pack batch
    (DESIGN.md §8) finishes inline on the scheduler thread — its entropy
    stream was built on the device, so no worker-pool entropy work
    exists. ``map(fields, xis)`` is the ordered convenience wrapper.
    See ``_StreamBase`` for window/backpressure/batching knobs.
    """

    def submit(self, field: np.ndarray, xi: float, *,
               base: pipeline.BaseName = "szlike",
               edit_value_dtype: str = "auto",
               entropy: str = "deflate",
               block: bool = True,
               timeout: Optional[float] = None) -> Future:
        """Queue one field for compression; the Future resolves to its
        ``CompressedArtifact``. ``entropy`` picks the residual byte
        codec ("deflate" | "device-pack", DESIGN.md §8) and is part of
        the coalescing spec: device-pack batches finish entirely on the
        scheduler thread with zero worker-pool entropy work. Raises
        ``StreamBackpressure`` when ``block=False`` and the in-flight
        window is full."""
        field = np.asarray(field)
        pipeline._check_base_entropy(base, entropy)
        spec = (field.shape, str(field.dtype), base, edit_value_dtype,
                entropy)
        return self._submit(field, float(xi), spec, block=block,
                            timeout=timeout)

    def map(self, fields: Sequence[np.ndarray],
            xi) -> List[pipeline.CompressedArtifact]:
        """Compress ``fields`` through the stream; artifacts return in
        submission order regardless of completion order. ``xi``: scalar
        or per-field sequence."""
        fields = list(fields)
        xi_arr = np.broadcast_to(np.asarray(xi, np.float64), (len(fields),))
        futs = [self.submit(f, float(x)) for f, x in zip(fields, xi_arr)]
        return [f.result() for f in futs]

    def _dispatch(self, batch: List[_Request]) -> None:
        spec = batch[0].spec
        _, _, base, evd, entropy = spec
        fields = [req.item for req in batch]
        xi_arr = np.asarray([req.xi for req in batch], np.float64)

        steps: List[float] = []
        use_dev = False
        if self._device_path is True and base != "szlike":
            self._fail_batch(batch, ValueError(
                f"device_path=True but the device path serves the szlike "
                f"base only (got {base!r})"))
            return
        if self._device_path is not False and base == "szlike":
            reasons = [pipeline._device_path_reason(f, float(x), base, "fused")
                       for f, x in zip(fields, xi_arr)]
            use_dev = all(r is None for r, _ in reasons)
            steps = [s for _, s in reasons]
            if self._device_path is True and not use_dev:
                bad = next(r for r, _ in reasons if r is not None)
                self._fail_batch(batch, ValueError(
                    f"device_path=True but {bad}"))
                return
        be = None
        if use_dev:
            be = self._resolved_backend(fields[0].shape, fields[0].dtype,
                                        float(xi_arr[0]))
            if not hasattr(be, "transform"):
                if self._device_path is True:
                    self._fail_batch(batch, ValueError(
                        f"device_path=True but backend {be.name!r} implements "
                        "no transform/reconstruct protocol entry"))
                    return
                be, use_dev = None, False
        if not use_dev:
            # host byte-codec path (zfplike base, unsupported dtype, range
            # precondition failures, ...): one whole-batch worker job so
            # the scheduler stays free for the next batch's device stage
            self._note_fix_mode("host")
            self._pool.submit(self._host_batch, batch, fields, xi_arr,
                              base, evd, entropy)
            return

        # pad the batch to a power-of-two member count: the vmapped
        # dispatches then specialize on ~log2(window) batch sizes total.
        # Distributed backends run members sequentially — padding would
        # only add work there.
        B = len(fields)
        cap = pipeline._pow2_at_least(B) if (
            self._pad_pow2 and not hasattr(be, "fix_loop")) else B
        pad = cap - B
        if pad:
            fields = fields + [fields[-1]] * pad
            xi_arr = np.concatenate([xi_arr, np.full(pad, xi_arr[-1])])
            steps = steps + [steps[-1]] * pad
        t0 = time.perf_counter()
        # under MSZ_SANITIZERS the whole device stage runs inside the
        # transfer guard: an untracked host<->device crossing fails the
        # batch loudly instead of silently serializing the dispatch
        # stream (debug.guards, DESIGN.md §10)
        with sanitize_transfers():
            if self._use_fused_fix(fields[0], be):
                self._note_fix_mode("fused")
                db = pipeline._device_batch_stage(fields, xi_arr, be,
                                                  self._max_iters, steps,
                                                  entropy=entropy)
            else:
                self._note_fix_mode("pipelined")
                db = pipeline._device_pipelined_stage(fields, xi_arr, be,
                                                      self._max_iters, steps,
                                                      n_real=B,
                                                      entropy=entropy)
        self._note_batch(B, pad, db.nbytes_h2d, db.nbytes_d2h,
                         time.perf_counter() - t0)
        if hasattr(be, "halo_plan"):
            self._note_shard(be, fields[0].shape, fields[0].dtype,
                             int(np.sum(db.iters_b[:B])))
        for i, req in enumerate(batch):
            if db.packed is not None:
                # device-pack: the entropy stream already left the device
                # as framed words — member finish is pure header assembly,
                # so it runs inline and the worker pool sees no entropy
                # work at all (DESIGN.md §8)
                self._finish_compress(db, i, evd, req)
            else:
                self._pool.submit(self._finish_compress, db, i, evd, req)

    def _use_fused_fix(self, field: np.ndarray, be) -> bool:
        """Whether this batch's fix loops run as ONE batched while_loop
        (``_device_batch_stage``) or as per-member solo loops behind a
        shared vmapped transform (``_device_pipelined_stage``). The
        batched loop amortizes dispatch overhead but holds every member
        until its compaction round retires it (and vmapped interpret-
        mode Pallas stencils pay a further per-iteration penalty), so
        "auto" fuses only members small enough that dispatch overhead
        dominates — up to ``fused_fix_voxels`` voxels. That threshold
        is no longer a hardcoded constant: when the constructor leaves
        it ``None``, the first auto decision runs the one-shot machine
        calibration (``compress.calibrate``, cached per backend/dtype/
        platform, ``MSZ_FUSED_FIX_VOXELS`` overrides). Distributed
        backends always take the batch stage (their fix loops run
        members sequentially either way)."""
        if hasattr(be, "fix_loop"):
            return True
        if self._fix_batching != "auto":
            return self._fix_batching == "fused"
        if self._fused_fix_voxels is None:
            # scheduler-thread only, so the lazy fill needs no lock;
            # stats() readers see None until the first auto decision
            self._fused_fix_voxels = calibrate.fused_fix_threshold(
                be, field.dtype).threshold_voxels
        return field.size <= self._fused_fix_voxels

    def _host_batch(self, batch: List[_Request], fields, xi_arr,
                    base: str, evd: str, entropy: str = "deflate") -> None:
        try:
            arts = pipeline.compress_preserving_mss_batch(
                fields, xi_arr, base=base, edit_value_dtype=evd,
                max_iters=self._max_iters, backend=self._backend,
                mesh=self._mesh, device_path=False, entropy=entropy)
        except BaseException as exc:                # noqa: BLE001
            self._fail_batch(batch, exc)
            return
        self._note_batch(len(batch), 0, 0, 0, 0.0)
        for req, art in zip(batch, arts):
            self._note_codec(getattr(art, "entropy", "deflate"),
                             len(art.base_payload))
            self._finish(req, result=art)

    def _finish_compress(self, db: "pipeline._DeviceBatch", i: int,
                         evd: str, req: _Request) -> None:
        t0 = time.perf_counter()
        try:
            art = pipeline._encode_batch_member(db, i, evd)
        except BaseException as exc:                # noqa: BLE001
            self._finish(req, exc=exc)
            return
        with self._lock:
            self._t_encode += time.perf_counter() - t0
        self._note_codec(getattr(art, "entropy", "deflate"),
                         len(art.base_payload))
        self._finish(req, result=art)


# ---------------------------------------------------------------------------
# read side
# ---------------------------------------------------------------------------

class DecompressStream(_StreamBase):
    """Streaming ``decompress_preserving_mss``: same scheduler, artifacts
    in, fields out. Same-(base, shape, dtype) artifacts coalesce into one
    ``decompress_artifact_batch`` call — which itself pipelines threaded
    entropy decode against async per-member device dispatch (DESIGN.md
    §5) — and whole batches run on worker threads, so batch *k+1*'s
    entropy decode overlaps batch *k*'s device work. Because those inner
    stages overlap inside one call, the read side cannot attribute them
    separately: ``stats()['t_device_s']`` carries the combined batch
    time and ``t_encode_s`` stays 0. Outputs are byte-identical to
    one-shot calls."""

    def submit(self, art: pipeline.CompressedArtifact, *,
               block: bool = True,
               timeout: Optional[float] = None) -> Future:
        """Queue one artifact; the Future resolves to the decompressed
        field g (``np.ndarray``)."""
        spec = (art.base, tuple(art.shape), str(art.dtype))
        return self._submit(art, float(art.xi), spec, block=block,
                            timeout=timeout)

    def map(self, arts: Sequence[pipeline.CompressedArtifact]
            ) -> List[np.ndarray]:
        """Decompress ``arts`` through the stream, results in submission
        order."""
        futs = [self.submit(a) for a in arts]
        return [f.result() for f in futs]

    def _dispatch(self, batch: List[_Request]) -> None:
        if self._device_path is not False and all(
                self._art_codec(req.item) == "device-pack" and
                getattr(req.item, "path", "host") == "device"
                for req in batch):
            # device-pack device-path batch: residual decode is a device
            # unpack, so there is no host entropy work to overlap — run
            # inline rather than paying a worker-pool hop (DESIGN.md §8).
            # Under MSZ_SANITIZERS the decode also runs inside the
            # transfer guard, asserting the no-host-entropy claim.
            with sanitize_transfers():
                self._decode_batch(batch)
        else:
            self._pool.submit(self._decode_batch, batch)

    @staticmethod
    def _art_codec(art: pipeline.CompressedArtifact) -> str:
        """The artifact's residual entropy codec, trusting the payload
        magic over the (v3+) artifact field when the base is szlike."""
        if art.base == "szlike":
            try:
                return szlike.sz_blob_entropy(art.base_payload)
            except ValueError:
                pass
        return getattr(art, "entropy", "deflate")

    def _decode_batch(self, batch: List[_Request]) -> None:
        arts = [req.item for req in batch]
        t0 = time.perf_counter()
        try:
            if len(arts) == 1:
                # skip the batch machinery (pooled entropy decode, stacked
                # d2h) for singleton batches — output is identical
                gs = [pipeline.decompress_preserving_mss(
                    arts[0], device_path=self._device_path,
                    backend=self._backend, mesh=self._mesh)]
            else:
                gs = pipeline.decompress_artifact_batch(
                    arts, device_path=self._device_path,
                    backend=self._backend, mesh=self._mesh)
        except BaseException as exc:                # noqa: BLE001
            self._fail_batch(batch, exc)
            return
        nbytes = sum(g.nbytes for g in gs)
        self._note_batch(len(batch), 0,
                         sum(len(a.base_payload) + len(a.edit_payload)
                             for a in arts),
                         nbytes, time.perf_counter() - t0)
        for a in arts:
            self._note_codec(self._art_codec(a), len(a.base_payload))
        for req, g in zip(batch, gs):
            self._finish(req, result=g)
