"""End-to-end MSz-corrected compression pipeline (paper Fig. 3).

compression:   f --base compressor--> payload --decompress--> f_hat
               (f, f_hat) --C/R fix loops--> edits --codec--> edit blob
decompression: payload --> f_hat ; f_hat + edits --> g  (MSS(g) == MSS(f))

Two execution paths produce BITWISE-IDENTICAL artifacts (DESIGN.md §4):

* **device** (the production path for the szlike base): one host->device
  transfer of ``f``, then quantize+Lorenzo (``backend.transform``),
  on-device reconstruction of ``f_hat`` from the residual codes
  (``backend.reconstruct``), the fused fix loop, and on-device edit
  extraction (mask/count/compaction inside jit) — one device->host
  transfer of the int32 residual codes, after which only entropy coding
  (szlike._pack_residuals, codec.encode_edits) runs host-side.
* **host**: the original per-member byte-codec loop (any base
  compressor, any dtype, no int32 range precondition).

``device_path="auto"`` picks the device path whenever its preconditions
hold (szlike base, fused mode, f32 field — or f64 under jax x64 — and
szlike.check_int32_range passes); artifacts record which path produced
them (``CompressedArtifact.path``, header version 2).

``compress_preserving_mss_batch`` runs many same-shape fields through
ONE vmapped transform and ONE batched fix loop instead of B sequential
host codec calls.

The base codec is pluggable (``codec="szlike" | "zfplike"``, or any
codec registered through ``compress.preserve``): edit derivation is
codec-agnostic (DESIGN.md §11), the artifact records the base codec and
its payload magic, and the read side negotiates the decoder from the
magic — retired blob formats are refused, never misdecoded.

The READ side is symmetric (DESIGN.md §5): ``decompress_preserving_mss``
host-decodes the entropy streams once, then does one h2d of the int32
residual codes, on-device ``backend.reconstruct`` + edit scatter-add
(``backend.scatter_edits``), and one d2h of g — bitwise identical to the
host-side ``decompress_artifact``. ``decompress_artifact_batch`` serves
many same-shape artifacts pipelined: threaded entropy decode overlapping
per-member async device dispatch, one d2h of the stacked batch.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, List, Literal, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core import fixes
from ..core.backend import BackendLike, resolve_backend
from ..core.driver import apply_edits, extract_edits
from . import codec, preserve, szlike
from .preserve import ARTIFACT_VERSION, CompressedArtifact

BaseName = Literal["szlike", "zfplike"]
DevicePath = Union[bool, Literal["auto"]]

# compatibility aliases: the checked edit encoders moved to the
# codec-agnostic preserve layer (both paths still share them)
_encode_edits_checked = preserve.encode_edits_checked
_encode_edits_checked_dev = preserve.encode_edits_checked_dev


# test seam: when set, called as hook(direction, nbytes) for every
# host<->device ARRAY crossing the device path makes ("h2d"/"d2h";
# scalar syncs — counts, convergence flags — are not array transfers and
# are exempt). tests/test_device_path.py counts field-sized crossings.
_transfer_hook: Optional[Callable[[str, int], None]] = None


def _h2d(x: np.ndarray) -> jnp.ndarray:
    """The audited host->device seam: every array the device path
    uploads crosses here, counted by ``_transfer_hook`` and moved via
    the EXPLICIT ``jax.device_put`` — so ``debug.guards.no_transfers``
    (which disallows only implicit crossings) passes tracked uploads
    and fails untracked ones."""
    if _transfer_hook is not None:
        _transfer_hook("h2d", x.nbytes)
    return jax.device_put(x)   # mszlint: disable=transfer-discipline — the choke point itself


def _d2h(x: jnp.ndarray) -> np.ndarray:
    """The audited device->host seam (explicit ``jax.device_get``);
    twin of ``_h2d``."""
    if _transfer_hook is not None:
        _transfer_hook("d2h", x.nbytes)
    return jax.device_get(x)   # mszlint: disable=transfer-discipline — the choke point itself


# ---------------------------------------------------------------------------
# path selection
# ---------------------------------------------------------------------------

def _device_dtype_ok(dtype) -> bool:
    if dtype == np.float32:
        return True
    if dtype == np.float64:
        return bool(jax.config.jax_enable_x64)
    return False


def _device_path_reason(f: np.ndarray, xi: float, base: str, mode: str
                        ) -> Tuple[Optional[str], Optional[float]]:
    """(None, step) when the device path can serve this call, else
    (why not, None). One field scan total: max|f| feeds both the step
    headroom and the range-precondition check."""
    if base != "szlike":
        return (f"device path serves the szlike base only (got {base!r}); "
                "zfplike's block transform stays host-side"), None
    if mode != "fused":
        return f"device path requires mode='fused' (got {mode!r})", None
    if f.ndim not in (2, 3) or f.size == 0:
        return (f"device path needs a non-empty 2D/3D field "
                f"(shape {f.shape})"), None
    if not _device_dtype_ok(f.dtype):
        return (f"device path needs float32 (or float64 under jax x64 "
                f"mode); got {f.dtype}"), None
    amax = float(np.max(np.abs(f)))
    step = szlike.effective_step(f, xi, amax=amax)
    try:
        szlike.check_int32_range(f, step / 2.0, amax=amax)
    except ValueError as e:
        return str(e), None
    return None, step


def _resolve_device_path(device_path: DevicePath, f: np.ndarray, xi: float,
                         base: str, mode: str) -> Optional[float]:
    """The quantization step when the device path should run, else None."""
    if device_path is False:
        return None
    reason, step = _device_path_reason(f, xi, base, mode)
    if device_path is True and reason is not None:
        raise ValueError(f"device_path=True but {reason}")
    return step


# ---------------------------------------------------------------------------
# the device-resident path (DESIGN.md §4)
# ---------------------------------------------------------------------------

def _device_pack_ok(be, entropy: str) -> bool:
    """Whether ``entropy`` coding itself can run on device: device-pack
    selected and the backend implements the pack protocol entries."""
    return entropy == "device-pack" and hasattr(be, "pack_codes")


def _pull_packed(be, r) -> Tuple[np.ndarray, np.ndarray]:
    """Entropy-code one member's int32 residual codes on device and pull
    ``(words, bits)``: the chunked-bitplane stream replaces the full
    code array on the d2h hop, and no host entropy work remains — the
    blob assembly in ``sz_encode_packed`` is pure byte copying. The
    ``n_words`` sync is a scalar (exempt from the transfer-hook array
    accounting), needed to slice the jit-static capacity buffer to the
    true stream before it crosses; like every other crossing it routes
    through the explicit ``_d2h`` seam so the path stays clean under
    ``no_transfers()``."""
    w, bts, n_words = be.pack_codes(r)
    nw = int(_d2h(n_words))
    return _d2h(_slice_to(w, nw)), _d2h(bts)


@functools.partial(jax.jit, static_argnames=("n",))
def _slice_to(x: jnp.ndarray, n: int) -> jnp.ndarray:
    """``x[:n]`` jitted with a static length: an eager slice ships its
    indices to the device per call (an implicit transfer under
    ``debug.no_transfers()``); the jitted one bakes them in at trace
    time, at the same one-compile-per-distinct-length cost."""
    return x[:n]


@functools.partial(jax.jit, static_argnames=("i",))
def _member(x_b: jnp.ndarray, i: int) -> jnp.ndarray:
    """``x_b[i]`` jitted with a static index — the batch stages' member
    extraction. Same rationale as ``_slice_to``: eager integer indexing
    is a dynamic_slice whose index crosses host->device per call."""
    return x_b[i]


def _device_compress(f: np.ndarray, xi: float, be, max_iters: int,
                     edit_value_dtype: str, step: float,
                     entropy: str = "deflate") -> CompressedArtifact:
    """Single host->device transfer of f; transform, reconstruction, fix
    loop, and edit extraction stay on-device; single device->host
    transfer of the residual codes — entropy-coded on device first when
    ``entropy="device-pack"`` — for blob assembly. ``step`` comes
    pre-validated from _device_path_reason."""
    t0 = time.perf_counter()
    fj = _h2d(f)
    r = be.transform(fj, step)
    f_hat = be.reconstruct(r, step, fj.dtype)
    base_err = float(_d2h(jnp.max(jnp.abs(fj - f_hat))))
    t1 = time.perf_counter()
    if base_err > xi * (1 + 1e-6):
        raise ValueError(
            f"reconstructed data violates the error bound before editing: "
            f"max|f-f_hat|={base_err:.3g} > xi={xi:.3g}")

    topo = fixes.field_topology(fj, xi)
    g, iters, ok = fixes.fused_fix(f_hat, topo, max_iters=max_iters,
                                   backend=be)
    if not bool(_d2h(ok)):
        raise RuntimeError("MSz fix loops did not converge within max_iters")
    idx_d, val_d = extract_edits(f_hat, g)
    t2 = time.perf_counter()

    # ---- residual entropy coding: on device (pack) or host (DEFLATE) ----
    if _device_pack_ok(be, entropy):
        words, bits = _pull_packed(be, r)
        payload = szlike.sz_encode_packed(words, bits, f.shape, f.dtype,
                                          step)
    else:
        payload = szlike.sz_encode_residuals(_d2h(r), f.shape, f.dtype,
                                             step, entropy=entropy)
    idx = _d2h(idx_d).astype(np.int64)
    val = _d2h(val_d)
    blob = _encode_edits_checked_dev(fj, f_hat, idx, val, xi,
                                     edit_value_dtype)
    t3 = time.perf_counter()
    return CompressedArtifact(
        base="szlike", base_payload=payload, edit_payload=blob,
        shape=f.shape, dtype=str(f.dtype), xi=xi,
        t_base=(t1 - t0) + (t3 - t2), t_fix=t2 - t1,
        edit_ratio=float(idx.size) / float(f.size),
        fix_iters=int(_d2h(iters)), backend=be.name,
        path="device", t_transform=t1 - t0, entropy=entropy,
        base_magic=preserve.payload_magic(payload).decode("ascii"),
    )


@dataclasses.dataclass
class _DeviceBatch:
    """Completed device stage of one compress batch (DESIGN.md §4/§6).

    Everything up to — and including — the single d2h of the residual
    codes has run; what remains per member is host-only entropy coding
    (``_encode_batch_member``). The stream scheduler hands that stage to
    worker threads so it overlaps the NEXT batch's device dispatch;
    ``_device_compress_batch`` runs it inline for the one-shot API."""
    fields: List[np.ndarray]
    xi_arr: np.ndarray
    steps: List[float]
    f_b: jnp.ndarray             # device-resident originals (bf16 re-verify)
    fhat_b: jnp.ndarray          # device-resident reconstructions
    r_host: Optional[np.ndarray]  # residual codes pulled to host (DEFLATE)
    edits: List[Tuple[jnp.ndarray, jnp.ndarray]]  # device (idx, val) pairs
    iters_b: np.ndarray
    backend_name: str
    t_transform_each: float
    t_fix_each: float
    t_pull_each: float
    nbytes_h2d: int = 0          # array bytes crossed host->device
    nbytes_d2h: int = 0          # array bytes crossed device->host
    # device-pack batches carry per-member (words, bits) pulled off the
    # device instead of r_host; _encode_batch_member then only assembles
    # bytes — zero host entropy work
    entropy: str = "deflate"
    packed: Optional[List[Tuple[np.ndarray, np.ndarray]]] = None


def _batch_transform(fields: List[np.ndarray], xi_arr: np.ndarray, be,
                     steps: List[float], n_check: int):
    """Shared device prologue of the two batch stages: ONE h2d of the
    stacked fields + steps, the transform/reconstruct dispatch (vmapped;
    member-sequential for distributed backends, where vmap over
    shard_map is not attempted, mirroring fused_fix_batch), and the
    pre-edit bound check of the first ``n_check`` members. Returns
    (f_stack, f_b, step_b, r_b, fhat_b, base_errs)."""
    B = len(fields)
    f_stack = np.stack(fields)
    f_b = _h2d(f_stack)
    step_b = _h2d(np.asarray(steps, fields[0].dtype))
    if hasattr(be, "fix_loop"):
        r_b = jnp.stack([be.transform(_member(f_b, i), _member(step_b, i))
                         for i in range(B)])
        fhat_b = jnp.stack([be.reconstruct(_member(r_b, i),
                                           _member(step_b, i), f_b.dtype)
                            for i in range(B)])
    else:
        r_b = jax.vmap(be.transform)(f_b, step_b)
        fhat_b = jax.vmap(lambda ri, si: be.reconstruct(ri, si, f_b.dtype))(
            r_b, step_b)
    sp = tuple(range(1, f_b.ndim))
    base_errs = _d2h(jnp.max(jnp.abs(f_b - fhat_b), axis=sp))
    for i in range(n_check):
        if base_errs[i] > xi_arr[i] * (1 + 1e-6):
            raise ValueError(
                f"batch member {i}: reconstructed data violates the error "
                f"bound before editing: max|f-f_hat|={base_errs[i]:.3g} > "
                f"xi={xi_arr[i]:.3g}")
    return f_stack, f_b, step_b, r_b, fhat_b, base_errs


def _pull_batch_codes(be, r_b, B: int, entropy: str):
    """The batch's residual-code d2h hop: per-member device-packed
    streams for ``entropy="device-pack"`` (the words replace the full
    codes on the wire and no host entropy stage remains), else the raw
    stacked codes for host DEFLATE. Returns (r_host, packed, nbytes)."""
    if _device_pack_ok(be, entropy):
        packed = [_pull_packed(be, _member(r_b, i)) for i in range(B)]
        return None, packed, sum(w.nbytes + b.nbytes for w, b in packed)
    r_host = _d2h(r_b)
    return r_host, None, r_host.nbytes


def _device_batch_stage(fields: List[np.ndarray], xi_arr: np.ndarray,
                        be, max_iters: int, steps: List[float],
                        entropy: str = "deflate") -> _DeviceBatch:
    """The device-resident half of a compress batch: ONE h2d of the
    stacked fields, ONE vmapped transform + ONE batched fix loop +
    on-device edit extraction, ONE d2h of the residual codes (device-
    packed first under ``entropy="device-pack"``). ``steps`` come
    pre-validated from the caller's _device_path_reason sweep."""
    B = len(fields)
    t0 = time.perf_counter()
    f_stack, f_b, step_b, r_b, fhat_b, base_errs = _batch_transform(
        fields, xi_arr, be, steps, n_check=B)
    t1 = time.perf_counter()

    # mszlint: disable=transfer-discipline -- xi_arr is the host numpy bounds
    topos = [fixes.field_topology(_member(f_b, i), float(xi_arr[i]))
             for i in range(B)]
    topo_b = jax.tree_util.tree_map(lambda *leaves: jnp.stack(leaves), *topos)
    g_b, iters_b, ok_b = fixes.fused_fix_batch(fhat_b, topo_b,
                                               max_iters=max_iters, backend=be)
    if not bool(_d2h(jnp.all(ok_b))):
        raise RuntimeError("MSz fix loops did not converge within max_iters")
    edits = [extract_edits(_member(fhat_b, i), _member(g_b, i))
             for i in range(B)]
    t2 = time.perf_counter()

    r_host, packed, nbytes_codes = _pull_batch_codes(be, r_b, B, entropy)
    t_pull = time.perf_counter() - t2
    return _DeviceBatch(
        fields=fields, xi_arr=xi_arr, steps=steps,
        f_b=f_b, fhat_b=fhat_b, r_host=r_host, edits=edits,
        iters_b=_d2h(iters_b), backend_name=be.name,
        t_transform_each=(t1 - t0) / B, t_fix_each=(t2 - t1) / B,
        t_pull_each=t_pull / B,
        nbytes_h2d=f_stack.nbytes + step_b.nbytes,
        nbytes_d2h=nbytes_codes + base_errs.nbytes,
        entropy=entropy, packed=packed,
    )


def _encode_batch_member(db: _DeviceBatch, i: int,
                         edit_value_dtype: str) -> CompressedArtifact:
    """Per-member tail of a device batch. DEFLATE batches entropy-code
    here (thread-safe: zlib and the edit-sized d2h pulls release the
    GIL, so the stream runs many members through worker threads while
    the scheduler dispatches the next batch's device stage); device-pack
    batches arrive already entropy-coded and only assemble bytes — cheap
    enough that the stream runs them inline on the scheduler thread."""
    fi = db.fields[i]
    # per-member entropy-coding time joins t_base so batch artifacts
    # report the same cost split as solo device-path calls
    te0 = time.perf_counter()
    if db.packed is not None:
        words, bits = db.packed[i]
        payload = szlike.sz_encode_packed(words, bits, fi.shape, fi.dtype,
                                          db.steps[i])
    else:
        payload = szlike.sz_encode_residuals(db.r_host[i], fi.shape,
                                             fi.dtype, db.steps[i],
                                             entropy=db.entropy)
    idx = _d2h(db.edits[i][0]).astype(np.int64)
    val = _d2h(db.edits[i][1])
    blob = _encode_edits_checked_dev(
        _member(db.f_b, i), _member(db.fhat_b, i), idx, val,
        # mszlint: disable=transfer-discipline -- xi_arr is host numpy
        float(db.xi_arr[i]), edit_value_dtype)
    t_entropy = time.perf_counter() - te0
    return CompressedArtifact(
        base="szlike", base_payload=payload, edit_payload=blob,
        # mszlint: disable=transfer-discipline -- xi_arr is host numpy
        shape=fi.shape, dtype=str(fi.dtype), xi=float(db.xi_arr[i]),
        t_base=db.t_transform_each + db.t_pull_each + t_entropy,
        t_fix=db.t_fix_each,
        edit_ratio=float(idx.size) / float(fi.size),
        # mszlint: disable=transfer-discipline -- iters_b was pulled by _d2h
        fix_iters=int(db.iters_b[i]), backend=db.backend_name,
        path="device", t_transform=db.t_transform_each,
        entropy=db.entropy,
        base_magic=preserve.payload_magic(payload).decode("ascii"),
    )


def _device_pipelined_stage(fields: List[np.ndarray], xi_arr: np.ndarray,
                            be, max_iters: int, steps: List[float],
                            n_real: Optional[int] = None,
                            entropy: str = "deflate") -> _DeviceBatch:
    """The stream scheduler's large-member alternative to
    ``_device_batch_stage`` (DESIGN.md §6): ONE h2d + ONE vmapped
    transform/reconstruct dispatch for the whole batch (elementwise —
    vmap amortizes its dispatch overhead at every size), but the fix
    loops run per member through the SOLO ``fixes.fused_fix``
    specialization. The batched while_loop computes every member each
    iteration until the slowest converges (B x max(iters) work) and
    vmapping the interpret-mode Pallas stencils multiplies per-iteration
    cost, so above a few thousand voxels per member solo loops win;
    per-member g is the exact one-shot computation, so artifacts stay
    byte-identical. ``n_real``: members beyond it are batch padding —
    transformed (they ride the vmapped dispatch) but never fixed."""
    B = len(fields)
    n_real = B if n_real is None else n_real
    t0 = time.perf_counter()
    f_stack, f_b, step_b, r_b, fhat_b, base_errs = _batch_transform(
        fields, xi_arr, be, steps, n_check=n_real)
    t1 = time.perf_counter()

    edits: List[Tuple[jnp.ndarray, jnp.ndarray]] = []
    iters_list: List[int] = []
    for i in range(n_real):
        fhat_i = _member(fhat_b, i)
        # mszlint: disable=transfer-discipline -- xi_arr is host numpy
        topo = fixes.field_topology(_member(f_b, i), float(xi_arr[i]))
        g, iters, ok = fixes.fused_fix(fhat_i, topo, max_iters=max_iters,
                                       backend=be)
        if not bool(_d2h(ok)):
            raise RuntimeError(
                "MSz fix loops did not converge within max_iters")
        edits.append(extract_edits(fhat_i, g))
        iters_list.append(int(_d2h(iters)))
    t2 = time.perf_counter()

    r_host, packed, nbytes_codes = _pull_batch_codes(be, r_b, B, entropy)
    t_pull = time.perf_counter() - t2
    empty = (jnp.zeros(0, jnp.int32), jnp.zeros(0, f_b.dtype))
    return _DeviceBatch(
        fields=fields, xi_arr=xi_arr, steps=steps,
        f_b=f_b, fhat_b=fhat_b, r_host=r_host,
        edits=edits + [empty] * (B - n_real),
        # mszlint: disable=transfer-discipline -- iters_list is python ints
        iters_b=np.asarray(iters_list + [0] * (B - n_real)),
        backend_name=be.name,
        t_transform_each=(t1 - t0) / B,
        t_fix_each=(t2 - t1) / max(n_real, 1),
        t_pull_each=t_pull / B,
        nbytes_h2d=f_stack.nbytes + step_b.nbytes,
        nbytes_d2h=nbytes_codes + base_errs.nbytes,
        entropy=entropy, packed=packed,
    )


def _device_compress_batch(fields: List[np.ndarray], xi_arr: np.ndarray,
                           be, max_iters: int, edit_value_dtype: str,
                           steps: List[float],
                           entropy: str = "deflate"
                           ) -> List[CompressedArtifact]:
    """Batch device path: ONE vmapped transform + ONE batched fix loop;
    per-member entropy coding afterwards (on device under device-pack).
    Artifacts are bitwise identical to solo device-path calls (the
    batched loop freezes early-converged members, fixes.fused_fix_batch)."""
    db = _device_batch_stage(fields, xi_arr, be, max_iters, steps,
                             entropy=entropy)
    return [_encode_batch_member(db, i, edit_value_dtype)
            for i in range(len(fields))]


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def _check_base_entropy(base: str, entropy: str) -> None:
    """Validate the (base, entropy) combination: the residual entropy
    codec choice exists for the szlike residual stream only."""
    szlike.check_entropy(entropy)
    if entropy != "deflate" and base != "szlike":
        raise ValueError(
            f"entropy={entropy!r} applies to the szlike base only "
            f"(got base={base!r})")


def _host_compressor(base: str, entropy: str) -> Optional[Callable]:
    """A pre-bound compressor for the host path when ``entropy`` needs
    binding in (szlike's compressor takes the codec as a keyword), else
    None — ``preserve.compress_host`` then uses the registered default.
    The decoders dispatch on the blob magic, so no binding there."""
    if base == "szlike" and entropy != "deflate":
        return functools.partial(szlike.sz_compress, entropy=entropy)
    return None


def compress_preserving_mss(f: np.ndarray, xi: float, base: BaseName = "szlike",
                            mode: str = "fused",
                            edit_value_dtype: str = "auto",
                            max_iters: int = 512,
                            backend: BackendLike = "auto",
                            mesh=None,
                            device_path: DevicePath = "auto",
                            entropy: str = "deflate",
                            codec: Optional[str] = None
                            ) -> CompressedArtifact:
    """``codec``: the base compressor's registry name (an alias that
    overrides ``base`` when given — any codec registered through
    ``compress.preserve`` qualifies). ``mesh``: route the fix loop
    through the slab-sharded SPMD backend when the mesh has >= 2
    ``data``-axis devices. ``device_path``: run the whole compress stage
    device-resident ("auto" = whenever the preconditions hold, see
    module docstring; non-szlike bases take the codec-agnostic host
    path). ``entropy``: the szlike residual codec — "deflate" (host
    zlib, the compatibility default) or "device-pack" (the chunked-
    bitplane codec; on the device path it runs on device and the
    compress stage performs zero host entropy work). Artifacts are
    byte-for-byte identical across paths, backends, and meshes."""
    if codec is not None:
        base = codec
    f = np.asarray(f)
    _check_base_entropy(base, entropy)
    step = _resolve_device_path(device_path, f, xi, base, mode)
    if step is not None:
        be = resolve_backend(backend, f.shape, f.dtype, mesh=mesh)
        if hasattr(be, "transform"):
            return _device_compress(f, xi, be, max_iters, edit_value_dtype,
                                    step, entropy=entropy)
        if device_path is True:
            raise ValueError(
                f"device_path=True but backend {be.name!r} implements no "
                "transform/reconstruct protocol entry")

    art = preserve.compress_host(
        base, f, xi, compressor=_host_compressor(base, entropy),
        mode=mode, edit_value_dtype=edit_value_dtype, max_iters=max_iters,
        backend=backend, mesh=mesh)
    art.entropy = entropy
    return art


def compress_preserving_mss_batch(
        fields: Union[np.ndarray, Sequence[np.ndarray]],
        xi: Union[float, Sequence[float]],
        base: BaseName = "szlike",
        edit_value_dtype: str = "auto",
        max_iters: int = 512,
        backend: BackendLike = "auto",
        mesh=None,
        device_path: DevicePath = "auto",
        entropy: str = "deflate",
        codec: Optional[str] = None) -> List[CompressedArtifact]:
    """Batch variant of compress_preserving_mss for many same-shape fields.

    On the device path the base transform of ALL members runs as one
    vmapped dispatch and the fix loops as one batched while_loop
    (derive_edits_batch's machinery); host-side only the entropy coders
    run per member — and under ``entropy="device-pack"`` even those move
    on device, leaving pure byte assembly. Non-szlike bases run their
    transforms host-side but still share the ONE batched fix loop
    (``preserve.compress_host_batch``). Each member's artifact is
    bitwise identical to a solo compress_preserving_mss call; t_base /
    t_fix report the batch time split evenly across members.
    """
    if codec is not None:
        base = codec
    fields = [np.asarray(fi) for fi in fields]
    _check_base_entropy(base, entropy)
    if not fields:
        return []
    if any(fi.shape != fields[0].shape for fi in fields):
        raise ValueError("batch members must share one shape; got "
                         f"{[fi.shape for fi in fields]}")
    B = len(fields)
    xi_arr = np.broadcast_to(np.asarray(xi, np.float64), (B,))

    use_dev, steps = False, []
    if device_path is not False:
        reasons = [_device_path_reason(fi, float(xi_i), base, "fused")
                   for fi, xi_i in zip(fields, xi_arr)]
        use_dev = all(r is None for r, _ in reasons)
        steps = [s for _, s in reasons]
        if device_path is True and not use_dev:
            bad = next(r for r, _ in reasons if r is not None)
            raise ValueError(f"device_path=True but {bad}")
    if use_dev:
        be = resolve_backend(backend, fields[0].shape, fields[0].dtype,
                             mesh=mesh)
        if hasattr(be, "transform"):
            be = fixes._bind(be)
            return _device_compress_batch(fields, xi_arr, be, max_iters,
                                          edit_value_dtype, steps,
                                          entropy=entropy)
        if device_path is True:
            raise ValueError(
                f"device_path=True but backend {be.name!r} implements no "
                "transform/reconstruct protocol entry")

    arts = preserve.compress_host_batch(
        base, fields, xi_arr, compressor=_host_compressor(base, entropy),
        edit_value_dtype=edit_value_dtype, max_iters=max_iters,
        backend=backend, mesh=mesh)
    for art in arts:
        art.entropy = entropy
    return arts


def decompress_artifact(art: CompressedArtifact) -> np.ndarray:
    """Host-side decompression: magic-negotiated base decode
    (``preserve.decode_payload`` — retired blob formats are refused,
    never misdecoded) + numpy edit apply. Works for any base/dtype;
    ``decompress_preserving_mss`` is the production read path
    (device-resident whenever possible)."""
    f_hat = preserve.decode_payload(art)
    idx, val = codec.decode_edits(art.edit_payload)
    return apply_edits(f_hat, idx, val)


# ---------------------------------------------------------------------------
# the device-resident decompression path (DESIGN.md §5)
# ---------------------------------------------------------------------------

def _device_decode_reason(art: CompressedArtifact) -> Optional[str]:
    """None when the device decode path can serve ``art`` on metadata
    grounds (the residual-code range check runs after entropy decode),
    else why not. Mirrors _device_path_reason on the write side."""
    if art.base != "szlike":
        return (f"device decode serves the szlike base only (got "
                f"{art.base!r}); zfplike's block transform stays host-side")
    if len(art.shape) not in (2, 3) or _size_of(art.shape) == 0:
        return (f"device decode needs a non-empty 2D/3D field "
                f"(shape {art.shape})")
    if not _device_dtype_ok(np.dtype(art.dtype)):
        return (f"device decode needs float32 (or float64 under jax x64 "
                f"mode); got {art.dtype}")
    return None


def _size_of(shape) -> int:
    return int(np.prod(shape, dtype=np.int64)) if len(shape) else 1


def _decode_backend(backend: BackendLike, shape, dtype, mesh,
                    device_path: DevicePath):
    """Resolve the stencil backend for a decode call, or None (-> host
    fallback) when it lacks the reconstruct/scatter protocol entries."""
    be = resolve_backend(backend, shape, np.dtype(dtype), mesh=mesh)
    if hasattr(be, "reconstruct") and hasattr(be, "scatter_edits"):
        return be
    if device_path is True:
        raise ValueError(
            f"device_path=True but backend {be.name!r} implements no "
            "reconstruct/scatter_edits protocol entry")
    return None


def _checked_codes(art: CompressedArtifact):
    """Entropy-decode ``art``'s residual stream and validate the int32
    reconstruction precondition. Returns (r, shape, dtype, step) or a
    reason string. Device-path artifacts were range-checked against the
    original field at compress time; every other artifact's decoded
    stream is validated soundly (szlike.codes_fit_int32 — a cheap
    sum|r| sufficiency pass in the common case) because nothing in the
    codec itself enforces the error bound: a directly-constructed
    artifact can carry codes of any magnitude."""
    r, shape, dtype, step = szlike.sz_decode_residuals(art.base_payload)
    reason = _codes_reason(art, r)
    if reason is not None:
        return reason
    return r, shape, dtype, step


def _codes_reason(art: CompressedArtifact, r: np.ndarray) -> Optional[str]:
    if art.path != "device" and not szlike.codes_fit_int32(r):
        return ("the artifact's residual codes overflow the int32 cumsum "
                "reconstruction (host-path artifact beyond the device "
                "range precondition)")
    return None


def _device_unpack_decompress(art: CompressedArtifact,
                              backend: BackendLike, mesh,
                              device_path: DevicePath
                              ) -> Optional[np.ndarray]:
    """The zero-host-entropy read fast path (DESIGN.md §8) for device-
    path SZP1 artifacts: split the blob into (words, bits) by pointer
    arithmetic, ship them to the device, and run unpack -> reconstruct
    -> edit scatter there. Device-path artifacts were range-checked at
    compress time, so no host-side code inspection is needed. Returns
    None when it cannot serve (backend without ``unpack_codes``, or a
    non-default chunk size — the host decoder handles both)."""
    from ..kernels.pack import CHUNK
    words, bits, shape, dtype, step, chunk = \
        szlike.sz_parse_packed(art.base_payload)
    if chunk != CHUNK:
        return None
    be = _decode_backend(backend, shape, dtype, mesh, device_path)
    if be is None or not hasattr(be, "unpack_codes"):
        return None
    idx, val = codec.decode_edits(art.edit_payload)
    idx, val = _pad_pow2(idx, val, _size_of(shape))
    w_j = _h2d(np.ascontiguousarray(words))
    b_j = _h2d(np.ascontiguousarray(bits))
    f_hat = be.reconstruct(be.unpack_codes(w_j, b_j, shape), step, dtype)
    g = be.scatter_edits(f_hat, _h2d(idx.astype(np.int32)), _h2d(val))
    return _d2h(g)


def decompress_preserving_mss(art: CompressedArtifact,
                              device_path: DevicePath = "auto",
                              backend: BackendLike = "auto",
                              mesh=None) -> np.ndarray:
    """The mirror of the device-resident compress path (DESIGN.md §5):
    host-decode the entropy streams once, then ONE host->device transfer
    of the int32 residual codes, on-device ``backend.reconstruct`` of
    f_hat and scatter-add of the edit deltas (``backend.scatter_edits``),
    and ONE device->host transfer of g. Bitwise identical to
    ``decompress_artifact`` — both reconstructions share the per-dtype
    arithmetic contract (szlike module docstring) and the scatter adds
    the identical f32 deltas at unique indices.

    ``device_path="auto"`` falls back to the host path whenever the
    preconditions fail (non-szlike base, unsupported dtype, residual
    codes beyond the int32 range); ``True`` raises instead; ``False``
    is ``decompress_artifact``. ``mesh`` routes reconstruction and the
    scatter through the slab-sharded SPMD backend."""
    if device_path is False:
        return decompress_artifact(art)
    reason = _device_decode_reason(art)
    if reason is None and getattr(art, "path", "host") == "device" \
            and szlike.sz_blob_entropy(art.base_payload) == "device-pack":
        g = _device_unpack_decompress(art, backend, mesh, device_path)
        if g is not None:
            return g
    decoded = None
    if reason is None:
        decoded = _checked_codes(art)
        if isinstance(decoded, str):
            reason, decoded = decoded, None
    be = None
    if reason is None:
        r, shape, dtype, step = decoded
        be = _decode_backend(backend, shape, dtype, mesh, device_path)
    if reason is not None or be is None:
        if device_path is True:
            raise ValueError(f"device_path=True but {reason}")
        return decompress_artifact(art)

    idx, val = codec.decode_edits(art.edit_payload)
    idx, val = _pad_pow2(idx, val, _size_of(shape))
    r_j = _h2d(np.ascontiguousarray(r, np.int32))
    f_hat = be.reconstruct(r_j, step, dtype)
    g = be.scatter_edits(f_hat, _h2d(idx.astype(np.int32)), _h2d(val))
    return _d2h(g)


def _pow2_at_least(n: int) -> int:
    """Smallest power of two >= n (0 stays 0) — the shared pad-to-pow2
    bound capping jit specializations at ~log2 of the padded dimension
    (edit streams here, batch axes in the stream scheduler)."""
    return 1 << max(n - 1, 0).bit_length() if n else 0


def _pad_pow2(idx_b: np.ndarray, val_b: np.ndarray, fill_idx: int):
    """Pad the edit axis to the next power of two (fill indices drop in
    the scatter) so the jitted scatter specializes on ~log2(V) distinct
    lengths instead of one per edit count — same trick as
    driver.extract_edits on the write side."""
    L = idx_b.shape[-1]
    cap = _pow2_at_least(L)
    if cap == L:
        return idx_b, val_b
    pad = [(0, 0)] * (idx_b.ndim - 1) + [(0, cap - L)]
    return (np.pad(idx_b, pad, constant_values=fill_idx),
            np.pad(val_b, pad, constant_values=0))


def decompress_artifact_batch(arts: Sequence[CompressedArtifact],
                              device_path: DevicePath = "auto",
                              backend: BackendLike = "auto",
                              mesh=None) -> List[np.ndarray]:
    """Batch decompression of many same-shape szlike artifacts, pipelined:
    the entropy streams inflate on host worker threads while each
    already-decoded member's residual codes cross to the device (one
    member-sized h2d each) and its reconstruct + edit scatter dispatch
    asynchronously; g stays device-resident until ONE d2h of the stacked
    batch at the end. Edit streams are decoded up front and padded to a
    shared power-of-two length with out-of-range indices the scatter
    drops. Per-member output is bitwise identical to a solo
    ``decompress_preserving_mss`` / ``decompress_artifact`` call.
    Heterogeneous batches (mixed shapes, dtypes, or bases) decompress
    member-by-member instead; the sharded backend serves each member's
    reconstruct/scatter over the mesh within the same pipeline."""
    arts = list(arts)
    if not arts:
        return []
    a0 = arts[0]
    uniform = all(a.base == a0.base and a.shape == a0.shape
                  and a.dtype == a0.dtype for a in arts)
    if device_path is False or not uniform:
        return [decompress_preserving_mss(a, device_path=device_path,
                                          backend=backend, mesh=mesh)
                for a in arts]
    reason = _device_decode_reason(a0)
    be = None
    if reason is None:
        shape, dtype = tuple(a0.shape), np.dtype(a0.dtype)
        be = _decode_backend(backend, shape, dtype, mesh, device_path)
    if reason is not None or be is None:
        if device_path is True:
            raise ValueError(f"device_path=True but {reason}")
        return [decompress_artifact(a) for a in arts]

    V = _size_of(shape)
    idx_b, val_b, _ = codec.decode_edits_batch(
        [a.edit_payload for a in arts], fill_idx=V)
    idx_b, val_b = _pad_pow2(idx_b, val_b, V)
    idx_j = _h2d(idx_b.astype(np.int32))
    val_j = _h2d(val_b)
    # zero-host-entropy batch fast path (DESIGN.md §8): an all-device-
    # pack device-path batch ships each member's (words, bits) straight
    # to the device — no threaded host inflate stage to pipeline at all
    from ..kernels.pack import CHUNK
    if hasattr(be, "unpack_codes") and all(
            getattr(a, "path", "host") == "device"
            and szlike.sz_blob_entropy(a.base_payload) == "device-pack"
            for a in arts):
        parsed = [szlike.sz_parse_packed(a.base_payload) for a in arts]
        if all(p[5] == CHUNK for p in parsed):
            gs = []
            for i, (words, bits, _, _, step, _) in enumerate(parsed):
                w_j = _h2d(np.ascontiguousarray(words))
                b_j = _h2d(np.ascontiguousarray(bits))
                f_hat = be.reconstruct(
                    be.unpack_codes(w_j, b_j, shape), step, dtype)
                gs.append(be.scatter_edits(f_hat, _member(idx_j, i),
                                           _member(val_j, i)))
            g_host = _d2h(jnp.stack(gs))
            return [g_host[i] for i in range(len(arts))]
    gs = []
    for i, (r, _, _, step) in enumerate(codec.iter_decode_blobs(
            szlike.sz_decode_residuals, [a.base_payload for a in arts])):
        reason = _codes_reason(arts[i], r)
        if reason is not None:
            if device_path is True:
                raise ValueError(f"device_path=True but {reason}")
            return [decompress_artifact(a) for a in arts]
        r_j = _h2d(np.ascontiguousarray(r, np.int32))
        f_hat = be.reconstruct(r_j, step, dtype)
        gs.append(be.scatter_edits(f_hat, _member(idx_j, i),
                                   _member(val_j, i)))
    g_host = _d2h(jnp.stack(gs))
    return [g_host[i] for i in range(len(arts))]


# --- paper metrics (Section 7 / Appendix B) --------------------------------

def overall_compression_ratio(f: np.ndarray, art: CompressedArtifact) -> float:
    """OCR: original bytes / (base payload + edit payload)."""
    return f.nbytes / art.nbytes


def overall_bit_rate(f: np.ndarray, art: CompressedArtifact) -> float:
    """OBR: average bits per data point after combining data + edits."""
    return art.nbytes * 8.0 / f.size


def psnr(f: np.ndarray, g: np.ndarray) -> float:
    """PSNR normalized by the VALUE RANGE max(f) - min(f), as in the paper
    and the SZ/ZFP literature — not max|f|, which wildly inflates the
    score for fields with a large offset (a field in [1000, 1001] would
    report ~60 dB extra) and is not shift-invariant."""
    f64 = np.asarray(f, np.float64)
    mse = float(np.mean((f64 - np.asarray(g, np.float64)) ** 2))
    if mse == 0:
        return float("inf")
    rng = float(np.max(f64) - np.min(f64))
    if rng == 0:
        return float("-inf")     # constant field reconstructed with error
    return 20.0 * np.log10(rng / np.sqrt(mse))
