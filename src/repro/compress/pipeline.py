"""End-to-end MSz-corrected compression pipeline (paper Fig. 3).

compression:   f --base compressor--> payload --decompress--> f_hat
               (f, f_hat) --C/R fix loops--> edits --codec--> edit blob
decompression: payload --> f_hat ; f_hat + edits --> g  (MSS(g) == MSS(f))
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Literal, Optional, Tuple

import numpy as np

from ..core.driver import derive_edits, apply_edits, verify_preservation
from . import codec, szlike, zfplike

BaseName = Literal["szlike", "zfplike"]

_BASES: Dict[str, Tuple[Callable, Callable]] = {
    "szlike": (szlike.sz_compress, szlike.sz_decompress),
    "zfplike": (zfplike.zfp_compress, zfplike.zfp_decompress),
}


@dataclasses.dataclass
class CompressedArtifact:
    base: str
    base_payload: bytes
    edit_payload: bytes
    shape: tuple
    dtype: str
    xi: float
    # bookkeeping for the paper's metrics
    t_base: float = 0.0          # base compressor seconds (t_comp)
    t_fix: float = 0.0           # MSz fix seconds (t_fix)
    edit_ratio: float = 0.0
    fix_iters: int = 0

    @property
    def nbytes(self) -> int:
        return len(self.base_payload) + len(self.edit_payload)


def compress_preserving_mss(f: np.ndarray, xi: float, base: BaseName = "szlike",
                            mode: str = "fused",
                            edit_value_dtype: str = "f4",
                            max_iters: int = 512) -> CompressedArtifact:
    f = np.asarray(f)
    comp, decomp = _BASES[base]
    t0 = time.perf_counter()
    payload = comp(f, xi)
    f_hat = decomp(payload)
    t1 = time.perf_counter()
    res = derive_edits(f, f_hat, xi, mode=mode, max_iters=max_iters)
    if not res.converged:
        raise RuntimeError("MSz fix loops did not converge within max_iters")
    t2 = time.perf_counter()

    blob = codec.encode_edits(res.edits_idx, res.edits_val, edit_value_dtype)
    if edit_value_dtype != "f4":
        # lossy edit storage (beyond-paper): must re-verify exactness and
        # the error bound; fall back to f4 when rounding breaks either.
        idx2, val2 = codec.decode_edits(blob)
        g2 = apply_edits(f_hat, idx2, val2)
        v = verify_preservation(f, g2, xi)
        if not (v["mss_preserved"] and v["bound_ok"]):
            blob = codec.encode_edits(res.edits_idx, res.edits_val, "f4")

    return CompressedArtifact(
        base=base, base_payload=payload, edit_payload=blob,
        shape=f.shape, dtype=str(f.dtype), xi=xi,
        t_base=t1 - t0, t_fix=t2 - t1,
        edit_ratio=res.edit_ratio, fix_iters=res.iters,
    )


def decompress_artifact(art: CompressedArtifact) -> np.ndarray:
    _, decomp = _BASES[art.base]
    f_hat = decomp(art.base_payload)
    idx, val = codec.decode_edits(art.edit_payload)
    return apply_edits(f_hat, idx, val)


# --- paper metrics (Section 7 / Appendix B) --------------------------------

def overall_compression_ratio(f: np.ndarray, art: CompressedArtifact) -> float:
    """OCR: original bytes / (base payload + edit payload)."""
    return f.nbytes / art.nbytes


def overall_bit_rate(f: np.ndarray, art: CompressedArtifact) -> float:
    """OBR: average bits per data point after combining data + edits."""
    return art.nbytes * 8.0 / f.size


def psnr(f: np.ndarray, g: np.ndarray) -> float:
    mse = float(np.mean((f.astype(np.float64) - g.astype(np.float64)) ** 2))
    if mse == 0:
        return float("inf")
    return 20.0 * np.log10(float(np.max(np.abs(f))) / np.sqrt(mse))
