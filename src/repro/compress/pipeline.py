"""End-to-end MSz-corrected compression pipeline (paper Fig. 3).

compression:   f --base compressor--> payload --decompress--> f_hat
               (f, f_hat) --C/R fix loops--> edits --codec--> edit blob
decompression: payload --> f_hat ; f_hat + edits --> g  (MSS(g) == MSS(f))

The fix stage dispatches to a stencil backend (repro.core.backend);
``compress_preserving_mss_batch`` runs many same-shape fields through one
vmapped fix loop (timestep series, ensemble members).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Literal, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.backend import BackendLike
from ..core.driver import (MszResult, apply_edits, derive_edits,
                           derive_edits_batch, verify_preservation)
from . import codec, szlike, zfplike

BaseName = Literal["szlike", "zfplike"]

_BASES: Dict[str, Tuple[Callable, Callable]] = {
    "szlike": (szlike.sz_compress, szlike.sz_decompress),
    "zfplike": (zfplike.zfp_compress, zfplike.zfp_decompress),
}


@dataclasses.dataclass
class CompressedArtifact:
    base: str
    base_payload: bytes
    edit_payload: bytes
    shape: tuple
    dtype: str
    xi: float
    # bookkeeping for the paper's metrics
    t_base: float = 0.0          # base compressor seconds (t_comp)
    t_fix: float = 0.0           # MSz fix seconds (t_fix)
    edit_ratio: float = 0.0
    fix_iters: int = 0
    backend: str = ""            # stencil backend that ran the fix loop

    @property
    def nbytes(self) -> int:
        return len(self.base_payload) + len(self.edit_payload)


def _encode_edits_checked(f: np.ndarray, f_hat: np.ndarray, res: MszResult,
                          xi: float, edit_value_dtype: str) -> bytes:
    """Edit codec with the lossy-storage safety net (beyond-paper): any
    non-f4 edit dtype must re-verify exactness and the error bound; fall
    back to f4 when rounding breaks either."""
    blob = codec.encode_edits(res.edits_idx, res.edits_val, edit_value_dtype)
    if edit_value_dtype != "f4":
        idx2, val2 = codec.decode_edits(blob)
        g2 = apply_edits(f_hat, idx2, val2)
        v = verify_preservation(f, g2, xi)
        if not (v["mss_preserved"] and v["bound_ok"]):
            blob = codec.encode_edits(res.edits_idx, res.edits_val, "f4")
    return blob


def _make_artifact(f: np.ndarray, payload: bytes, blob: bytes, xi: float,
                   base: str, res: MszResult, t_base: float,
                   t_fix: float) -> CompressedArtifact:
    return CompressedArtifact(
        base=base, base_payload=payload, edit_payload=blob,
        shape=f.shape, dtype=str(f.dtype), xi=xi,
        t_base=t_base, t_fix=t_fix,
        edit_ratio=res.edit_ratio, fix_iters=res.iters,
        backend=res.backend,
    )


def compress_preserving_mss(f: np.ndarray, xi: float, base: BaseName = "szlike",
                            mode: str = "fused",
                            edit_value_dtype: str = "f4",
                            max_iters: int = 512,
                            backend: BackendLike = "auto",
                            mesh=None) -> CompressedArtifact:
    """``mesh``: route the fix loop through the slab-sharded SPMD backend
    when the mesh has >= 2 ``data``-axis devices (artifacts stay byte-for-
    byte identical to single-device runs)."""
    f = np.asarray(f)
    comp, decomp = _BASES[base]
    t0 = time.perf_counter()
    payload = comp(f, xi)
    f_hat = decomp(payload)
    t1 = time.perf_counter()
    res = derive_edits(f, f_hat, xi, mode=mode, max_iters=max_iters,
                       backend=backend, mesh=mesh)
    if not res.converged:
        raise RuntimeError("MSz fix loops did not converge within max_iters")
    t2 = time.perf_counter()

    blob = _encode_edits_checked(f, f_hat, res, xi, edit_value_dtype)
    return _make_artifact(f, payload, blob, xi, base, res, t1 - t0, t2 - t1)


def compress_preserving_mss_batch(
        fields: Union[np.ndarray, Sequence[np.ndarray]],
        xi: Union[float, Sequence[float]],
        base: BaseName = "szlike",
        edit_value_dtype: str = "f4",
        max_iters: int = 512,
        backend: BackendLike = "auto",
        mesh=None) -> List[CompressedArtifact]:
    """Batch variant of compress_preserving_mss for many same-shape fields.

    Base compression/decompression runs per member (the codecs are
    host-side), but the MSz fix loops — the dominant cost, Table 1 — run
    as ONE vmapped loop over the whole batch (derive_edits_batch, fused
    mode). Each member's artifact is bitwise identical to a solo
    compress_preserving_mss call; t_fix reports the batch fix time split
    evenly across members.
    """
    fields = [np.asarray(fi) for fi in fields]
    if not fields:
        return []
    if any(fi.shape != fields[0].shape for fi in fields):
        raise ValueError("batch members must share one shape; got "
                         f"{[fi.shape for fi in fields]}")
    B = len(fields)
    xi_arr = np.broadcast_to(np.asarray(xi, np.float64), (B,))
    comp, decomp = _BASES[base]

    payloads, fhats, t_bases = [], [], []
    for fi, xi_i in zip(fields, xi_arr):
        t0 = time.perf_counter()
        payload = comp(fi, float(xi_i))
        fhats.append(decomp(payload))
        t_bases.append(time.perf_counter() - t0)
        payloads.append(payload)

    t0 = time.perf_counter()
    results = derive_edits_batch(np.stack(fields), np.stack(fhats), xi_arr,
                                 max_iters=max_iters, backend=backend,
                                 mesh=mesh)
    t_fix_each = (time.perf_counter() - t0) / B

    arts = []
    for fi, xi_i, payload, f_hat, res, t_base in zip(
            fields, xi_arr, payloads, fhats, results, t_bases):
        if not res.converged:
            raise RuntimeError(
                "MSz fix loops did not converge within max_iters")
        blob = _encode_edits_checked(fi, f_hat, res, float(xi_i),
                                     edit_value_dtype)
        arts.append(_make_artifact(fi, payload, blob, float(xi_i), base, res,
                                   t_base, t_fix_each))
    return arts


def decompress_artifact(art: CompressedArtifact) -> np.ndarray:
    _, decomp = _BASES[art.base]
    f_hat = decomp(art.base_payload)
    idx, val = codec.decode_edits(art.edit_payload)
    return apply_edits(f_hat, idx, val)


# --- paper metrics (Section 7 / Appendix B) --------------------------------

def overall_compression_ratio(f: np.ndarray, art: CompressedArtifact) -> float:
    """OCR: original bytes / (base payload + edit payload)."""
    return f.nbytes / art.nbytes


def overall_bit_rate(f: np.ndarray, art: CompressedArtifact) -> float:
    """OBR: average bits per data point after combining data + edits."""
    return art.nbytes * 8.0 / f.size


def psnr(f: np.ndarray, g: np.ndarray) -> float:
    mse = float(np.mean((f.astype(np.float64) - g.astype(np.float64)) ** 2))
    if mse == 0:
        return float("inf")
    return 20.0 * np.log10(float(np.max(np.abs(f))) / np.sqrt(mse))
