"""SZ-like error-bounded lossy compressor (prediction + linear-scaling
quantization), reimplemented with the *dual-quantization* parallel
reformulation used by GPU SZ implementations (cuSZ):

  1. linear-scaling quantization   q = round(f / (2*xi))   (|f - 2*xi*q| <= xi)
  2. Lorenzo prediction IN THE INTEGER DOMAIN: the residual is the d-D mixed
     first difference of q, which is exact in integers, so prediction is
     embarrassingly parallel both ways — decompression is d nested cumsums
     (an associative scan) instead of SZ's sequential reconstruction.
  3. residual entropy coding: small residuals -> int8 stream + escape list,
     then DEFLATE (stand-in for SZ's Huffman+ZSTD stage).

This is the paper's 'base compressor #1' baseline. The host path
(sz_compress/sz_decompress) is exact int64 numpy; the jit'd JAX path
(sz_transform/sz_inverse) is the TPU-target hot loop, int32-bounded:
intermediate cumsums reach 2^d * max|q|, so it requires
range(f)/xi < 2^28 — asserted, and always true for the paper's bounds.
"""
from __future__ import annotations

import io
import struct
import zlib
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

_MAGIC = b"SZJ1"


# ---------------------------------------------------------------------------
# JAX hot path (TPU target; also what the Pallas kernel in repro.kernels
# implements block-wise)
# ---------------------------------------------------------------------------

def _lorenzo_residual_jnp(q: jnp.ndarray) -> jnp.ndarray:
    r = q
    for ax in range(q.ndim):
        shifted = jnp.concatenate(
            [jnp.zeros_like(jax.lax.slice_in_dim(r, 0, 1, axis=ax)),
             jax.lax.slice_in_dim(r, 0, r.shape[ax] - 1, axis=ax)], axis=ax)
        r = r - shifted
    return r


@jax.jit
def sz_transform(f: jnp.ndarray, step) -> jnp.ndarray:
    """quantize + integer Lorenzo -> int32 residual codes."""
    q = jnp.round(f / step).astype(jnp.int32)
    return _lorenzo_residual_jnp(q)


@jax.jit
def sz_inverse(r: jnp.ndarray, step) -> jnp.ndarray:
    q = r
    for ax in range(r.ndim):
        q = jnp.cumsum(q, axis=ax, dtype=jnp.int32)
    return q.astype(jnp.float32) * jnp.float32(step)


# ---------------------------------------------------------------------------
# exact host path (what actually backs the byte-level codec)
# ---------------------------------------------------------------------------

def _lorenzo_residual_np(q: np.ndarray) -> np.ndarray:
    r = q
    for ax in range(q.ndim):
        pad = np.zeros_like(np.take(r, [0], axis=ax))
        shifted = np.concatenate([pad, np.take(r, range(r.shape[ax] - 1), axis=ax)], axis=ax)
        r = r - shifted
    return r


def _pack_residuals(r: np.ndarray) -> bytes:
    """int8 main stream with int64 escape side-channel, DEFLATE'd."""
    flat = r.reshape(-1)
    small = (flat >= -127) & (flat <= 127)
    main = np.where(small, flat, -128).astype(np.int8)
    esc_idx = np.flatnonzero(~small).astype(np.int64)
    esc_val = flat[esc_idx].astype(np.int64)
    payload = io.BytesIO()
    for chunk in (main.tobytes(), esc_idx.tobytes(), esc_val.tobytes()):
        comp = zlib.compress(chunk, 6)
        payload.write(struct.pack("<Q", len(comp)))
        payload.write(comp)
    return payload.getvalue()


def _unpack_residuals(buf: bytes, n: int) -> np.ndarray:
    view = memoryview(buf)
    parts = []
    off = 0
    for _ in range(3):
        (ln,) = struct.unpack_from("<Q", view, off)
        off += 8
        parts.append(zlib.decompress(view[off:off + ln]))
        off += ln
    main = np.frombuffer(parts[0], np.int8).astype(np.int64)
    esc_idx = np.frombuffer(parts[1], np.int64)
    esc_val = np.frombuffer(parts[2], np.int64)
    out = main.copy()
    if esc_idx.size:
        out[esc_idx] = esc_val
    return out[:n]


def sz_compress(f: np.ndarray, xi: float) -> bytes:
    """Compress with absolute error bound xi. Self-describing blob."""
    f = np.asarray(f)
    if f.dtype not in (np.float32, np.float64):
        raise TypeError(f"float field expected, got {f.dtype}")
    # headroom for the final f32 cast (see zfplike.zfp_compress)
    if f.dtype == np.float32 and f.size:
        xi = max(xi - float(np.max(np.abs(f))) * 2.0 ** -22, xi * 0.5)
    step = np.float64(2.0 * xi)
    q = np.round(f.astype(np.float64) / step).astype(np.int64)
    r = _lorenzo_residual_np(q)
    body = _pack_residuals(r)
    hdr = struct.pack("<4sBBdQ", _MAGIC, f.ndim,
                      0 if f.dtype == np.float32 else 1, float(step), f.size)
    dims = struct.pack(f"<{f.ndim}Q", *f.shape)
    return hdr + dims + body


def sz_decompress(blob: bytes) -> np.ndarray:
    magic, ndim, dt, step, size = struct.unpack_from("<4sBBdQ", blob, 0)
    if magic != _MAGIC:
        raise ValueError("not an SZ-like blob")
    off = struct.calcsize("<4sBBdQ")
    shape = struct.unpack_from(f"<{ndim}Q", blob, off)
    off += 8 * ndim
    r = _unpack_residuals(blob[off:], size).reshape(shape)
    q = r
    for ax in range(len(shape)):
        q = np.cumsum(q, axis=ax, dtype=np.int64)
    out = q.astype(np.float64) * step
    return out.astype(np.float32 if dt == 0 else np.float64)


def sz_roundtrip(f: np.ndarray, xi: float) -> Tuple[np.ndarray, int]:
    blob = sz_compress(f, xi)
    return sz_decompress(blob), len(blob)
