"""SZ-like error-bounded lossy compressor (prediction + linear-scaling
quantization), reimplemented with the *dual-quantization* parallel
reformulation used by GPU SZ implementations (cuSZ):

  1. linear-scaling quantization   q = round(f / step),  step = 2*xi_eff
     (|f - step*q| <= xi after headroom, see below)
  2. Lorenzo prediction IN THE INTEGER DOMAIN: the residual is the d-D mixed
     first difference of q, which is exact in integers, so prediction is
     embarrassingly parallel both ways — decompression is d nested cumsums
     (an associative scan) instead of SZ's sequential reconstruction.
  3. residual entropy coding: small residuals -> int8 stream + escape list,
     then DEFLATE (stand-in for SZ's Huffman+ZSTD stage).

This is the paper's 'base compressor #1' baseline, and since the
device-resident pipeline (DESIGN.md §4) the host and device paths share
ONE arithmetic contract per dtype so they are bitwise interchangeable:

  * quantization and reconstruction run in the FIELD'S dtype (f32 fields:
    f32 division/round and f32 multiply — numpy on host, XLA on device —
    both IEEE-754 round-to-nearest-even, so host and device agree bit for
    bit);
  * integer work (Lorenzo residual, cumsum inverse) is exact in any width;
    the host codec uses int64, the device path int32.

The int32 device path (sz_transform/sz_inverse, backed by the Pallas
kernel in repro.kernels.lorenzo) therefore requires the residual codes
and every intermediate cumsum to fit int32: intermediates reach
2^d * max|q| with max|q| ~= max|f|/step, so it requires
max|f|/xi < 2^28 (for the paper's field/bound regimes range(f)/xi and
max|f|/xi coincide within a small factor; both are far below 2^28).
f32 fields bind EARLIER, at max|f|/xi < 2^21: past that the quantization
quotient f/step leaves f32 rounding precision (and past ~2^23 no f32
f_hat can hold the bound at all, so the tighter limit forfeits nothing).
``check_int32_range`` validates the dtype's limit with a clear error —
callers of the device path (compress.pipeline) invoke it at runtime;
``sz_transform`` itself also checks when handed a host (numpy) array.
f64 fields keep f64 host arithmetic; the device transform serves them
only when jax x64 mode is enabled.
"""
from __future__ import annotations

import io
import struct
import zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# SZJ2: the dequantization arithmetic runs in the field's dtype (the
# shared host/device contract above). SZJ1 blobs used f64-multiply-then-
# cast and would silently reconstruct a different f_hat — refuse them.
_MAGIC = b"SZJ2"
# SZP1: same header, residuals carried as the chunked-bitplane device
# codec's (bits table, uint32 word stream) instead of DEFLATE chunks
# (repro.kernels.pack; DESIGN.md §8)
_MAGIC_PACK = b"SZP1"

#: residual entropy codecs a blob can carry: "deflate" (SZJ2, host
#: zlib — the compatibility default) and "device-pack" (SZP1, the
#: chunked-bitplane codec that also runs fully on device)
ENTROPIES = ("deflate", "device-pack")

# intermediate cumsums of the int32 inverse reach 2^d * max|q| (d <= 3),
# so max|q| < 2^27  <=>  max|f|/xi < 2^28 keeps everything inside int32
INT32_RANGE_LIMIT = 2.0 ** 28
# f32 fields bind earlier: the quantization quotient f/step must round
# exactly in f32 (quotient < 2^22 keeps the fl-division error under half
# a unit and inside the 2^-22*max|f| headroom), so max|f|/xi < 2^21.
# Beyond ~2^23 an f32 field cannot hold the bound in ANY arithmetic
# (xi drops below max|f|'s ulp) — the limit forfeits no well-posed input.
F32_RANGE_LIMIT = 2.0 ** 21


def device_range_limit(dtype) -> float:
    """max|f|/xi ceiling of the device path for fields of ``dtype``."""
    return F32_RANGE_LIMIT if np.dtype(dtype) == np.float32 \
        else INT32_RANGE_LIMIT


def effective_step(f: np.ndarray, xi: float,
                   amax: Optional[float] = None) -> float:
    """The quantization step actually used for ``f`` at bound ``xi``.

    f32 fields reserve headroom for the dtype-arithmetic reconstruction
    (quantize + reconstruct in f32 costs up to ~3 ulp relative to exact
    arithmetic, hence 2^-22; zfplike reserves its own — smaller,
    half-ulp — headroom for its single final f32 cast), and the step
    itself is an f32-exact value so host and device multiply by the
    identical scalar. ``amax``: pass a precomputed max|f| to skip the
    field scan.
    """
    f = np.asarray(f)
    if f.dtype == np.float32 and f.size:
        if amax is None:
            amax = float(np.max(np.abs(f)))
        xi = max(xi - amax * 2.0 ** -22, xi * 0.5)
    step = np.float64(2.0 * xi)
    if f.dtype == np.float32:
        step = np.float64(np.float32(step))
    return float(step)


def check_int32_range(f: np.ndarray, xi: float,
                      amax: Optional[float] = None) -> None:
    """Validate the device path's range precondition (module docstring):
    quantized magnitudes and their d-D cumsum intermediates must fit
    int32 — max|f|/xi < 2^28 — and f32 fields must additionally keep the
    quantization quotient inside f32 rounding precision — max|f|/xi <
    2^21, the binding limit. Raises ValueError otherwise. ``amax``: pass
    a precomputed max|f| to skip the field scan."""
    f = np.asarray(f)
    if f.size == 0:
        return
    if xi <= 0:
        raise ValueError(f"error bound must be positive, got xi={xi!r}")
    if amax is None:
        amax = float(np.max(np.abs(f)))
    limit = device_range_limit(f.dtype)
    if amax / xi >= limit:
        why = ("the f32 quantization quotient would exceed f32 rounding "
               "precision" if limit == F32_RANGE_LIMIT else
               "quantized codes would overflow the int32 cumsum "
               "reconstruction")
        raise ValueError(
            f"device path precondition violated: max|f|/xi = "
            f"{amax / xi:.3g} >= 2^{int(np.log2(limit))}; {why}. Use the "
            "host path (device_path=False) or a looser error bound.")


# ---------------------------------------------------------------------------
# JAX hot path (TPU target; also what the Pallas kernel in repro.kernels
# implements block-wise). Same arithmetic contract as the host codec:
# bitwise-equal f_hat within the int32 range precondition.
# ---------------------------------------------------------------------------

def _lorenzo_residual_jnp(q: jnp.ndarray) -> jnp.ndarray:
    r = q
    for ax in range(q.ndim):
        shifted = jnp.concatenate(
            [jnp.zeros_like(jax.lax.slice_in_dim(r, 0, 1, axis=ax)),
             jax.lax.slice_in_dim(r, 0, r.shape[ax] - 1, axis=ax)], axis=ax)
        r = r - shifted
    return r


@jax.jit
def _sz_transform_jit(f: jnp.ndarray, step) -> jnp.ndarray:
    q = jnp.round(f / step).astype(jnp.int32)
    return _lorenzo_residual_jnp(q)


def sz_transform(f, step) -> jnp.ndarray:
    """quantize + integer Lorenzo -> int32 residual codes.

    ``step`` should be a scalar of f's dtype (a python float behaves as
    one for f32 fields). Host (numpy) inputs are range-checked against
    the device-range precondition; device-resident or traced callers
    must validate themselves via ``check_int32_range`` — the check is a
    host scan and must not force a device->host pull of the field.
    """
    if isinstance(f, np.ndarray) and not isinstance(step, jax.core.Tracer):
        check_int32_range(f, float(np.asarray(step)) / 2.0)
    return _sz_transform_jit(f, step)


def int32_cumsum(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Exact int32 cumsum along ``axis``. The leading axis of a >= 2D
    array runs as an O(n) ``lax.scan`` with a slab carry — XLA's
    log-depth cumsum rewrite strides badly there (~2x slower at 256^3 on
    CPU) — the rest as XLA's native cumsum. Integer adds are exact, so
    both formulations are bitwise identical."""
    x = x.astype(jnp.int32)      # both branches accumulate in int32
    if axis == 0 and x.ndim > 1:
        def step(c, row):
            s = c + row
            return s, s
        _, out = jax.lax.scan(step, jnp.zeros_like(x[0]), x)
        return out
    return jnp.cumsum(x, axis=axis, dtype=jnp.int32)


@jax.jit
def sz_inverse(r: jnp.ndarray, step) -> jnp.ndarray:
    """int32 residual codes -> reconstructed field, in step's dtype
    (weakly-typed python floats reconstruct f32)."""
    q = r
    for ax in range(r.ndim):
        # mszlint: disable=int32-range -- every codec entry gates on
        # codes_fit_int32/check_int32_range before reaching this decode
        q = int32_cumsum(q, ax)
    step = jnp.asarray(step)
    return q.astype(step.dtype) * step


# ---------------------------------------------------------------------------
# exact host path (what actually backs the byte-level codec)
# ---------------------------------------------------------------------------

def _lorenzo_residual_np(q: np.ndarray) -> np.ndarray:
    if q.size == 0:
        return q
    r = q
    for ax in range(q.ndim):
        pad = np.zeros_like(np.take(r, [0], axis=ax))
        shifted = np.concatenate([pad, np.take(r, range(r.shape[ax] - 1), axis=ax)], axis=ax)
        r = r - shifted
    return r


def _pack_residuals(r: np.ndarray) -> bytes:
    """int8 main stream with int64 escape side-channel, DEFLATE'd."""
    flat = r.reshape(-1).astype(np.int64)
    small = (flat >= -127) & (flat <= 127)
    main = np.where(small, flat, -128).astype(np.int8)
    esc_idx = np.flatnonzero(~small).astype(np.int64)
    esc_val = flat[esc_idx].astype(np.int64)
    payload = io.BytesIO()
    for chunk in (main.tobytes(), esc_idx.tobytes(), esc_val.tobytes()):
        comp = zlib.compress(chunk, 6)
        payload.write(struct.pack("<Q", len(comp)))
        payload.write(comp)
    return payload.getvalue()


def _unpack_residuals(buf: bytes, n: int) -> np.ndarray:
    view = memoryview(buf)
    parts = []
    off = 0
    for _ in range(3):
        (ln,) = struct.unpack_from("<Q", view, off)
        off += 8
        parts.append(zlib.decompress(view[off:off + ln]))
        off += ln
    main = np.frombuffer(parts[0], np.int8).astype(np.int64)
    esc_idx = np.frombuffer(parts[1], np.int64)
    esc_val = np.frombuffer(parts[2], np.int64)
    out = main.copy()
    if esc_idx.size:
        out[esc_idx] = esc_val
    return out[:n]


def check_entropy(entropy: str) -> None:
    """Validate a residual entropy codec name against ``ENTROPIES``."""
    if entropy not in ENTROPIES:
        raise ValueError(
            f"unknown entropy codec {entropy!r}; expected one of "
            f"{ENTROPIES}")


def _szlike_header(magic: bytes, shape: Tuple[int, ...], dtype,
                   step: float) -> bytes:
    dtype = np.dtype(dtype)
    ndim = len(shape)
    size = int(np.prod(shape, dtype=np.int64)) if shape else 1
    hdr = struct.pack("<4sBBdQ", magic, ndim,
                      0 if dtype == np.float32 else 1, float(step), size)
    return hdr + struct.pack(f"<{ndim}Q", *shape)


def sz_encode_residuals(r: np.ndarray, shape: Tuple[int, ...],
                        dtype, step: float, *,
                        entropy: str = "deflate") -> bytes:
    """Serialize Lorenzo residual codes into the self-describing SZ-like
    blob. The single entropy-coding entry point for BOTH paths: the host
    codec packs its own int64 residuals, the device pipeline packs the
    int32 codes pulled off the device — identical codes give identical
    bytes. ``entropy`` picks the residual codec (``ENTROPIES``);
    "device-pack" runs the chunked-bitplane packer's numpy mirror here
    (the device pipeline hands its already-packed stream to
    ``sz_encode_packed`` directly and skips this)."""
    check_entropy(entropy)
    if entropy == "device-pack":
        from ..kernels import pack
        words, bits = pack.pack_codes_host(np.asarray(r))
        return sz_encode_packed(words, bits, shape, dtype, step)
    return _szlike_header(_MAGIC, shape, dtype, step) \
        + _pack_residuals(np.asarray(r))


def sz_encode_packed(words: np.ndarray, bits: np.ndarray,
                     shape: Tuple[int, ...], dtype, step: float, *,
                     chunk: Optional[int] = None) -> bytes:
    """Serialize an already-packed chunked-bitplane stream (from any of
    the ``repro.kernels.pack`` codecs — all bitwise identical) into the
    SZP1 blob: the SZJ2-shaped header, then ``<IIQ`` (chunk size, chunk
    count, word count), the per-chunk bit widths as uint8, and the
    little-endian uint32 word stream. Pure byte assembly — the entropy
    work already happened wherever the stream was packed."""
    from ..kernels import pack
    if chunk is None:
        chunk = pack.CHUNK
    words = np.ascontiguousarray(np.asarray(words, np.uint32))
    bits = np.asarray(bits)
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    n_chunks = -(-n // chunk) if n else 0
    if bits.size != n_chunks:
        raise ValueError(
            f"bit-width table has {bits.size} chunks, expected "
            f"{n_chunks} for shape {shape} at chunk={chunk}")
    sub = struct.pack("<IIQ", chunk, n_chunks, words.size)
    return _szlike_header(_MAGIC_PACK, shape, dtype, step) + sub \
        + bits.astype(np.uint8).tobytes() \
        + words.astype("<u4").tobytes()


def _parse_header(blob: bytes):
    hdr = struct.calcsize("<4sBBdQ")
    if len(blob) < hdr:
        raise ValueError(
            f"truncated SZ-like blob: {len(blob)} bytes, header needs {hdr}")
    magic, ndim, dt, step, size = struct.unpack_from("<4sBBdQ", blob, 0)
    off = hdr
    if len(blob) < off + 8 * ndim:
        raise ValueError(
            f"truncated SZ-like blob: {len(blob)} bytes, {ndim}-d header "
            f"needs {off + 8 * ndim}")
    shape = struct.unpack_from(f"<{ndim}Q", blob, off)
    return magic, tuple(int(s) for s in shape), \
        np.dtype(np.float32 if dt == 0 else np.float64), float(step), \
        int(size), off + 8 * ndim


def sz_blob_entropy(blob: bytes) -> str:
    """Which residual entropy codec an SZ-like blob carries ("deflate"
    or "device-pack") — the read side's codec negotiation hook: callers
    route SZP1 payloads to the on-device unpacker without touching the
    byte stream."""
    magic = bytes(blob[:4])
    if magic == _MAGIC:
        return "deflate"
    if magic == _MAGIC_PACK:
        return "device-pack"
    raise ValueError("not an SZ-like blob")


def sz_parse_packed(blob: bytes
                    ) -> Tuple[np.ndarray, np.ndarray, Tuple[int, ...],
                               np.dtype, float, int]:
    """Split an SZP1 blob into ``(words, bits, shape, dtype, step,
    chunk)`` WITHOUT unpacking codes — pure pointer arithmetic, so the
    device read path can ship words/bits to the accelerator with zero
    host entropy work. Header lengths are validated against
    ``len(blob)``: truncated or over-long blobs are hard errors."""
    magic, shape, dtype, step, size, off = _parse_header(blob)
    if magic != _MAGIC_PACK:
        raise ValueError("not a packed (SZP1) SZ-like blob")
    sub = struct.calcsize("<IIQ")
    if len(blob) < off + sub:
        raise ValueError(
            f"SZP1 blob is {len(blob)} bytes, too short for its "
            "pack sub-header (truncated blob)")
    chunk, n_chunks, n_words = struct.unpack_from("<IIQ", blob, off)
    off += sub
    expect_chunks = (-(-size // chunk) if size else 0) if chunk else -1
    if n_chunks != expect_chunks:
        raise ValueError(
            f"SZP1 header: {n_chunks} chunks inconsistent with "
            f"{size} codes at chunk={chunk}")
    end = off + n_chunks + 4 * n_words
    if end != len(blob):
        raise ValueError(
            f"SZP1 blob is {len(blob)} bytes, header demands {end} "
            "(truncated or over-long blob)")
    bits = np.frombuffer(blob, np.uint8, n_chunks, off).astype(np.int32)
    words = np.frombuffer(blob, "<u4", n_words, off + n_chunks)
    words = words.astype(np.uint32, copy=False)
    return words, bits, shape, dtype, step, int(chunk)


def sz_compress(f: np.ndarray, xi: float, *,
                entropy: str = "deflate") -> bytes:
    """Compress with absolute error bound xi. Self-describing blob;
    ``entropy`` picks the residual codec (see ``ENTROPIES``)."""
    f = np.asarray(f)
    if f.dtype not in (np.float32, np.float64):
        raise TypeError(f"float field expected, got {f.dtype}")
    if xi <= 0:
        # linear-scaling quantization has no lossless mode: step = 2*xi
        # degenerates and q = round(f/0) is garbage, so fail loudly here
        # instead of emitting a blob that cannot hold any bound
        raise ValueError(
            f"error bound must be positive for the SZ-like codec, got "
            f"xi={xi!r} (linear-scaling quantization has no lossless mode)")
    step = effective_step(f, xi)
    if f.dtype == np.float32:
        # canonical f32 arithmetic — bitwise-shared with the device path
        q = np.round(f / np.float32(step)).astype(np.int64)
    else:
        q = np.round(f.astype(np.float64) / step).astype(np.int64)
    r = _lorenzo_residual_np(q)
    return sz_encode_residuals(r, f.shape, f.dtype, step, entropy=entropy)


def sz_decode_residuals(blob: bytes
                        ) -> Tuple[np.ndarray, Tuple[int, ...], np.dtype,
                                   float]:
    """Entropy-decode an SZ-like blob into ``(r, shape, dtype, step)``
    WITHOUT reconstructing: ``r`` is the int64 Lorenzo residual-code
    array. This is the host half of the device decompression path
    (DESIGN.md §5) — the byte-stream-sequential DEFLATE decode runs once
    on the host, and everything downstream (cumsum reconstruction,
    dequantization, edit scatter) can stay on device. Dispatches on the
    blob magic: SZP1 (device-pack) payloads decode through the packer's
    numpy mirror, so every consumer of this function reads both codecs
    transparently."""
    magic, shape, dtype, step, size, off = _parse_header(blob)
    if magic == _MAGIC_PACK:
        from ..kernels import pack
        words, bits, shape, dtype, step, chunk = sz_parse_packed(blob)
        r = pack.unpack_codes_host(words, bits, size, chunk) \
            .astype(np.int64).reshape(shape)
        return r, shape, dtype, step
    if magic != _MAGIC:
        raise ValueError("not an SZ-like blob")
    r = _unpack_residuals(blob[off:], size).reshape(shape)
    return r, shape, dtype, step


def codes_fit_int32(r: np.ndarray) -> bool:
    """Sound decode-side precondition of the int32 device reconstruction:
    every intermediate of the d nested cumsums (each axis pass's full
    array — whose elements ARE that axis's running prefixes) must fit
    int32. Compress-time artifacts from the device path satisfy this by
    construction (``check_int32_range``); host-path artifacts can carry
    arbitrarily large codes, so the device decode validates the decoded
    stream itself. Two tiers: every intermediate is a box-prefix sum of
    r entries, so ``sum|r| < 2^31`` proves all of them fit in one cheap
    vectorized pass (typical Lorenzo residuals are tiny, so this is the
    common exit); only an inconclusive sum pays the exact int64 cumsum
    sweep per axis — still far cheaper than the DEFLATE decode that
    precedes it."""
    q = np.asarray(r, np.int64)
    if q.size == 0:
        return True
    lim = np.int64(2 ** 31 - 1)
    # f64 total is within ~n*eps relative error; the margin keeps the
    # shortcut strictly sufficient
    total = float(np.sum(np.abs(q), dtype=np.float64))
    if total * (1 + 1e-6) < float(lim):
        return True
    for ax in range(q.ndim):
        q = np.cumsum(q, axis=ax, dtype=np.int64)
        if np.max(np.abs(q)) > lim:
            return False
    return True


def sz_decompress(blob: bytes) -> np.ndarray:
    """Host-side inverse of ``sz_compress``: entropy-decode the
    residual codes and reconstruct f_hat (d nested cumsums + dequant;
    bitwise equal to the device path's ``backend.reconstruct``)."""
    r, shape, dtype, step = sz_decode_residuals(blob)
    q = r
    for ax in range(len(shape)):
        q = np.cumsum(q, axis=ax, dtype=np.int64)
    if dtype == np.float32:
        # canonical f32 reconstruction (matches sz_inverse bit for bit)
        return q.astype(np.float32) * np.float32(step)
    return q.astype(np.float64) * step


def sz_roundtrip(f: np.ndarray, xi: float) -> Tuple[np.ndarray, int]:
    """Compress + decompress in one call: (f_hat, compressed bytes) —
    the bench/test convenience for the SZ-like base."""
    blob = sz_compress(f, xi)
    return sz_decompress(blob), len(blob)
