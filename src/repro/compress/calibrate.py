"""One-shot calibration of the stream's fused-vs-pipelined fix policy.

``CompressStream`` has two ways to run a coalesced batch's fix loops:
ONE batched while_loop over all members (``_device_batch_stage`` —
amortizes dispatch overhead, but every member computes until the slowest
converges, and the active-member compaction that recovers most of that
waste still pays per-round gather/scatter), or per-member solo loops
behind a shared vmapped transform (``_device_pipelined_stage`` — each
member stops exactly at its own convergence, but pays a full dispatch).
The crossover is a machine property, not a constant: it moves with
dispatch latency, with whether the Pallas stencils interpret or lower,
and with the platform's step throughput. Earlier revisions hardcoded it
at 16^3 voxels; this module measures it.

Cost model (per batch member with V voxels, fitted from probe runs):

* pipelined:  ``O + s*V``  — per-dispatch overhead O plus the solo
  per-voxel step cost s (two probe sizes separate O from s);
* fused:      ``sv*V``     — the *marginal* per-voxel cost of one more
  member inside the batched while_loop (a B=2 run minus the solo run).

Fusing a member wins while ``O + s*V > sv*V``, i.e. for
``V < O / (sv - s)``; when the batched lane is no more expensive than
the solo step (``sv <= s``) fusing always wins. The measured threshold
is clamped to ``CLAMP`` (2^9..2^21 voxels) so one noisy probe can never
push the policy into a pathological regime, and cached per
(backend name, dtype, jax platform) — calibration runs once per
process, not once per stream.

``MSZ_FUSED_FIX_VOXELS`` overrides everything (an explicit integer
voxel threshold; useful for pinning the policy in CI or benchmarking a
specific mode), and an explicit ``fused_fix_voxels=<int>`` stream
argument overrides even that.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

ENV_VAR = "MSZ_FUSED_FIX_VOXELS"
CLAMP = (1 << 9, 1 << 21)
#: probe fields: two sizes to separate per-dispatch overhead from
#: per-voxel step cost (both converge in one fix iteration, so timings
#: compare one step plus overhead, never iteration-count noise)
PROBES = ((8, 8, 8), (16, 16, 16))
_REPS = 3

#: number of real measurements taken (not env/cache hits) — lets tests
#: assert the cache actually short-circuits repeat calls
measure_count = 0  # guarded-by: _lock


@dataclasses.dataclass(frozen=True)
class FixCalibration:
    """One calibration outcome: the policy threshold plus the fitted
    model terms behind it (zeros when ``source == "env"``)."""
    threshold_voxels: int     # fuse members with V <= this many voxels
    overhead_s: float         # fitted per-dispatch overhead O
    solo_voxel_s: float       # fitted solo per-voxel step cost s
    batched_voxel_s: float    # marginal batched per-voxel cost sv
    source: str               # "env" | "measured"


_cache: Dict[Tuple, FixCalibration] = {}  # guarded-by: _lock
_lock = threading.Lock()


def clear_cache() -> None:
    """Drop every cached measurement (tests; a live process never
    needs this — the machine does not change under it)."""
    with _lock:
        _cache.clear()


def _env_threshold() -> Optional[int]:
    raw = os.environ.get(ENV_VAR, "").strip()
    if not raw:
        return None
    try:
        v = int(raw)
    except ValueError:
        raise ValueError(
            f"{ENV_VAR} must be an integer voxel threshold, got {raw!r}"
        ) from None
    if v < 0:
        raise ValueError(f"{ENV_VAR} must be >= 0, got {v}")
    return v


def _time_best(fn, reps: int = _REPS) -> float:
    """Best-of-``reps`` wall time of ``fn`` after one untimed warm-up
    call (the warm-up absorbs trace + compile; min-of-N is the robust
    estimator for a fixed-work measurement under scheduler noise).

    The timed reps run under ``debug.no_recompiles()``: a recompile in
    the measured region is exactly the PR 7 calibration bug (a cache
    key missing a policy dimension makes every "warm" reconsultation
    retrace), and it corrupts the fitted model rather than failing — so
    the sanitizer turns it into a hard error."""
    from ..debug import no_recompiles
    fn()
    best = float("inf")
    with no_recompiles(label="calibrate._time_best"):
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
    return best


def _measure(be, dtype) -> FixCalibration:
    global measure_count
    import jax
    import jax.numpy as jnp

    from ..core import fixes

    with _lock:
        measure_count += 1
    rng = np.random.default_rng(0)
    t_solo = []
    probes = []
    for shape in PROBES:
        f = jnp.asarray(rng.standard_normal(shape).astype(dtype))
        topo = fixes.field_topology(f, 0.1)
        probes.append((f, topo))

        def run(f=f, topo=topo):
            jax.block_until_ready(
                fixes.fused_fix(f, topo, max_iters=8, backend=be)[0])

        t_solo.append(_time_best(run))

    v1, v2 = (int(np.prod(p)) for p in PROBES)
    s = max((t_solo[1] - t_solo[0]) / (v2 - v1), 0.0)
    overhead = max(t_solo[0] - s * v1, 0.0)

    # marginal cost of a second member in the batched while_loop, at the
    # larger probe (identical members => identical iteration counts, so
    # the difference is pure lane cost, not straggler wait)
    f2, topo2 = probes[1]
    g_b = jnp.stack([f2, f2])
    topo_b = jax.tree_util.tree_map(lambda x: jnp.stack([x, x]), topo2)

    def run_b2():
        jax.block_until_ready(
            fixes.fused_fix_batch(g_b, topo_b, max_iters=8, backend=be,
                                  batching="fused")[0])

    sv = max((_time_best(run_b2) - t_solo[1]) / v2, 0.0)

    if sv <= s:                     # batched lane free or cheaper: always fuse
        thr = CLAMP[1]
    else:
        thr = int(overhead / (sv - s))
    thr = max(CLAMP[0], min(CLAMP[1], thr))
    return FixCalibration(threshold_voxels=thr, overhead_s=overhead,
                          solo_voxel_s=s, batched_voxel_s=sv,
                          source="measured")


def fused_fix_threshold(backend, dtype=np.float32) -> FixCalibration:
    """The fused-vs-pipelined voxel threshold for ``backend`` on this
    machine: the ``MSZ_FUSED_FIX_VOXELS`` override when set, else the
    cached measurement for (backend name, dtype, jax platform), else a
    fresh probe run (see module docstring for the model).

    ``backend`` is a resolved stencil backend instance (or a registry
    name); distributed backends never reach this policy — the stream
    always batch-dispatches them since their fix loops run members
    sequentially either way."""
    env = _env_threshold()
    if env is not None:
        return FixCalibration(threshold_voxels=env, overhead_s=0.0,
                              solo_voxel_s=0.0, batched_voxel_s=0.0,
                              source="env")
    import jax

    if isinstance(backend, str):
        from ..core.backend import resolve_backend
        backend = resolve_backend(backend, PROBES[0], np.dtype(dtype))
    # the resolved Pallas interpret decision is part of the key: a
    # Pallas backend running interpreted (CPU, or MSZ_PALLAS_INTERPRET=1)
    # is orders of magnitude slower per iteration than the same backend
    # compiled, so a threshold measured under one policy is wrong for
    # the other — and both can occur in one process when the policy env
    # var changes between calls
    interp = bool(backend._interpret()) if hasattr(backend, "_interpret") \
        else None
    key = (getattr(backend, "name", str(backend)), np.dtype(dtype).str,
           jax.default_backend(), interp)
    with _lock:
        hit = _cache.get(key)
    if hit is not None:
        return hit
    cal = _measure(backend, np.dtype(dtype))
    with _lock:
        return _cache.setdefault(key, cal)
