"""ZFP-like error-bounded transform compressor (the paper's 'base
compressor #2' baseline), reimplemented in JAX/numpy.

Follows ZFP's structure (Lindstrom 2014):
  * partition into 4^d blocks (edge blocks padded by replication),
  * per-block block-floating-point: align to the block's max exponent,
  * ZFP's exact integer lifting transform along each dimension
    (the non-orthogonal decorrelating transform from the reference codec),
  * error-bounded bit-plane truncation: drop the b lowest bit planes where
    b is the largest value keeping `gain * 2^b * scale <= xi` and `gain`
    is the numerically-computed Linf amplification of the inverse
    transform — this gives a hard absolute error bound like ZFP's
    fixed-accuracy mode,
  * DEFLATE over the truncated coefficient planes (stand-in for ZFP's
    embedded group-testing coder; ratios are conservative but the
    bound/size tradeoff shape matches).
"""
from __future__ import annotations

import functools
import struct
import zlib
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ZFJ2: the header records the field dtype and decompression returns it
# (f64 fields reconstruct in f64 — no final f32 cast). ZFJ1 blobs record
# no dtype and always decode to float32, silently losing the precision
# an f64 bound was derived in — refuse them.
_MAGIC = b"ZFJ2"
_MAGIC_OLD = b"ZFJ1"
_BITS = 26  # fixed-point fraction bits for block-floating-point
_DTYPES = {0: np.dtype(np.float32), 1: np.dtype(np.float64)}


def _fwd_lift_np(x: np.ndarray, axis: int) -> np.ndarray:
    """ZFP's forward integer lifting on groups of 4 along `axis` (exact)."""
    x = np.moveaxis(x, axis, -1)
    s = x.shape
    v = x.reshape(-1, 4).astype(np.int64)
    a, b, c, d = v[:, 0].copy(), v[:, 1].copy(), v[:, 2].copy(), v[:, 3].copy()
    # reference codec lifting steps
    a += d; a >>= 1; d -= a
    c += b; c >>= 1; b -= c
    a += c; a >>= 1; c -= a
    d += b; d >>= 1; b -= d
    d += b >> 1; b -= d >> 1
    out = np.stack([a, b, c, d], axis=1).reshape(s)
    return np.moveaxis(out, -1, axis)


def _inv_lift_np(x: np.ndarray, axis: int) -> np.ndarray:
    x = np.moveaxis(x, axis, -1)
    s = x.shape
    v = x.reshape(-1, 4).astype(np.int64)
    a, b, c, d = v[:, 0].copy(), v[:, 1].copy(), v[:, 2].copy(), v[:, 3].copy()
    b += d >> 1; d -= b >> 1
    b += d; d <<= 1; d -= b
    c += a; a <<= 1; a -= c
    b += c; c <<= 1; c -= b
    d += a; a <<= 1; a -= d
    out = np.stack([a, b, c, d], axis=1).reshape(s)
    return np.moveaxis(out, -1, axis)


@functools.lru_cache(maxsize=4)
def _inverse_gain(ndim: int) -> float:
    """Linf->Linf gain of the inverse transform: max over outputs of the
    L1 row norm of the inverse matrix (worst case: every coefficient
    perturbed by +/-1 LSB with adversarial signs). Built by probing the
    exact integer lifting with unit impulses at high scale."""
    shape = (4,) * ndim
    scale = 1 << 20
    n = 4 ** ndim
    rowsum = np.zeros(shape, np.float64)
    for i in range(n):
        e = np.zeros(n, np.int64)
        e[i] = scale
        e = e.reshape(shape)
        for ax in range(ndim):
            e = _inv_lift_np(e, ax)
        rowsum += np.abs(e).astype(np.float64) / scale
    return float(np.max(rowsum))


@functools.lru_cache(maxsize=4)
def _lift_slack(ndim: int) -> float:
    """Max |inv(fwd(x)) - x| in LSBs: the forward lifting's >>1 steps drop
    low bits, so the pair is near- but not bit-exact; measure the slack."""
    rng = np.random.default_rng(0)
    shape = (4,) * ndim
    worst = 0.0
    for _ in range(64):
        x = rng.integers(-(1 << 24), 1 << 24, size=shape).astype(np.int64)
        y = x
        for ax in range(ndim):
            y = _fwd_lift_np(y, ax)
        for ax in range(ndim - 1, -1, -1):
            y = _inv_lift_np(y, ax)
        worst = max(worst, float(np.max(np.abs(y - x))))
    return worst


def _blockify(f: np.ndarray) -> Tuple[np.ndarray, Tuple[int, ...]]:
    """Pad to multiples of 4 (edge replication) and reshape to blocks:
    returns (nblocks, 4^d) int-indexable view and padded shape."""
    pads = [(0, (-s) % 4) for s in f.shape]
    fp = np.pad(f, pads, mode="edge")
    if f.ndim == 2:
        H, W = fp.shape
        blocks = fp.reshape(H // 4, 4, W // 4, 4).transpose(0, 2, 1, 3).reshape(-1, 4, 4)
    else:
        D, H, W = fp.shape
        blocks = (fp.reshape(D // 4, 4, H // 4, 4, W // 4, 4)
                  .transpose(0, 2, 4, 1, 3, 5).reshape(-1, 4, 4, 4))
    return blocks, fp.shape


def _unblockify(blocks: np.ndarray, padded_shape, orig_shape) -> np.ndarray:
    if len(orig_shape) == 2:
        H, W = padded_shape
        f = (blocks.reshape(H // 4, W // 4, 4, 4).transpose(0, 2, 1, 3)
             .reshape(H, W))
    else:
        D, H, W = padded_shape
        f = (blocks.reshape(D // 4, H // 4, W // 4, 4, 4, 4)
             .transpose(0, 3, 1, 4, 2, 5).reshape(D, H, W))
    return f[tuple(slice(0, s) for s in orig_shape)]


def zfp_compress(f: np.ndarray, xi: float) -> bytes:
    """ZFP-like fixed-accuracy compression of a 2D/3D field to one
    blob: 4^d block transform, per-block bit-plane truncation against
    the error bound ``xi``, then DEFLATE.

    ``xi = 0`` is permitted (maximum coded precision, b = 0 everywhere)
    but guaranteed only for fields the block transform round-trips
    exactly; the per-dtype floor below which the bound is unreachable is
    ~``amax * 2^-23`` for f32 fields (BFP quantization + the output
    cast) and ~``amax * 2^-25`` for f64 (the ``_BITS``-bit BFP mantissa
    alone). The preserving pipeline's derivation re-checks the bound and
    raises when a blob misses it."""
    f = np.asarray(f)
    if f.ndim not in (2, 3):
        raise ValueError("zfp-like supports 2D/3D fields")
    if xi < 0:
        raise ValueError(f"error bound must be non-negative, got xi={xi!r}")
    dt_codes = {v: k for k, v in _DTYPES.items()}
    if f.dtype not in dt_codes:
        raise TypeError(f"float field expected, got {f.dtype}")
    dt = dt_codes[f.dtype]
    # reserve headroom for the final f32 cast: the cast costs at most half
    # an ulp of the cast value, |f_hat| <= amax + xi, so the cast error is
    # <= (amax + xi) * 2^-24 — the f64 guarantee then holds inclusive of
    # output rounding. (Below xi ~ amax * 2^-23 the bound is unreachable
    # in f32 regardless of headroom: BFP quantization + the cast alone
    # exceed it; the xi*0.5 floor keeps the transform well-posed there.)
    # f64 output needs no headroom: reconstruction stays in f64 end to end.
    if f.dtype == np.float32 and f.size:
        amax = float(np.max(np.abs(f)))
        xi = max(xi - (amax + xi) * 2.0 ** -24, xi * 0.5)
    if f.size == 0:                  # empty field: header only, no blocks
        hdr = struct.pack("<4sBBdQ", _MAGIC, f.ndim, dt, float(xi), 0)
        dims = struct.pack(f"<{f.ndim}Q", *f.shape)
        return hdr + dims + struct.pack("<QQ", 0, 0)
    blocks, padded = _blockify(f.astype(np.float64))
    nb = blocks.shape[0]
    flat = blocks.reshape(nb, -1)

    # block-floating-point: shared exponent per block
    amax = np.max(np.abs(flat), axis=1)
    e = np.where(amax > 0, np.ceil(np.log2(np.maximum(amax, 1e-300))), 0.0)
    scale = np.exp2(e - _BITS)                       # LSB value per block
    ints = np.round(flat / scale[:, None]).astype(np.int64)

    blk = ints.reshape(blocks.shape)
    for ax in range(1, blocks.ndim):
        blk = _fwd_lift_np(blk, ax)
    coeff = blk.reshape(nb, -1)

    # error-bounded plane truncation: fixed-point error <= 0.5*scale, the
    # integer lifting round-trip slack <= _LIFT_SLACK LSB; truncation error
    # after inverse <= gain * 2^b * scale  ==> choose the largest valid b.
    gain = _inverse_gain(f.ndim)
    slack = _lift_slack(f.ndim)
    margin = xi - (0.5 + slack) * scale             # room for BFP+lift error
    with np.errstate(divide="ignore", invalid="ignore"):
        b = np.floor(np.log2(np.maximum(margin, 0.0) / (gain * scale) + 1e-300))
    b = np.clip(np.where(margin > 0, b, 0), 0, _BITS + 8).astype(np.int64)
    # rounded truncation (error <= 2^(b-1) < 2^b, consistent with the bound)
    q = (coeff + (np.int64(1) << b[:, None] >> 1)) >> b[:, None]

    # serialize: per-block exponent (f16-safe int16), plane shift b (uint8),
    # then the shifted coefficients as int32 (DEFLATE squeezes the slack).
    if np.any(np.abs(q) >= 2**31):
        raise OverflowError("coefficient overflow; xi too small for range")
    stream = zlib.compress(q.astype(np.int32).tobytes(), 6)
    meta = zlib.compress(
        e.astype(np.int16).tobytes() + b.astype(np.uint8).tobytes(), 6)
    hdr = struct.pack("<4sBBdQ", _MAGIC, f.ndim, dt, float(xi), nb)
    dims = struct.pack(f"<{f.ndim}Q", *f.shape)
    return (hdr + dims + struct.pack("<QQ", len(meta), len(stream))
            + meta + stream)


def zfp_decompress(blob: bytes) -> np.ndarray:
    """Inverse of ``zfp_compress``: f_hat with max|f - f_hat| <= xi, in
    the dtype the blob records. Retired ZFJ1 blobs are refused (they
    carry no dtype and were always decoded as f32) — never misdecoded."""
    if bytes(blob[:4]) == _MAGIC_OLD:
        raise ValueError(
            "refusing retired 'ZFJ1' payload: ZFJ1 blobs record no field "
            "dtype and always decode to float32; re-compress with the "
            "current codec")
    magic, ndim, dt, xi, nb = struct.unpack_from("<4sBBdQ", blob, 0)
    if magic != _MAGIC:
        raise ValueError("not a ZFP-like blob")
    if dt not in _DTYPES:
        raise ValueError(f"unknown ZFP-like dtype code {dt}")
    out_dtype = _DTYPES[dt]
    off = struct.calcsize("<4sBBdQ")
    shape = struct.unpack_from(f"<{ndim}Q", blob, off)
    off += 8 * ndim
    lm, ls = struct.unpack_from("<QQ", blob, off)
    off += 16
    if nb == 0:                     # empty field: no blocks were coded
        return np.zeros(shape, out_dtype)
    meta = zlib.decompress(blob[off:off + lm]); off += lm
    stream = zlib.decompress(blob[off:off + ls])
    e = np.frombuffer(meta[:2 * nb], np.int16).astype(np.float64)
    b = np.frombuffer(meta[2 * nb:], np.uint8).astype(np.int64)
    q = np.frombuffer(stream, np.int32).astype(np.int64).reshape(nb, -1)
    coeff = q << b[:, None]
    bs = (4,) * ndim
    blk = coeff.reshape((nb,) + bs)
    for ax in range(ndim, 0, -1):
        blk = _inv_lift_np(blk, ax)
    scale = np.exp2(e - _BITS)
    flat = blk.reshape(nb, -1).astype(np.float64) * scale[:, None]
    padded = tuple(s + ((-s) % 4) for s in shape)
    return _unblockify(flat.reshape((nb,) + bs), padded, shape) \
        .astype(out_dtype)


def zfp_roundtrip(f: np.ndarray, xi: float) -> Tuple[np.ndarray, int]:
    """Compress + decompress in one call: (f_hat, compressed bytes) —
    the bench/test convenience for the ZFP-like base."""
    blob = zfp_compress(f, xi)
    return zfp_decompress(blob), len(blob)
