"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads per layer
(arXiv:2411.13676). Sliding-window attention everywhere except 3 global
layers; meta-tokens omitted (DESIGN.md). Runs long_500k."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
    d_ff=5504, vocab=32001,
    ssm_state=16, sliding_window=1024,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="hymba-smoke", family="hybrid",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256,
        ssm_state=4, sliding_window=8,
    )
