"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks at 7:1 ratio (arXiv:2405.04517). Attention-free: runs the
long_500k shape with O(1) recurrent state."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_head=512,
    d_ff=0, vocab=50304,
    slstm_every=8,          # one sLSTM per 8 blocks (7:1 m:s ratio)
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, d_head=32,
        d_ff=0, vocab=256,
        slstm_every=2,
    )
