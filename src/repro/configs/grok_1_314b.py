"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2."""
from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32768, vocab=131072,
    moe=MoEConfig(n_experts=8, top_k=2),
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="grok-1-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=96, vocab=256,
        moe=MoEConfig(n_experts=4, top_k=2),
    )
