"""whisper-base [audio]: 6L d_model=512 8H d_ff=2048 vocab=51865 — enc-dec,
conv frontend STUB: input_specs() provides precomputed frame embeddings
(B, 1500, d_model) in place of the mel conv stem (arXiv:2212.04356)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
    d_ff=2048, vocab=51865,
    enc_dec=True, n_enc_layers=6, enc_positions=1500,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab=256,
        enc_dec=True, n_enc_layers=2, enc_positions=32,
    )
