"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(per expert) vocab=151936, MoE 128 experts top-8 (fine-grained)."""
from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab=151936,
    rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8),
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=32, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2),
    )
