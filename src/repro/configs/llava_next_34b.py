"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling. Backbone only; the vision tower is a stub:
input_specs() supplies precomputed anyres patch embeddings (576 tokens)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab=64000,
    rope_theta=5_000_000.0,
    n_img_tokens=576,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="llava-next-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, n_img_tokens=8,
    )
