"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama-arch (arXiv:2401.14196)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=19200, vocab=32256,
    rope_theta=100_000.0,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-coder-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256,
    )
