"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
— llama-arch small. Also the CPU-trainable end-to-end example arch."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_head=64,
    d_ff=1536, vocab=49152,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="smollm-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, d_head=16,
        d_ff=128, vocab=256,
    )
