"""Assigned-architecture registry: ``get_config(arch_id)``."""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ArchConfig

ARCH_IDS = (
    "llava_next_34b", "grok_1_314b", "qwen3_moe_235b_a22b",
    "deepseek_coder_33b", "smollm_135m", "granite_8b", "gemma2_9b",
    "whisper_base", "xlstm_1_3b", "hymba_1_5b",
)

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update({
    "llava-next-34b": "llava_next_34b",
    "grok-1-314b": "grok_1_314b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "smollm-135m": "smollm_135m",
    "granite-8b": "granite_8b",
    "gemma2-9b": "gemma2_9b",
    "whisper-base": "whisper_base",
    "xlstm-1.3b": "xlstm_1_3b",
    "hymba-1.5b": "hymba_1_5b",
})


def get_config(arch: str) -> ArchConfig:
    key = _ALIASES.get(arch, arch)
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f".{key}", __package__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    key = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f".{key}", __package__)
    return mod.smoke_config()


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
