"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating attention, logit softcaps
(arXiv:2408.00118)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_head=256,
    d_ff=14336, vocab=256000,
    sliding_window=4096, local_global_period=2,
    attn_softcap=50.0, final_softcap=30.0,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256,
        sliding_window=8, local_global_period=2,
        attn_softcap=50.0, final_softcap=30.0,
    )
