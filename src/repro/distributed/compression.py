"""Error-bounded gradient compression for the slow cross-pod links.

This is the paper's compressor (linear-scaling quantization, the same
primitive as repro.compress.szlike) applied to distributed optimization:
gradients are quantized to int16 codes with a per-tensor absolute error
bound xi = rel_bound * max|g|, summed across pods with an integer psum
(exact — integer addition commutes with dequantization), and dequantized.
Bytes on the pod interconnect drop 2x (f32 -> int16) with a hard
per-element error bound; an int8 mode drops 4x.

Used via shard_map manual over the 'pod' axis with 'data'/'model' left to
the SPMD partitioner (jax.shard_map axis_names={'pod'}).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp


def _bits_dtype(bits: int):
    return jnp.int8 if bits == 8 else jnp.int16


def quantize_tree(grads: Any, rel_bound: float, bits: int = 16):
    """Per-tensor linear-scaling quantization. Returns (codes, steps)."""
    # mszlint: disable=transfer-discipline -- bits is a python int
    qmax = float(2 ** (bits - 1) - 1)

    def q(g):
        gf = g.astype(jnp.float32)
        amax = jnp.max(jnp.abs(gf))
        # step chosen so codes fit in the integer range even after the
        # pod-axis sum (divide headroom by n_pods at the call site)
        step = jnp.maximum(amax * rel_bound * 2.0, amax / qmax)
        step = jnp.maximum(step, 1e-30)
        return jnp.clip(jnp.round(gf / step), -qmax, qmax).astype(
            _bits_dtype(bits)), step

    flat, tdef = jax.tree.flatten(grads)
    out = [q(g) for g in flat]
    codes = jax.tree.unflatten(tdef, [c for c, _ in out])
    steps = jax.tree.unflatten(tdef, [s for _, s in out])
    return codes, steps


def dequantize_tree(codes: Any, steps: Any, like: Any):
    """Inverse of ``quantize_tree``: codes * step, cast back to the
    dtypes of ``like``."""
    return jax.tree.map(
        lambda c, s, g: (c.astype(jnp.float32) * s).astype(g.dtype),
        codes, steps, like)


def compressed_psum_tree(grads: Any, axis_name: str, rel_bound: float = 1e-3,
                         bits: int = 16, n_shards: int = 2):
    """psum over `axis_name` with error-bounded quantized payloads.

    The integer codes are summed exactly; each pod's dequantization error
    is bounded by its step, so the summed error is bounded by
    n_shards * max_step — still a hard error bound, scaled accordingly.
    Steps are synchronized by a (tiny) f32 psum-max first so all shards
    use one step per tensor.
    """
    # mszlint: disable=transfer-discipline -- bits is a python int
    qmax = float(2 ** (bits - 1) - 1) / n_shards   # headroom for the sum
    wire = _bits_dtype(bits)                       # int16 / int8 on the wire

    def q(g):
        gf = g.astype(jnp.float32)
        amax = jnp.max(jnp.abs(gf))
        amax = jax.lax.pmax(amax, axis_name)       # shared scale
        step = jnp.maximum(jnp.maximum(amax * rel_bound * 2.0, amax / qmax),
                           1e-30)
        # per-shard codes fit qmax = range/n_shards, so the psum result
        # fits the narrow wire dtype — the reduce itself moves 2x (int16)
        # or 4x (int8) fewer bytes than f32.
        codes = jnp.clip(jnp.round(gf / step), -qmax, qmax).astype(wire)
        summed = jax.lax.psum(codes, axis_name)    # exact integer reduce
        return (summed.astype(jnp.float32) * step).astype(g.dtype)

    return jax.tree.map(q, grads)


def make_grad_sync(pod_axis: str = "pod", rel_bound: float = 1e-3,
                   bits: int = 16, n_pods: int = 2) -> Callable:
    """Returns grad_sync(grads) for use inside shard_map(axis_names={pod})."""
    def sync(grads):
        summed = compressed_psum_tree(grads, pod_axis, rel_bound, bits,
                                      n_shards=n_pods)
        return jax.tree.map(lambda g: g / n_pods, summed)
    return sync
