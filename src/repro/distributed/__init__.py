"""repro.distributed — mesh-aware distributed utilities: the
block-sharded SPMD MSz fix loop (shardfix: 1D slab chains and 2D/3D
block meshes with overlapped halo exchange), error-bounded compressed
cross-pod gradient all-reduce (the paper's compressor applied to
distributed training), straggler-tolerant stepping, and collective
helpers."""
from .compression import (compressed_psum_tree, quantize_tree,
                          dequantize_tree, make_grad_sync)
from .shardfix import (BLOCK_AXES, BlockPlan, ShardedBackend,
                       active_data_mesh, block_halo, data_axis_size,
                       halo_exchange, halo_plan, plan_blocks, sharded_fix,
                       time_step_parts)
from .straggler import StepWatchdog

__all__ = ["compressed_psum_tree", "quantize_tree", "dequantize_tree",
           "make_grad_sync", "StepWatchdog",
           "BLOCK_AXES", "BlockPlan", "ShardedBackend", "active_data_mesh",
           "block_halo", "data_axis_size", "halo_exchange", "halo_plan",
           "plan_blocks", "sharded_fix", "time_step_parts"]
