"""repro.distributed — mesh-aware distributed-optimization utilities:
error-bounded compressed cross-pod gradient all-reduce (the paper's
compressor applied to distributed training), straggler-tolerant stepping,
and collective helpers."""
from .compression import (compressed_psum_tree, quantize_tree,
                          dequantize_tree, make_grad_sync)
from .straggler import StepWatchdog

__all__ = ["compressed_psum_tree", "quantize_tree", "dequantize_tree",
           "make_grad_sync", "StepWatchdog"]
