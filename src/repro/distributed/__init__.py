"""repro.distributed — mesh-aware distributed utilities: the slab-sharded
SPMD MSz fix loop (shardfix), error-bounded compressed cross-pod gradient
all-reduce (the paper's compressor applied to distributed training),
straggler-tolerant stepping, and collective helpers."""
from .compression import (compressed_psum_tree, quantize_tree,
                          dequantize_tree, make_grad_sync)
from .shardfix import (ShardedBackend, active_data_mesh, data_axis_size,
                       halo_exchange, sharded_fix)
from .straggler import StepWatchdog

__all__ = ["compressed_psum_tree", "quantize_tree", "dequantize_tree",
           "make_grad_sync", "StepWatchdog",
           "ShardedBackend", "active_data_mesh", "data_axis_size",
           "halo_exchange", "sharded_fix"]
