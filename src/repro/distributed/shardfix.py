"""Sharded pMSz fix loop over the device mesh (shard_map + ppermute).

PR 1 made the Pallas fix kernels the single-device production path,
including sequential Z-tiling with per-iteration halo re-exchange; PR 4
generalized that tiling into 1D SPMD Z-slab chains. This module carries
the decomposition the rest of the way (DESIGN.md §9): fields shard over
true 2D/3D *block* meshes — ``('data_y','data_z')``, optionally
``('data_x','data_y','data_z')`` — with per-block halo exchange on every
sharded mesh axis and compute/communication overlap, pMSz's block
decomposition with overlapped ghost exchange (arXiv 2601.01787).

Axis naming: mesh axis ``data_z`` shards field axis 0 (the kernel slab
axis), ``data_y`` field axis 1, ``data_x`` field axis 2 (3D only; a
size->1 ``data_x`` axis is ignored for 2D fields). The legacy 1-axis
``data`` name keeps meaning "shard field axis 0" — every PR-4 caller and
test runs unchanged, bit for bit.

Halo-exchange protocol per fused iteration (overlap OFF — the legacy
schedule, generalized to N axes):

  1. exchange 1-deep ``g`` faces along every sharded axis IN ORDER —
     later axes exchange faces of the already-extended block, so edge
     and corner ghosts of the 26-stencil arrive transitively without
     dedicated diagonal sends (the two-phase face exchange; §9 has the
     correctness argument);
  2. run the extrema/false-point kernel on the extended block in GLOBAL
     coordinates (traced per-axis origins ``axis_index * L - 1``, static
     real extents) — its interior is exact;
  3. exchange 1-deep faces of the fresh interior masks the same way
     (one stacked exchange for all four mask arrays per axis);
  4. run the fix kernel on the extended block and keep its interior;
  5. count fix sources over interior real vertices only and ``psum``
     over every sharded axis — the loop's convergence predicate,
     identical on every device.

With overlap ON (default for block meshes with blocks >= 3 vertices per
sharded axis), the iteration is split into an *interior pass* with no
halo dependency — issued while a single 2-deep ``g`` face exchange is in
flight — and a *boundary pass* that consumes the fresh ghosts: with
2-deep ``g`` ghosts every device recomputes its boundary-shell masks
(including the ghost ring) locally, so the mid-iteration mask exchange
disappears entirely and the schedule has exactly one collective phase
per iteration for the XLA scheduler to overlap with the interior
kernels. Both schedules produce bitwise-identical trajectories — fields,
violation counts, iteration counts (tests/test_blockfix.py sweeps both
against ``reference``).

Padding, worklists, and the rest of the PR-4/PR-6 contract generalize
per block: non-divisible extents zero-pad at the high end of each
sharded axis (kernels mask true domain boundaries in global coordinates,
so pad and chain-end ppermute zeros never reach a real vertex), and the
per-device dirty worklist skips both kernels on blocks whose 2-vertex
dependency radius saw no ``g`` change last iteration, with dirt flags
folded axis-by-axis so diagonal-neighbor dirt propagates through the
same two-phase relay as the halos.

``ShardedBackend`` plugs this into the stencil-backend registry
(``repro.core.backend``) under the name ``"sharded"``; ``resolve_backend
("auto", ...)`` selects it automatically whenever a mesh with >= 2
devices on recognized data axes is active (``with mesh:``) or passed
explicitly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from ..core.backend import register_backend
from ..kernels.extrema import (default_interpret, extrema_masks_pallas,
                               typed_operand)
from ..kernels.fixpass import fix_pass_pallas
from ..kernels.lorenzo import lorenzo_quant_pallas

DATA_AXIS = "data"
#: block-mesh axis names, by the FIELD axis they shard: data_z -> axis 0
#: (the kernel slab axis), data_y -> axis 1, data_x -> axis 2.
BLOCK_AXES = ("data_z", "data_y", "data_x")
#: every mesh axis name the sharded backend recognizes as a data axis.
ALL_DATA_AXES = (DATA_AXIS,) + BLOCK_AXES


# ---------------------------------------------------------------------------
# mesh discovery
# ---------------------------------------------------------------------------

def active_data_mesh(axis_name: Optional[str] = None) -> Optional[Mesh]:
    """The mesh installed by ``with mesh:`` if it has ``axis_name`` (or,
    when None, any recognized data axis — ``data``/``data_z``/``data_y``/
    ``data_x``), else None. This is what makes ``backend="auto"``
    mesh-aware."""
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    if m.empty:
        return None
    names = (axis_name,) if axis_name is not None else ALL_DATA_AXES
    if not any(n in m.axis_names for n in names):
        return None
    return m


def data_axis_size(mesh: Optional[Mesh],
                   axis_name: Optional[str] = None) -> int:
    """Devices on ``axis_name`` (or, when None, the product over every
    recognized data axis present); 0 when the mesh is absent or has no
    such axis."""
    if mesh is None:
        return 0
    names = (axis_name,) if axis_name is not None else ALL_DATA_AXES
    present = [n for n in names if n in mesh.axis_names]
    if not present:
        return 0
    size = 1
    for n in present:
        size *= int(mesh.shape[n])
    return size


# ---------------------------------------------------------------------------
# block decomposition plan
# ---------------------------------------------------------------------------

class BlockAxis(NamedTuple):
    """One sharded field axis of a block plan: field axis ``dim`` splits
    into ``n`` blocks of (padded) extent ``L`` over mesh axis ``name``."""
    dim: int
    name: str
    n: int
    L: int


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """How a field decomposes over a mesh's data axes.

    ``names`` maps each field axis to its mesh axis name (None when
    unsharded; size-1 mesh axes keep their name for placement but emit
    no collectives); ``sharded`` lists the axes with >= 2 devices, in
    field-axis order — the canonical two-phase exchange order.
    """
    shape: Tuple[int, ...]
    names: Tuple[Optional[str], ...]
    sharded: Tuple[BlockAxis, ...]
    legacy: bool

    @property
    def ndim(self) -> int:
        """Field rank (2 or 3)."""
        return len(self.shape)

    def spec(self) -> PartitionSpec:
        """The PartitionSpec placing a field-shaped array on the mesh."""
        return PartitionSpec(*self.names)

    def padded_shape(self) -> Tuple[int, ...]:
        """Field shape after padding every sharded axis to ``n * L``."""
        out = list(self.shape)
        for a in self.sharded:
            out[a.dim] = a.n * a.L
        return tuple(out)

    def block_shape(self) -> Tuple[int, ...]:
        """Per-device local block shape (padded extents)."""
        out = list(self.shape)
        for a in self.sharded:
            out[a.dim] = a.L
        return tuple(out)

    def axis_names(self) -> Tuple[str, ...]:
        """Mesh axis names of the sharded axes (psum/ppermute targets)."""
        return tuple(a.name for a in self.sharded)

    def min_block(self) -> int:
        """Smallest sharded block extent (large sentinel when unsharded)."""
        return min([a.L for a in self.sharded], default=1 << 30)


def plan_blocks(shape: Sequence[int], mesh: Mesh,
                axis_name: Optional[str] = None) -> BlockPlan:
    """Build the :class:`BlockPlan` for a field ``shape`` on ``mesh``.

    ``axis_name`` forces the legacy single-axis decomposition over that
    mesh axis (field axis 0). Otherwise the plan maps ``data`` -> field
    axis 0 (legacy), or the block axes ``data_z``/``data_y``/``data_x``
    -> field axes 0/1/2; mixing ``data`` with block axes is an error, as
    is a >1-device ``data_x`` axis with a 2D field.
    """
    # mszlint: disable=transfer-discipline -- host planning over a shape tuple
    shape = tuple(int(s) for s in shape)
    ndim = len(shape)
    if ndim not in (2, 3):
        raise ValueError(f"block decomposition supports 2D/3D, got {shape}")
    names_map: Dict[int, str] = {}
    legacy = True
    if axis_name is not None:
        if axis_name not in mesh.axis_names:
            raise ValueError(
                f"mesh {mesh} has no {axis_name!r} axis to shard over")
        names_map[0] = axis_name
    else:
        block_present = [n for n in BLOCK_AXES if n in mesh.axis_names]
        if DATA_AXIS in mesh.axis_names:
            if block_present:
                raise ValueError(
                    f"mesh mixes the legacy {DATA_AXIS!r} axis with block "
                    f"axes {block_present}; use one naming scheme")
            names_map[0] = DATA_AXIS
        elif block_present:
            legacy = False
            for dim, nm in enumerate(BLOCK_AXES):
                if nm not in mesh.axis_names:
                    continue
                if dim >= ndim:
                    if int(mesh.shape[nm]) > 1:
                        raise ValueError(
                            f"{nm!r} has {int(mesh.shape[nm])} devices but "
                            f"the field is {ndim}D; 2D fields shard over "
                            "('data_y','data_z') only")
                    continue
                names_map[dim] = nm
        else:
            raise ValueError(
                f"mesh axes {mesh.axis_names} include no data axis "
                f"(one of {ALL_DATA_AXES}); build one with "
                "launch.mesh.make_data_mesh / make_block_mesh")
    names = tuple(names_map.get(d) for d in range(ndim))
    sharded = []
    for dim in range(ndim):
        nm = names[dim]
        if nm is None:
            continue
        n = int(mesh.shape[nm])
        if n >= 2:
            sharded.append(BlockAxis(dim, nm, n, -(-shape[dim] // n)))
    return BlockPlan(shape, names, tuple(sharded), legacy)


def _pad_blocks(x: jnp.ndarray, plan: BlockPlan) -> jnp.ndarray:
    """Zero-pad every sharded axis to ``n * L`` (kernels mask the true
    domain boundary in global coordinates, so pad content is never read
    by a real vertex; pad outputs are dropped on unpad)."""
    pads = [(0, 0)] * x.ndim
    changed = False
    for a in plan.sharded:
        want = a.n * a.L
        if x.shape[a.dim] != want:
            pads[a.dim] = (0, want - x.shape[a.dim])
            changed = True
    return jnp.pad(x, pads) if changed else x


def _unpad(x: jnp.ndarray, plan: BlockPlan) -> jnp.ndarray:
    """Crop a padded global array back to the real field shape."""
    return x[tuple(slice(0, s) for s in plan.shape)]


# ---------------------------------------------------------------------------
# halo exchange
# ---------------------------------------------------------------------------

def _chain_perms(n: int):
    fwd = [(d, d + 1) for d in range(n - 1)]
    bwd = [(d + 1, d) for d in range(n - 1)]
    return fwd, bwd


def halo_exchange(x: jnp.ndarray, axis_name: str, n_dev: int, *,
                  axis: int = 0,
                  depth: int = 1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``depth``-layer ghost faces from the chain neighbors.

    Returns ``(lo, hi)``: ``lo`` is the previous device's last ``depth``
    layers along ``axis``, ``hi`` the next device's first. The chain does
    NOT wrap: device 0's ``lo`` and device n-1's ``hi`` are zeros, which
    is safe because the kernels mask true domain boundaries themselves,
    in global coordinates, and the fix pass never pulls across them.
    1-device axes emit NO collective at all — the zero faces are built
    locally instead of round-tripping a degenerate self-permute.
    """
    size = x.shape[axis]
    last = jax.lax.slice_in_dim(x, size - depth, size, axis=axis)
    first = jax.lax.slice_in_dim(x, 0, depth, axis=axis)
    if n_dev <= 1:
        return jnp.zeros_like(last), jnp.zeros_like(first)
    fwd, bwd = _chain_perms(n_dev)
    lo = jax.lax.ppermute(last, axis_name, fwd)
    hi = jax.lax.ppermute(first, axis_name, bwd)
    return lo, hi


def with_halo(x: jnp.ndarray, axis_name: str, n_dev: int) -> jnp.ndarray:
    """Extend a local (L, ...) slab block to (L+2, ...) with exchanged
    ghost slabs on both ends (the legacy 1-axis helper; block meshes use
    ``block_halo``)."""
    lo, hi = halo_exchange(x, axis_name, n_dev)
    return jnp.concatenate([lo, x, hi], axis=0)


def block_halo(x: jnp.ndarray, plan: BlockPlan, depth: int, *,
               axis_offset: int = 0) -> jnp.ndarray:
    """Two-phase axis-ordered face exchange: extend ``x`` with ``depth``
    ghost layers along every sharded axis of ``plan``, in field-axis
    order. Later axes take their faces from the *already-extended* array,
    so a face sent in phase b carries the phase-a ghosts at its rim —
    after all phases every edge/corner ghost of the 26-stencil holds the
    correct diagonal-neighbor value without any dedicated diagonal sends
    (DESIGN.md §9). ``axis_offset`` shifts field axes for stacked
    payloads (e.g. a leading channel axis)."""
    ext = x
    for a in plan.sharded:
        ax = a.dim + axis_offset
        lo, hi = halo_exchange(ext, a.name, a.n, axis=ax, depth=depth)
        ext = jnp.concatenate([lo, ext, hi], axis=ax)
    return ext


def exchange_tree(tree, plan: BlockPlan, depth: int):
    """Halo-extend every leaf of a field-shaped pytree, reusing ONE
    stacked exchange per dtype group instead of one per leaf: leaves of
    equal dtype stack along a new leading axis, ride a single two-phase
    face exchange, and unstack. For the fix loop's constant topology
    (4 int32 label/code leaves + 2 bool extremum masks + 1 float lower
    bound) this cuts the per-axis topology exchange from 7 ppermute
    pairs to 3."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    by_dtype: Dict[str, List[int]] = {}
    for i, leaf in enumerate(leaves):
        # mszlint: disable=transfer-discipline -- runs under the shard_map
        # trace; asarray of a tracer is a no-op cast
        by_dtype.setdefault(str(jnp.asarray(leaf).dtype), []).append(i)
    out: List[Optional[jnp.ndarray]] = [None] * len(leaves)
    for idxs in by_dtype.values():
        # mszlint: disable=transfer-discipline -- same trace-context no-op
        stacked = jnp.stack([jnp.asarray(leaves[i]) for i in idxs])
        ext = block_halo(stacked, plan, depth, axis_offset=1)
        for k, i in enumerate(idxs):
            out[i] = ext[k]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# traced block coordinates
# ---------------------------------------------------------------------------

def _origins(plan: BlockPlan) -> List[jnp.ndarray]:
    """Per field axis, this device's global block origin (traced)."""
    o = [jnp.int32(0)] * plan.ndim
    for a in plan.sharded:
        o[a.dim] = jax.lax.axis_index(a.name).astype(jnp.int32) * a.L
    return o


def _coords(plan: BlockPlan, origins, start: Sequence[int]):
    """Kernel global-placement kwargs for a call whose array begins at
    block layer ``start[d]`` along field axis d (negative = inside the
    ghost ring). 2D fields use the slab/col pairs (the kernel plane's
    row axis is unused)."""
    s = plan.shape
    o = [origins[d] + jnp.int32(start[d]) for d in range(plan.ndim)]
    if plan.ndim == 3:
        return dict(slab_lo=o[0], n_slabs_total=s[0],
                    row_lo=o[1], n_rows_total=s[1],
                    col_lo=o[2], n_cols_total=s[2])
    return dict(slab_lo=o[0], n_slabs_total=s[0],
                row_lo=0, n_rows_total=1,
                col_lo=o[1], n_cols_total=s[1])


def _sl(plan: BlockPlan, per_axis: Dict[int, slice],
        offset: int = 0) -> Tuple[slice, ...]:
    """A slice tuple: ``per_axis[dim]`` on listed SHARDED dims, full
    slices elsewhere; ``offset`` prepends full slices (stacked arrays)."""
    out = [slice(None)] * (plan.ndim + offset)
    for dim, s in per_axis.items():
        out[dim + offset] = s
    return tuple(out)


def _real_weight(plan: BlockPlan, origins, dtype=jnp.int32) -> jnp.ndarray:
    """Broadcastable 0/1 weight marking the real (non-pad) vertices of
    this device's block."""
    w = jnp.ones((1,) * plan.ndim, dtype)
    for a in plan.sharded:
        line = ((origins[a.dim] + jnp.arange(a.L, dtype=jnp.int32))
                < plan.shape[a.dim]).astype(dtype)
        shp = [1] * plan.ndim
        shp[a.dim] = a.L
        w = w * line.reshape(shp)
    return w


def _resolve_modes(plan: BlockPlan, overlap: Optional[bool],
                   worklist: Optional[bool]) -> Tuple[bool, bool]:
    """(use_overlap, use_worklist) for a plan.

    Overlap needs >= 3 vertices per sharded axis (the boundary shells'
    2-deep faces and the interior pass must not degenerate); default ON
    for block meshes, OFF for legacy 1-axis plans (whose schedule stays
    byte-stable with PR 4). The worklist needs >= 2 (the 2-vertex dirt
    radius must stay within one ppermute hop); default ON, as in PR 6.
    """
    # mszlint: disable=transfer-discipline -- plan/overlap are host config
    sharded = bool(plan.sharded)
    can_overlap = sharded and plan.min_block() >= 3
    use_overlap = (can_overlap if overlap is None
                   # mszlint: disable=transfer-discipline -- host config
                   else bool(overlap) and can_overlap)
    if overlap is None and plan.legacy:
        use_overlap = False
    can_wl = sharded and plan.min_block() >= 2
    use_wl = (worklist if worklist is not None else True) and can_wl
    return use_overlap, use_wl


# ---------------------------------------------------------------------------
# the SPMD fix iteration (overlap OFF: exchange -> extrema -> mask
# exchange -> fix, the PR-4 schedule generalized to N sharded axes)
# ---------------------------------------------------------------------------

def _stack_masks(selfe, dem, pro, up_c, inner):
    return jnp.stack([selfe[inner], dem[inner], pro[inner], up_c[inner]])


def _step_plain(g_loc, topo_ext, run, src, cache, *, plan: BlockPlan,
                interpret: bool, worklist: bool):
    """One non-overlapped fix iteration on a local block.

    ``topo_ext``: topology pytree with (constant) 1-deep halos. With
    ``worklist`` the kernels sit under ``lax.cond(run, ...)`` while every
    collective stays unconditional; a skipped device re-sends its
    ``cache`` — the 1-deep mask faces of the last iteration it ran,
    still exact because nothing within its dependency radius changed —
    and the two-phase exchange relays fresh corner data through it
    untouched. Returns (g2, viol, src2, cache2, run2).
    """
    origins = _origins(plan)
    names = plan.axis_names()
    inner = _sl(plan, {a.dim: slice(1, -1) for a in plan.sharded})
    start = [0] * plan.ndim
    for a in plan.sharded:
        start[a.dim] = -1
    coords = _coords(plan, origins, start)
    block = plan.block_shape()

    g_ext = block_halo(g_loc, plan, 1)

    def do_masks(_):
        up_c, _, selfe, dem, pro = extrema_masks_pallas(
            g_ext, topo_ext.M, topo_ext.m,
            topo_ext.is_max.astype(jnp.int32),
            topo_ext.is_min.astype(jnp.int32),
            interpret=interpret, **coords)
        return _stack_masks(selfe, dem, pro, up_c, inner)

    if worklist:
        stacked = jax.lax.cond(
            run, do_masks, lambda _: jnp.zeros((4,) + block, jnp.int32),
            None)
        send = stacked
        cache2 = []
        for i, a in enumerate(plan.sharded):
            ax = 1 + a.dim
            f_lo = jax.lax.slice_in_dim(stacked, 0, 1, axis=ax)
            f_hi = jax.lax.slice_in_dim(stacked, a.L - 1, a.L, axis=ax)
            use_lo = jnp.where(run, f_lo, cache[i][0])
            use_hi = jnp.where(run, f_hi, cache[i][1])
            mid = jax.lax.slice_in_dim(send, 1, a.L - 1, axis=ax)
            send = jnp.concatenate([use_lo, mid, use_hi], axis=ax)
            cache2.append((use_lo, use_hi))
        cache2 = tuple(cache2)
    else:
        stacked = do_masks(None)
        send, cache2 = stacked, cache

    m_ext = block_halo(send, plan, 1, axis_offset=1)
    self_e, dem_e, pro_e, upc_e = m_ext

    def do_fix(_):
        g2_ext, _, _ = fix_pass_pallas(
            g_ext, topo_ext.lower, self_e, dem_e, pro_e, upc_e,
            topo_ext.dn_c, interpret=interpret, **coords)
        return g2_ext[inner]

    if worklist:
        g2_loc = jax.lax.cond(run, do_fix, lambda _: g_loc, None)
    else:
        g2_loc = do_fix(None)

    real = _real_weight(plan, origins)
    src_fresh = jnp.sum((stacked[0] + stacked[1] + stacked[2])
                        * real).astype(jnp.int32)
    src2 = jnp.where(run, src_fresh, src) if worklist else src_fresh
    viol = jax.lax.psum(src2, names) if names else src2

    run2 = _dirt_flags(g2_loc, g_loc, real, plan) if worklist else run
    return g2_loc, viol, src2, cache2, run2


def _dirt_flags(g2_loc, g_loc, real, plan: BlockPlan):
    """Next iteration's run flag: did g change inside this block, or
    within 2 layers of a face whose neighbor (or diagonal neighbor, via
    the axis-ordered fold) must hear about it? A vertex's next masks
    depend on g within 1 and its next fix output on g within 2, so a
    device may skip iff no g change landed within 2 vertices of its
    block — the PR-6 invariant per block."""
    changed = (g2_loc != g_loc) & (real != 0)
    own_any = jnp.any(changed)
    recv_any = jnp.bool_(False)
    for a in plan.sharded:
        lo_edge = jnp.any(jax.lax.slice_in_dim(changed, 0, 2, axis=a.dim))
        hi_edge = jnp.any(jax.lax.slice_in_dim(changed, a.L - 2, a.L,
                                               axis=a.dim))
        fwd, bwd = _chain_perms(a.n)
        dirt_lo = jax.lax.ppermute(hi_edge | recv_any, a.name, fwd)
        dirt_hi = jax.lax.ppermute(lo_edge | recv_any, a.name, bwd)
        recv_any = recv_any | dirt_lo | dirt_hi
    return own_any | recv_any


# ---------------------------------------------------------------------------
# the SPMD fix iteration (overlap ON: one 2-deep g exchange per
# iteration; interior pass has no halo dependency and overlaps it)
# ---------------------------------------------------------------------------

def _overlap_masks(g_loc, ext2, topo_ext2, *, plan, origins, interpret):
    """Fresh masks on the 1-deep-extended block (ext1 layout), computed
    from 2-deep ``g`` ghosts only: the interior pass runs on the bare
    block (no halo dependency), the per-axis boundary shells recompute
    the face ring AND the ghost ring locally — exactly what the
    overlap-OFF schedule's mask exchange would have delivered, because
    both kernels place every vertex in global coordinates."""
    ndim = plan.ndim
    c2 = _sl(plan, {a.dim: slice(2, -2) for a in plan.sharded})

    def extrema(g_arr, topo_idx, start):
        t = jax.tree_util.tree_map(lambda x: x[topo_idx], topo_ext2)
        up_c, _, selfe, dem, pro = extrema_masks_pallas(
            g_arr, t.M, t.m, t.is_max.astype(jnp.int32),
            t.is_min.astype(jnp.int32), interpret=interpret,
            **_coords(plan, origins, start))
        return jnp.stack([selfe, dem, pro, up_c])

    # interior: exact at >= 1 vertex from every sharded face
    m_int = extrema(g_loc, c2, [0] * ndim)

    ext1_shape = tuple(s + (2 if any(a.dim == d for a in plan.sharded)
                            else 0)
                       for d, s in enumerate(plan.block_shape()))
    m1 = jnp.zeros((4,) + ext1_shape, jnp.int32)
    m1 = m1.at[_sl(plan, {a.dim: slice(1, -1) for a in plan.sharded},
                   offset=1)].set(m_int)

    for a in plan.sharded:
        others = {b.dim: slice(0, b.L + 4) for b in plan.sharded
                  if b.dim != a.dim}
        keep_o = {b.dim: slice(1, b.L + 3) for b in plan.sharded
                  if b.dim != a.dim}
        start = [0] * ndim
        for b in plan.sharded:
            start[b.dim] = -2
        # low shell: g layers -2..1 -> masks exact at layers -1..0
        idx = _sl(plan, {**others, a.dim: slice(0, 4)})
        m_lo = extrema(ext2[idx], idx, start)
        keep = _sl(plan, {**keep_o, a.dim: slice(1, 3)}, offset=1)
        m1 = m1.at[_sl(plan, {a.dim: slice(0, 2)}, offset=1)].set(m_lo[keep])
        # high shell: g layers L-2..L+1 -> masks exact at L-1..L
        start_hi = list(start)
        start_hi[a.dim] = a.L - 2
        idx = _sl(plan, {**others, a.dim: slice(a.L, a.L + 4)})
        m_hi = extrema(ext2[idx], idx, start_hi)
        m1 = m1.at[_sl(plan, {a.dim: slice(a.L, a.L + 2)},
                       offset=1)].set(m_hi[keep])
    return m1


def _overlap_fix(g_loc, ext2, m1, topo_ext2, *, plan, origins, interpret):
    """The fix pass split into interior + per-axis boundary shells; the
    interior call touches no ghost data and overlaps the exchange that
    fed ``m1``'s shells."""
    ndim = plan.ndim

    def fix(g_arr, masks, low, dnc, start):
        g2, _, _ = fix_pass_pallas(
            g_arr, low, masks[0], masks[1], masks[2], masks[3], dnc,
            interpret=interpret, **_coords(plan, origins, start))
        return g2

    sh_dims = {a.dim: a for a in plan.sharded}
    g2 = jnp.zeros_like(g_loc)

    # interior: block layers [1, L-1), exact (kept) at [2, L-2)
    ci = _sl(plan, {d: slice(1, -1) for d in sh_dims})
    ci_m1 = _sl(plan, {d: slice(2, -2) for d in sh_dims}, offset=1)
    ci_t = _sl(plan, {d: slice(3, a.L + 1) for d, a in sh_dims.items()})
    start = [0] * ndim
    for d in sh_dims:
        start[d] = 1
    g2_int = fix(g_loc[ci], m1[ci_m1], topo_ext2.lower[ci_t],
                 topo_ext2.dn_c[ci_t], start)
    keep_i = _sl(plan, {d: slice(1, -1) for d in sh_dims})
    g2 = g2.at[_sl(plan, {d: slice(2, -2) for d in sh_dims})].set(
        g2_int[keep_i])

    for a in plan.sharded:
        o_m1 = {b.dim: slice(0, b.L + 2) for b in plan.sharded
                if b.dim != a.dim}
        o_g = {b.dim: slice(1, b.L + 3) for b in plan.sharded
               if b.dim != a.dim}
        keep_o = {b.dim: slice(1, b.L + 1) for b in plan.sharded
                  if b.dim != a.dim}
        start = [0] * ndim
        for b in plan.sharded:
            if b.dim != a.dim:
                start[b.dim] = -1
        # low shell: targets at block layers 0..1
        start_lo = list(start)
        start_lo[a.dim] = -1
        m_idx = _sl(plan, {**o_m1, a.dim: slice(0, 4)}, offset=1)
        g_idx = _sl(plan, {**o_g, a.dim: slice(1, 5)})
        out = fix(ext2[g_idx], m1[m_idx], topo_ext2.lower[g_idx],
                  topo_ext2.dn_c[g_idx], start_lo)
        keep = _sl(plan, {**keep_o, a.dim: slice(1, 3)})
        g2 = g2.at[_sl(plan, {a.dim: slice(0, 2)})].set(out[keep])
        # high shell: targets at block layers L-2..L-1
        start_hi = list(start)
        start_hi[a.dim] = a.L - 3
        m_idx = _sl(plan, {**o_m1, a.dim: slice(a.L - 2, a.L + 2)},
                    offset=1)
        g_idx = _sl(plan, {**o_g, a.dim: slice(a.L - 1, a.L + 3)})
        out = fix(ext2[g_idx], m1[m_idx], topo_ext2.lower[g_idx],
                  topo_ext2.dn_c[g_idx], start_hi)
        g2 = g2.at[_sl(plan, {a.dim: slice(a.L - 2, a.L)})].set(out[keep])
    return g2


def _step_overlap(g_loc, topo_ext2, run, src, *, plan: BlockPlan,
                  interpret: bool, worklist: bool, part: str = "full"):
    """One overlapped fix iteration: a single 2-deep two-phase ``g``
    face exchange (which subsumes the mask exchange — boundary masks are
    recomputed locally from the deep ghosts) plus interior kernels that
    depend only on local data, so XLA schedules them while the ppermutes
    are in flight. ``part`` carves out the probe surfaces the stream
    stats use ("interior" skips the exchange and shells, "exchange" only
    moves ghosts). Returns (g2, viol, src2, run2)."""
    origins = _origins(plan)
    names = plan.axis_names()
    real = _real_weight(plan, origins)
    c2 = _sl(plan, {a.dim: slice(2, -2) for a in plan.sharded})

    if part == "exchange":
        # the probe's viol output consumes every exchanged element so
        # XLA cannot dead-code the ppermutes away
        ext2 = block_halo(g_loc, plan, 2)
        return g_loc, jnp.sum(ext2).astype(jnp.int32), src, run

    if part == "interior":
        pads = [(2, 2) if any(a.dim == d for a in plan.sharded) else (0, 0)
                for d in range(plan.ndim)]
        ext2 = jnp.pad(g_loc, pads)
    else:
        ext2 = block_halo(g_loc, plan, 2)

    def do_masks(_):
        return _overlap_masks(g_loc, ext2, topo_ext2, plan=plan,
                              origins=origins, interpret=interpret)

    ext1_shape = tuple(s + (2 if any(a.dim == d for a in plan.sharded)
                            else 0)
                       for d, s in enumerate(plan.block_shape()))
    if worklist:
        m1 = jax.lax.cond(run, do_masks,
                          lambda _: jnp.zeros((4,) + ext1_shape, jnp.int32),
                          None)
    else:
        m1 = do_masks(None)

    def do_fix(_):
        return _overlap_fix(g_loc, ext2, m1, topo_ext2, plan=plan,
                            origins=origins, interpret=interpret)

    if worklist:
        g2_loc = jax.lax.cond(run, do_fix, lambda _: g_loc, None)
    else:
        g2_loc = do_fix(None)

    m1c = m1[_sl(plan, {a.dim: slice(1, -1) for a in plan.sharded},
                 offset=1)]
    src_fresh = jnp.sum((m1c[0] + m1c[1] + m1c[2]) * real).astype(jnp.int32)
    src2 = jnp.where(run, src_fresh, src) if worklist else src_fresh
    if part == "interior":
        return g2_loc, src2, src2, run
    viol = jax.lax.psum(src2, names) if names else src2
    run2 = _dirt_flags(g2_loc, g_loc, real, plan) if worklist else run
    return g2_loc, viol, src2, run2


# ---------------------------------------------------------------------------
# full distributed loop (one shard_map around the whole while_loop)
# ---------------------------------------------------------------------------

def sharded_fix(g0: jnp.ndarray, topo, mesh: Mesh, *, max_iters: int = 512,
                axis_name: Optional[str] = None,
                interpret: Optional[bool] = None,
                worklist: Optional[bool] = None,
                overlap: Optional[bool] = None):
    """Run the fused fix loop to convergence, distributed over ``mesh``'s
    data axes (1D slab chains or 2D/3D block meshes). Returns (g, iters,
    converged), bitwise equal to ``fused_fix(..., backend="pallas")``.

    The entire while_loop executes inside ONE shard_map: the (constant)
    topology halos are exchanged once (one stacked exchange per dtype
    group), only ``g`` — and, without overlap, mask — faces move per
    iteration, and the convergence predicate is the violation count
    psummed over every sharded axis, replicated so all devices decide
    identically.

    ``worklist`` (default on with >= 2 vertices per sharded block axis)
    is the per-block dirty skip of PR 6; ``overlap`` (default on for
    block meshes with >= 3-vertex blocks, off for legacy ``data`` chains)
    selects the interior/boundary split schedule with its single 2-deep
    ``g`` exchange. All four combinations produce bitwise-identical
    trajectories — only the collective schedule changes.
    """
    if interpret is None:
        interpret = default_interpret()
    plan = plan_blocks(g0.shape, mesh, axis_name)
    use_overlap, use_wl = _resolve_modes(plan, overlap, worklist)
    g_p = _pad_blocks(g0, plan)
    topo_p = jax.tree_util.tree_map(lambda x: _pad_blocks(x, plan), topo)
    depth = 2 if use_overlap else 1
    block = plan.block_shape()

    def spmd(g_loc, topo_loc):
        topo_ext = exchange_tree(topo_loc, plan, depth)
        if use_overlap:
            step = functools.partial(_step_overlap, plan=plan,
                                     interpret=interpret, worklist=use_wl)

            def body(state):
                g, it, _, src, run = state
                g2, viol2, src2, run2 = step(g, topo_ext, run, src)
                return g2, it + 1, viol2, src2, run2
        else:
            step_p = functools.partial(_step_plain, plan=plan,
                                       interpret=interpret, worklist=use_wl)

            def body(state):
                g, it, _, src, run, cache = state
                g2, viol2, src2, cache2, run2 = step_p(
                    g, topo_ext, run, src, cache)
                return g2, it + 1, viol2, src2, run2, cache2

        def cond(state):
            return (state[2] > 0) & (state[1] < max_iters)

        run0 = jnp.bool_(True)
        src0 = jnp.int32(0)
        if use_overlap:
            g1, viol1, src1, run1 = step(g_loc, topo_ext, run0, src0)
            out = jax.lax.while_loop(
                cond, body, (g1, jnp.int32(1), viol1, src1, run1))
        else:
            cache0 = tuple(
                (jnp.zeros((4,) + tuple(1 if d == a.dim else s
                                        for d, s in enumerate(block)),
                           jnp.int32),) * 2
                for a in plan.sharded) if use_wl else tuple(
                    ((jnp.int32(0),) * 2) for a in plan.sharded)
            cache0 = tuple(cache0)
            g1, viol1, src1, cache1, run1 = step_p(
                g_loc, topo_ext, run0, src0, cache0)
            out = jax.lax.while_loop(
                cond, body, (g1, jnp.int32(1), viol1, src1, run1, cache1))
        return out[0], out[1], out[2]

    spec = plan.spec()
    g, iters, viol = shard_map(
        spmd, mesh=mesh, in_specs=(spec, spec),
        out_specs=(spec, PartitionSpec(), PartitionSpec()),
        check_rep=False)(g_p, topo_p)
    return _unpad(g, plan), iters, viol == 0


# ---------------------------------------------------------------------------
# halo accounting + overlap probe (stream/service observability, §9)
# ---------------------------------------------------------------------------

def halo_plan(shape: Sequence[int], dtype, mesh: Mesh, *,
              axis_name: Optional[str] = None,
              overlap: Optional[bool] = None,
              worklist: Optional[bool] = None) -> Dict[str, int]:
    """Analytic per-mesh-axis halo traffic of ONE fix iteration, in
    bytes summed over all devices (both directions, including the
    corner/edge rows that later phases relay). Overlap-OFF counts the g
    faces plus the stacked 4-channel int32 mask faces; overlap-ON counts
    the single 2-deep g exchange. The stream scheduler multiplies by the
    observed iteration counts to surface live per-axis exchange bytes in
    ``CompressionService.stats()``."""
    plan = plan_blocks(shape, mesh, axis_name)
    use_overlap, _ = _resolve_modes(plan, overlap, worklist)
    item = jnp.dtype(dtype).itemsize
    out: Dict[str, int] = {}

    def sweep(depth, channels, itemsize):
        dims = list(plan.block_shape())
        for a in plan.sharded:
            face = depth * channels * itemsize
            for d, s in enumerate(dims):
                if d != a.dim:
                    face *= s
            senders = 2 * (a.n - 1)
            for b in plan.sharded:
                if b.dim != a.dim:
                    senders *= b.n
            out[a.name] = out.get(a.name, 0) + face * senders
            dims[a.dim] += 2 * depth
    if use_overlap:
        sweep(2, 1, item)
    else:
        sweep(1, 1, item)
        sweep(1, 4, jnp.dtype(jnp.int32).itemsize)
    return out


def time_step_parts(g0: jnp.ndarray, topo, mesh: Mesh, *,
                    axis_name: Optional[str] = None,
                    interpret: Optional[bool] = None,
                    reps: int = 3) -> Dict[str, float]:
    """Measure one overlapped iteration's interior pass, ghost exchange,
    and full step (seconds, best of ``reps``) on real arrays — the
    interior/boundary timing surface the service stats expose so the
    overlap win is observable in serving. Falls back to timing the plain
    schedule as "full" when the plan cannot overlap."""
    import time as _time
    if interpret is None:
        interpret = default_interpret()
    plan = plan_blocks(g0.shape, mesh, axis_name)
    use_overlap, _ = _resolve_modes(plan, True, False)
    g_p = _pad_blocks(g0, plan)
    topo_p = jax.tree_util.tree_map(lambda x: _pad_blocks(x, plan), topo)
    spec = plan.spec()

    def make(part):
        def spmd(g_loc, topo_loc):
            topo_ext = exchange_tree(topo_loc, plan, 2)
            g2, viol, _, _ = _step_overlap(
                g_loc, topo_ext, jnp.bool_(True), jnp.int32(0), plan=plan,
                interpret=interpret, worklist=False, part=part)
            return g2, viol

        def plain(g_loc, topo_loc):
            topo_ext = exchange_tree(topo_loc, plan, 1)
            g2, viol, _, _, _ = _step_plain(
                g_loc, topo_ext, jnp.bool_(True), jnp.int32(0), (),
                plan=plan, interpret=interpret, worklist=False)
            return g2, viol

        fn = spmd if use_overlap else plain
        return jax.jit(shard_map(
            fn, mesh=mesh, in_specs=(spec, spec),
            out_specs=(spec, PartitionSpec()), check_rep=False))

    parts = ("interior", "exchange", "full") if use_overlap else ("full",)
    res: Dict[str, float] = {}
    for part in parts:
        fn = make(part)
        g2, v = fn(g_p, topo_p)       # compile + warm
        jax.block_until_ready((g2, v))
        best = float("inf")
        for _ in range(max(1, reps)):
            t0 = _time.perf_counter()
            g2, v = fn(g_p, topo_p)
            jax.block_until_ready((g2, v))
            best = min(best, _time.perf_counter() - t0)
        res[f"t_{part}_s"] = best
    if use_overlap:
        res["t_boundary_s"] = max(0.0, res["t_full_s"] - res["t_interior_s"])
    # mszlint: disable=transfer-discipline -- host mode flag
    res["overlap"] = bool(use_overlap)
    return res


# ---------------------------------------------------------------------------
# sharded base transform (device-resident compression path, DESIGN.md §4)
# ---------------------------------------------------------------------------

def sharded_transform(f: jnp.ndarray, step, mesh: Mesh, *,
                      axis_name: Optional[str] = None,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Quantize + integer Lorenzo over the mesh: each device transforms
    its own block after one backward 1-deep face exchange per sharded
    axis (the Lorenzo stencil is backward-only; the two-phase ordering
    delivers the backward edge/corner ghosts transitively). The kernel
    runs in global slab coordinates, zero ghosts at true domain starts
    match the codec's zero-padding, and in-plane ghost columns feed the
    in-plane backward differences — residual codes are bitwise equal to
    a single-device run."""
    if interpret is None:
        interpret = default_interpret()
    plan = plan_blocks(f.shape, mesh, axis_name)
    f_p = _pad_blocks(f, plan)
    step_arr = typed_operand(step, f.dtype)

    def spmd(f_loc):
        ext = f_loc
        for a in plan.sharded:
            size = ext.shape[a.dim]
            last = jax.lax.slice_in_dim(ext, size - 1, size, axis=a.dim)
            if a.n > 1:
                fwd, _ = _chain_perms(a.n)
                lo = jax.lax.ppermute(last, a.name, fwd)
            else:
                lo = jnp.zeros_like(last)
            ext = jnp.concatenate([lo, ext], axis=a.dim)
        sh0 = next((a for a in plan.sharded if a.dim == 0), None)
        slab_lo = (jax.lax.axis_index(sh0.name).astype(jnp.int32) * sh0.L - 1
                   if sh0 is not None else 0)
        r_ext = lorenzo_quant_pallas(ext, step_arr, interpret=interpret,
                                     slab_lo=slab_lo)
        drop = tuple(slice(1, None) if any(a.dim == d
                                           for a in plan.sharded)
                     else slice(None) for d in range(plan.ndim))
        return r_ext[drop]

    spec = plan.spec()
    r = shard_map(spmd, mesh=mesh, in_specs=(spec,), out_specs=spec,
                  check_rep=False)(f_p)
    return _unpad(r, plan)


def sharded_scatter_edits(f_hat: jnp.ndarray, idx, val, mesh: Mesh, *,
                          axis_name: Optional[str] = None) -> jnp.ndarray:
    """Edit scatter over the mesh (the device decompression path's
    g = f_hat + delta, DESIGN.md §5): ``f_hat`` stays block-sharded, the
    (small) edit stream is replicated, and each device decomposes every
    global flat index into field coordinates, keeps exactly those inside
    its own block, and scatter-adds at the local offset — no collectives.
    Out-of-block indices (including the batched path's one-past-the-end
    padding) are remapped out of range and dropped, never wrapped —
    bitwise equal to the single-device scatter."""
    plan = plan_blocks(f_hat.shape, mesh, axis_name)
    f_p = _pad_blocks(f_hat, plan)
    block = plan.block_shape()
    shape = plan.shape
    loc_size = 1
    for s in block:
        # mszlint: disable=transfer-discipline -- block shape is host ints
        loc_size *= int(s)

    def spmd(fh_loc, idx_g, val_g):
        origins = _origins(plan)
        flat = idx_g.astype(jnp.int32)
        coords = []
        rem = flat
        for d in range(plan.ndim - 1, -1, -1):
            coords.append(rem % jnp.int32(shape[d]))
            rem = rem // jnp.int32(shape[d])
        coords = coords[::-1]
        oob = (flat < 0) | (flat >= jnp.int32(
            functools.reduce(lambda a, b: a * b, shape)))
        loc = jnp.int32(0)
        for d in range(plan.ndim):
            c = coords[d] - origins[d]
            oob = oob | (c < 0) | (c >= jnp.int32(block[d]))
            loc = loc * jnp.int32(block[d]) + c
        loc = jnp.where(oob, jnp.int32(loc_size), loc)
        out = fh_loc.reshape(-1)
        out = out.at[loc].add(val_g.astype(out.dtype), mode="drop")
        return out.reshape(fh_loc.shape)

    spec = plan.spec()
    idx_dev = typed_operand(idx, jnp.int32)
    val_dev = (val if isinstance(val, jnp.ndarray)
               else typed_operand(val, np.asarray(val).dtype))
    out = shard_map(spmd, mesh=mesh,
                    in_specs=(spec, PartitionSpec(), PartitionSpec()),
                    out_specs=spec, check_rep=False)(
        f_p, idx_dev, val_dev)
    return _unpad(out, plan)


def sharded_reconstruct(r: jnp.ndarray, step, dtype, mesh: Mesh, *,
                        axis_name: Optional[str] = None) -> jnp.ndarray:
    """Inverse transform over the mesh: along every sharded axis the
    global cumsum becomes local-cumsum + an exclusive prefix of
    per-device block totals (one all_gather of a face per axis);
    unsharded axes cumsum locally. Int32 arithmetic is exact and
    wraparound-commutative, and the final dequantization multiply is
    elementwise — bitwise equal to single-device ``sz_inverse``."""
    plan = plan_blocks(r.shape, mesh, axis_name)
    r_p = _pad_blocks(r, plan)
    step_arr = typed_operand(step, dtype)
    by_dim = {a.dim: a for a in plan.sharded}

    def spmd(r_loc):
        from ..compress.szlike import int32_cumsum
        q = r_loc
        for d in range(plan.ndim):
            # mszlint: disable=int32-range -- mirrors sz_inverse, whose
            # callers gate on codes_fit_int32 before any decode
            q = int32_cumsum(q, d)
            a = by_dim.get(d)
            if a is None:
                continue
            size = q.shape[d]
            last = jax.lax.slice_in_dim(q, size - 1, size, axis=d)
            totals = jax.lax.all_gather(last, a.name)       # (n, ...)
            before = (jnp.arange(a.n) < jax.lax.axis_index(a.name))
            before = before.astype(jnp.int32).reshape(
                (-1,) + (1,) * q.ndim)
            q = q + jnp.sum(totals * before, axis=0, dtype=jnp.int32)
        return q.astype(dtype) * step_arr

    spec = plan.spec()
    out = shard_map(spmd, mesh=mesh, in_specs=(spec,), out_specs=spec,
                    check_rep=False)(r_p)
    return _unpad(out, plan)


# ---------------------------------------------------------------------------
# the registered backend
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedBackend:
    """Block-sharded SPMD execution over a mesh's data axes.

    ``mesh=None`` (the registry instance) resolves the active mesh at
    call time; ``resolve_backend``/``fused_fix`` bind it into a concrete
    instance before jit so compilation caches key on the actual mesh.
    ``axis_name=None`` auto-detects the decomposition from the mesh's
    axis names (legacy ``data`` chains and ``data_*`` block meshes);
    an explicit name forces the 1-axis legacy layout.

    ``worklist``: per-block dirty skipping inside ``fix_loop`` (None =
    on whenever blocks keep >= 2 vertices per sharded axis; see
    ``sharded_fix``). ``overlap``: the interior/boundary split schedule
    (None = on for block meshes with >= 3-vertex blocks). Neither ever
    changes results — only which kernels run and when ghosts move.
    """
    name: str = "sharded"
    mesh: Optional[Mesh] = None
    axis_name: Optional[str] = None
    interpret: Optional[bool] = None
    worklist: Optional[bool] = None
    overlap: Optional[bool] = None

    def with_mesh(self, mesh: Mesh) -> "ShardedBackend":
        """A copy of this backend bound to ``mesh``."""
        return dataclasses.replace(self, mesh=mesh)

    def bind(self) -> "ShardedBackend":
        """Freeze the mesh this instance will run on (explicit mesh wins,
        else the active ``with mesh:`` context)."""
        if self.mesh is not None:
            return self
        m = active_data_mesh(self.axis_name)
        if m is None:
            raise ValueError(
                "sharded backend needs a mesh: pass mesh=..., or enter a "
                "`with mesh:` context whose mesh has a data axis (one of "
                f"{ALL_DATA_AXES})")
        return self.with_mesh(m)

    def _interpret(self) -> bool:
        return default_interpret() if self.interpret is None else self.interpret

    def n_data_devices(self) -> int:
        """Devices across this instance's data axes (0 when no mesh is
        bound or active)."""
        mesh = self.mesh if self.mesh is not None \
            else active_data_mesh(self.axis_name)
        return data_axis_size(mesh, self.axis_name)

    def supports(self, shape: Tuple[int, ...], dtype) -> bool:
        """Non-empty 2D/3D floating fields, given >= 1 data device."""
        return (len(shape) in (2, 3) and min(shape) >= 1
                and jnp.issubdtype(jnp.dtype(dtype), jnp.floating)
                and self.n_data_devices() >= 1)

    # -- protocol: one fused iteration on global arrays ----------------
    def fused_step(self, g: jnp.ndarray, topo):
        """Single shard_map-wrapped iteration (pad -> exchange -> kernels
        -> unpad), on the non-overlapped schedule. ``fix_loop`` is the
        production path — it amortizes the topology exchange and the
        shard_map entry over all iterations."""
        be = self.bind()
        plan = plan_blocks(g.shape, be.mesh, be.axis_name)
        g_p = _pad_blocks(g, plan)
        topo_p = jax.tree_util.tree_map(lambda x: _pad_blocks(x, plan), topo)

        def spmd(g_loc, topo_loc):
            topo_ext = exchange_tree(topo_loc, plan, 1)
            g2, viol, _, _, _ = _step_plain(
                g_loc, topo_ext, jnp.bool_(True), jnp.int32(0), (),
                plan=plan, interpret=be._interpret(), worklist=False)
            return g2, viol

        spec = plan.spec()
        g2, viol = shard_map(
            spmd, mesh=be.mesh, in_specs=(spec, spec),
            out_specs=(spec, PartitionSpec()), check_rep=False)(g_p, topo_p)
        return _unpad(g2, plan), viol

    # -- full-loop fast path consumed by fixes.fused_fix ---------------
    def fix_loop(self, g0: jnp.ndarray, topo, max_iters: int = 512):
        """The whole fused loop inside ONE shard_map (one topology
        halo exchange, per-iteration face exchanges): (g, iters,
        converged), bitwise equal to the single-device loop."""
        be = self.bind()
        return sharded_fix(g0, topo, be.mesh, max_iters=max_iters,
                           axis_name=be.axis_name,
                           interpret=be._interpret(),
                           worklist=be.worklist, overlap=be.overlap)

    # -- device-resident base transform (DESIGN.md §4) ------------------
    def transform(self, f: jnp.ndarray, step) -> jnp.ndarray:
        """Quantize + Lorenzo, each device on its own block (one
        backward face exchange per sharded axis)."""
        be = self.bind()
        return sharded_transform(f, step, be.mesh, axis_name=be.axis_name,
                                 interpret=be._interpret())

    def reconstruct(self, r: jnp.ndarray, step, dtype) -> jnp.ndarray:
        """f_hat from residual codes: local cumsums + all_gather
        exclusive prefixes along every sharded axis; bitwise equal to
        the host codec's reconstruction."""
        be = self.bind()
        return sharded_reconstruct(r, step, dtype, be.mesh,
                                   axis_name=be.axis_name)

    # -- device-resident decompression path (DESIGN.md §5) --------------
    def scatter_edits(self, f_hat: jnp.ndarray, idx, val) -> jnp.ndarray:
        """Edit scatter-add with the replicated edit stream filtered
        to each device's block (zero collectives)."""
        be = self.bind()
        return sharded_scatter_edits(f_hat, idx, val, be.mesh,
                                     axis_name=be.axis_name)

    # -- observability (DESIGN.md §9) ------------------------------------
    def halo_plan(self, shape: Tuple[int, ...], dtype) -> Dict[str, int]:
        """Per-mesh-axis halo bytes of one fix iteration for a field of
        ``shape``/``dtype`` under this backend's schedule flags."""
        be = self.bind()
        return halo_plan(shape, dtype, be.mesh, axis_name=be.axis_name,
                         overlap=be.overlap, worklist=be.worklist)

    # -- on-device entropy codec (DESIGN.md §8) --------------------------
    def pack_codes(self, r: jnp.ndarray):
        """Chunked-bitplane pack on the global code array. Every
        per-chunk stage (zigzag, plane transpose, width reduction) is
        chunk-independent and the offset scan/compaction are one
        XLA scan + scatter, so GSPMD partitions the jnp codec across
        the mesh without bespoke collectives — and the packed stream
        stays bitwise identical to every other backend's."""
        from ..kernels.pack import pack_codes_jnp
        return pack_codes_jnp(r)

    def unpack_codes(self, words, bits, shape: Tuple[int, ...]
                     ) -> jnp.ndarray:
        """Inverse of ``pack_codes`` on global arrays (same GSPMD
        argument as ``pack_codes``)."""
        from ..kernels.pack import unpack_codes_jnp
        return unpack_codes_jnp(words, bits, tuple(shape))


register_backend(ShardedBackend())
