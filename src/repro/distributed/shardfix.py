"""Sharded pMSz fix loop over the device mesh (shard_map + ppermute).

PR 1 made the Pallas fix kernels the single-device production path,
including sequential Z-tiling with per-iteration halo re-exchange. This
module generalizes that tiling into true SPMD execution: the field is
decomposed into per-device Z-slab blocks (Y-slab blocks in 2D) over the
``data`` axis of a ``jax.sharding.Mesh``, and every fix iteration runs
under ``shard_map`` with one-slab ghost layers exchanged between chain
neighbors via ``jax.lax.ppermute`` (pMSz's per-iteration ghost exchange,
arXiv 2601.01787).

Halo-exchange protocol per fused iteration (DESIGN.md §3):

  1. exchange a 1-slab halo of the current ``g`` (two ppermutes: last
     slab forward, first slab backward along the chain);
  2. run the extrema/false-point kernel on the (L+2)-slab extended block
     in GLOBAL coordinates (traced ``slab_lo = axis_index * L - 1``,
     static ``n_slabs_total``) — its interior L slabs are exact;
  3. exchange a 1-slab halo of the fresh interior masks (one ppermute
     pair over the stacked mask arrays);
  4. run the fix kernel on the extended block and keep its interior;
  5. count fix sources over interior real slabs only and ``psum`` them —
     the loop's convergence predicate, identical on every device.

Because both kernels evaluate domain boundaries and SoS linear indices in
global coordinates, halo garbage at the chain ends (ppermute delivers
zeros to unpaired devices) and in the padding slabs (fields whose slab
count is not divisible by the device count are zero-padded at the high
end) is masked inside the kernels and never reaches a real vertex. Every
real slab therefore computes exactly what the single-device ``pallas``
backend computes: the sharded trajectory — fields, violation counts,
iteration counts — is bitwise identical to single-device execution
(tests/test_shardfix.py enforces this against both single-device
backends).

``ShardedBackend`` plugs this into the stencil-backend registry
(``repro.core.backend``) under the name ``"sharded"``; ``resolve_backend
("auto", ...)`` selects it automatically whenever a mesh with >= 2
``data``-axis devices is active (``with mesh:``) or passed explicitly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from ..core.backend import register_backend
from ..kernels.extrema import default_interpret, extrema_masks_pallas
from ..kernels.fixpass import fix_pass_pallas
from ..kernels.lorenzo import lorenzo_quant_pallas

DATA_AXIS = "data"


# ---------------------------------------------------------------------------
# mesh discovery
# ---------------------------------------------------------------------------

def active_data_mesh(axis_name: str = DATA_AXIS) -> Optional[Mesh]:
    """The mesh installed by ``with mesh:`` if it has a ``axis_name`` axis,
    else None. This is what makes ``backend="auto"`` mesh-aware."""
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    if m.empty or axis_name not in m.axis_names:
        return None
    return m


def data_axis_size(mesh: Optional[Mesh], axis_name: str = DATA_AXIS) -> int:
    """Devices along ``axis_name``; 0 when mesh is absent or lacks it."""
    if mesh is None or axis_name not in mesh.axis_names:
        return 0
    return int(mesh.shape[axis_name])


# ---------------------------------------------------------------------------
# halo exchange
# ---------------------------------------------------------------------------

def halo_exchange(x: jnp.ndarray, axis_name: str, n_dev: int, *,
                  axis: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-slab ghost layers from the chain neighbors.

    Returns ``(lo, hi)``: ``lo`` is the previous device's last slab along
    ``axis``, ``hi`` the next device's first. The chain does NOT wrap:
    device 0's ``lo`` and device n-1's ``hi`` are ppermute zeros, which is
    safe because the kernels mask true domain boundaries themselves, in
    global coordinates, and the fix pass never pulls across them.
    """
    size = x.shape[axis]
    fwd = [(d, d + 1) for d in range(n_dev - 1)]
    bwd = [(d + 1, d) for d in range(n_dev - 1)]
    last = jax.lax.slice_in_dim(x, size - 1, size, axis=axis)
    first = jax.lax.slice_in_dim(x, 0, 1, axis=axis)
    lo = jax.lax.ppermute(last, axis_name, fwd)
    hi = jax.lax.ppermute(first, axis_name, bwd)
    return lo, hi


def with_halo(x: jnp.ndarray, axis_name: str, n_dev: int) -> jnp.ndarray:
    """Extend a local (L, ...) slab block to (L+2, ...) with exchanged
    ghost slabs on both ends."""
    lo, hi = halo_exchange(x, axis_name, n_dev)
    return jnp.concatenate([lo, x, hi], axis=0)


def _pad_slabs(x: jnp.ndarray, n_padded: int) -> jnp.ndarray:
    """Zero-pad the slab axis to ``n_padded`` (kernels mask the true
    domain boundary in global coordinates, so pad content is never read
    by a real slab; pad outputs are dropped on unpad)."""
    n = x.shape[0]
    if n == n_padded:
        return x
    return jnp.pad(x, [(0, n_padded - n)] + [(0, 0)] * (x.ndim - 1))


# ---------------------------------------------------------------------------
# the SPMD fix iteration
# ---------------------------------------------------------------------------

def _spmd_step(g_loc: jnp.ndarray, topo_ext, *, N: int, L: int, n_dev: int,
               axis_name: str, interpret: bool):
    """One fused fix iteration on a local (L, ...) slab block.

    ``topo_ext``: FieldTopo whose leaves already carry their (constant)
    1-slab halos, shape (L+2, ...); ``g`` halos are re-exchanged on every
    call. Returns (g_next local block, global violation count) — both
    bitwise equal to the corresponding slice/scalar of a single-device
    ``pallas`` ``fused_step``.
    """
    z0 = jax.lax.axis_index(axis_name).astype(jnp.int32) * L
    slab_lo = z0 - 1                       # global slab index of ext[0]

    g_ext = with_halo(g_loc, axis_name, n_dev)
    up_c, _, selfe, dem, pro = extrema_masks_pallas(
        g_ext, topo_ext.M, topo_ext.m,
        topo_ext.is_max.astype(jnp.int32), topo_ext.is_min.astype(jnp.int32),
        interpret=interpret, slab_lo=slab_lo, n_slabs_total=N)

    # the kernel's two boundary slabs lack their own neighbors — replace
    # them with the chain neighbors' fresh interior masks (the second,
    # mask-halo exchange of the protocol; one ppermute pair for all four)
    interior = slice(1, L + 1)
    stacked = jnp.stack([selfe[interior], dem[interior], pro[interior],
                         up_c[interior]])
    m_lo, m_hi = halo_exchange(stacked, axis_name, n_dev, axis=1)
    self_e, dem_e, pro_e, upc_e = jnp.concatenate([m_lo, stacked, m_hi],
                                                  axis=1)

    g2_ext, _, _ = fix_pass_pallas(
        g_ext, topo_ext.lower, self_e, dem_e, pro_e, upc_e, topo_ext.dn_c,
        interpret=interpret, slab_lo=slab_lo, n_slabs_total=N)

    # violations: every REAL slab counted exactly once (pad slabs hold
    # garbage masks and are excluded; psum makes the count global)
    real = ((z0 + jnp.arange(L, dtype=jnp.int32)) < N).astype(jnp.int32)
    real = real.reshape((-1,) + (1,) * (g_loc.ndim - 1))
    viol_loc = jnp.sum((selfe[interior] + dem[interior] + pro[interior])
                       * real).astype(jnp.int32)
    return g2_ext[interior], jax.lax.psum(viol_loc, axis_name)


def _block_size(n_slabs: int, n_dev: int) -> int:
    return -(-n_slabs // n_dev)


def _shard_args(g, topo, mesh, axis_name):
    """Pad g and every topo leaf to a device-divisible slab count."""
    n_dev = data_axis_size(mesh, axis_name)
    if n_dev < 1:
        raise ValueError(
            f"mesh {mesh} has no {axis_name!r} axis to shard the slab "
            f"axis over")
    N = g.shape[0]
    L = _block_size(N, n_dev)
    n_padded = L * n_dev
    g_p = _pad_slabs(g, n_padded)
    topo_p = jax.tree_util.tree_map(lambda x: _pad_slabs(x, n_padded), topo)
    return g_p, topo_p, n_dev, N, L


# ---------------------------------------------------------------------------
# full distributed loop (one shard_map around the whole while_loop)
# ---------------------------------------------------------------------------

def _spmd_step_worklist(g_loc, run, src_loc, cache, topo_ext, *, N, L, n_dev,
                        axis_name, interpret):
    """One worklist fix iteration on a local (L, ...) slab block.

    ``run``: this device's kernel predicate — False means no edit target
    landed within 2 slabs of this block last iteration, so its masks and
    its g block are unchanged by construction and both kernels sit under
    an untaken ``lax.cond``. The collectives stay UNCONDITIONAL on every
    device (SPMD programs must keep collectives aligned): a skipped
    device re-sends its ``cache`` — the interior-edge mask slabs of the
    last iteration it ran, still exact — so running neighbors see the
    same halos a dense iteration would deliver. ``src_loc`` carries the
    device's fix-source count; stale counts of skipped devices remain
    valid (nothing in their 2-slab dependency radius changed), so the
    psummed convergence predicate — and the iteration count — matches
    the dense loop exactly.

    Returns (g_next, viol_global, src_next, cache_next, run_next);
    ``run_next`` folds this device's own edit targets with the 2-edge
    target flags ppermuted from its chain neighbors.
    """
    z0 = jax.lax.axis_index(axis_name).astype(jnp.int32) * L
    slab_lo = z0 - 1
    plane = g_loc.shape[1:]
    interior = slice(1, L + 1)
    fwd = [(d, d + 1) for d in range(n_dev - 1)]
    bwd = [(d + 1, d) for d in range(n_dev - 1)]

    g_ext = with_halo(g_loc, axis_name, n_dev)

    def do_masks(_):
        up_c, _, selfe, dem, pro = extrema_masks_pallas(
            g_ext, topo_ext.M, topo_ext.m,
            topo_ext.is_max.astype(jnp.int32),
            topo_ext.is_min.astype(jnp.int32),
            interpret=interpret, slab_lo=slab_lo, n_slabs_total=N)
        return jnp.stack([selfe[interior], dem[interior], pro[interior],
                          up_c[interior]])

    stacked = jax.lax.cond(
        run, do_masks, lambda _: jnp.zeros((4, L) + plane, jnp.int32), None)

    # mask halo exchange: fresh interior edges when this device ran,
    # cached ones when it skipped (they are identical by the skip rule)
    send_first = jnp.where(run, stacked[:, :1], cache[:, :1])
    send_last = jnp.where(run, stacked[:, -1:], cache[:, 1:])
    cache2 = jnp.concatenate([send_first, send_last], axis=1)
    m_lo = jax.lax.ppermute(send_last, axis_name, fwd)
    m_hi = jax.lax.ppermute(send_first, axis_name, bwd)
    ext = jnp.concatenate([m_lo, stacked, m_hi], axis=1)
    self_e, dem_e, pro_e, upc_e = ext

    real = ((z0 + jnp.arange(L, dtype=jnp.int32)) < N)
    real_b = real.reshape((-1,) + (1,) * (g_loc.ndim - 1)).astype(jnp.int32)

    def do_fix(_):
        g2_ext, _, tgt = fix_pass_pallas(
            g_ext, topo_ext.lower, self_e, dem_e, pro_e, upc_e,
            topo_ext.dn_c, interpret=interpret,
            slab_lo=slab_lo, n_slabs_total=N)
        return g2_ext[interior], tgt[interior] * real.astype(jnp.int32)

    g2_loc, tgt_loc = jax.lax.cond(
        run, do_fix, lambda _: (g_loc, jnp.zeros(L, jnp.int32)), None)

    src_fresh = jnp.sum((stacked[0] + stacked[1] + stacked[2])
                        * real_b).astype(jnp.int32)
    src2 = jnp.where(run, src_fresh, src_loc)
    viol = jax.lax.psum(src2, axis_name)

    # 2-edge target flags to the chain neighbors: a neighbor must re-run
    # next iteration iff a target landed within 2 slabs of its block
    hi_edge = jnp.any(tgt_loc[-2:] > 0)
    lo_edge = jnp.any(tgt_loc[:2] > 0)
    dirt_lo = jax.lax.ppermute(hi_edge, axis_name, fwd)
    dirt_hi = jax.lax.ppermute(lo_edge, axis_name, bwd)
    run2 = jnp.any(tgt_loc > 0) | dirt_lo | dirt_hi
    return g2_loc, viol, src2, cache2, run2


def sharded_fix(g0: jnp.ndarray, topo, mesh: Mesh, *, max_iters: int = 512,
                axis_name: str = DATA_AXIS,
                interpret: Optional[bool] = None,
                worklist: Optional[bool] = None):
    """Run the fused fix loop to convergence, distributed over ``mesh``'s
    ``axis_name`` devices. Returns (g, iters, converged), bitwise equal to
    ``fused_fix(..., backend="pallas")``.

    The entire while_loop executes inside ONE shard_map: the (constant)
    topology halos are exchanged once, only ``g`` and mask halos move per
    iteration, and the convergence predicate is the psummed violation
    count carried in the loop state — replicated, so every device decides
    identically.

    ``worklist`` (default on for >= 2 devices with >= 2 slabs each)
    engages the per-device dirty-slab skip (DESIGN.md §7): a device whose
    block saw no edit target within 2 slabs last iteration skips both
    kernels under a device-local ``lax.cond`` and re-sends cached mask
    edges, while every collective stays unconditional — so fields whose
    remaining violations cluster on a few devices stop paying for the
    converged ones, with a bitwise-identical trajectory. Padding devices
    (all-pad blocks of a non-divisible field) skip from iteration 2 on
    for free.
    """
    if interpret is None:
        interpret = default_interpret()
    g_p, topo_p, n_dev, N, L = _shard_args(g0, topo, mesh, axis_name)
    # L >= 2 keeps the 2-slab dirt radius within the two edge flags one
    # ppermute hop delivers; below that every device borders everything
    use_wl = (worklist if worklist is not None else True) \
        and n_dev >= 2 and L >= 2

    def spmd(g_loc, topo_loc):
        topo_ext = jax.tree_util.tree_map(
            lambda x: with_halo(x, axis_name, n_dev), topo_loc)

        if use_wl:
            step = functools.partial(
                _spmd_step_worklist, topo_ext=topo_ext, N=N, L=L,
                n_dev=n_dev, axis_name=axis_name, interpret=interpret)

            def cond(state):
                return (state[2] > 0) & (state[1] < max_iters)

            def body(state):
                g, it, _, src, cache, run = state
                g2, viol2, src2, cache2, run2 = step(g, run, src, cache)
                return g2, it + 1, viol2, src2, cache2, run2

            cache0 = jnp.zeros((4, 2) + g_loc.shape[1:], jnp.int32)
            g1, viol1, src1, cache1, run1 = step(
                g_loc, jnp.bool_(True), jnp.int32(0), cache0)
            out = jax.lax.while_loop(
                cond, body, (g1, jnp.int32(1), viol1, src1, cache1, run1))
            return out[0], out[1], out[2]

        step = functools.partial(_spmd_step, topo_ext=topo_ext, N=N, L=L,
                                 n_dev=n_dev, axis_name=axis_name,
                                 interpret=interpret)

        def cond(state):
            _, it, viol = state
            return (viol > 0) & (it < max_iters)

        def body(state):
            g, it, _ = state
            g2, viol2 = step(g)
            return g2, it + 1, viol2

        g1, viol1 = step(g_loc)
        return jax.lax.while_loop(cond, body, (g1, jnp.int32(1), viol1))

    spec = PartitionSpec(axis_name)
    g, iters, viol = shard_map(
        spmd, mesh=mesh, in_specs=(spec, spec),
        out_specs=(spec, PartitionSpec(), PartitionSpec()),
        check_rep=False)(g_p, topo_p)
    return g[:N], iters, viol == 0


# ---------------------------------------------------------------------------
# sharded base transform (device-resident compression path, DESIGN.md §4)
# ---------------------------------------------------------------------------

def sharded_transform(f: jnp.ndarray, step, mesh: Mesh, *,
                      axis_name: str = DATA_AXIS,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Quantize + integer Lorenzo over the mesh: each device transforms
    its own Z-slab block after a single backward 1-slab halo exchange of
    ``f`` (the Lorenzo stencil is backward-only). The kernel runs in
    global coordinates via the same ``slab_lo`` plumbing as the fix
    kernels, so the q(z-1) term is zeroed at the true z == 0 boundary
    only — residual codes are bitwise equal to a single-device run."""
    if interpret is None:
        interpret = default_interpret()
    n_dev = data_axis_size(mesh, axis_name)
    N = f.shape[0]
    L = _block_size(N, n_dev)
    f_p = _pad_slabs(f, L * n_dev)
    step_arr = jnp.asarray(step, f.dtype)

    def spmd(f_loc):
        lo, _ = halo_exchange(f_loc, axis_name, n_dev)
        f_ext = jnp.concatenate([lo, f_loc], axis=0)       # (L+1, ...)
        slab_lo = jax.lax.axis_index(axis_name).astype(jnp.int32) * L - 1
        r_ext = lorenzo_quant_pallas(f_ext, step_arr, interpret=interpret,
                                     slab_lo=slab_lo)
        return r_ext[1:]   # drop the halo slab's (possibly garbage) output

    spec = PartitionSpec(axis_name)
    r = shard_map(spmd, mesh=mesh, in_specs=(spec,), out_specs=spec,
                  check_rep=False)(f_p)
    return r[:N]


def sharded_scatter_edits(f_hat: jnp.ndarray, idx, val, mesh: Mesh, *,
                          axis_name: str = DATA_AXIS) -> jnp.ndarray:
    """Edit scatter over the mesh (the device decompression path's
    g = f_hat + delta, DESIGN.md §5): ``f_hat`` stays slab-sharded, the
    (small) edit stream is replicated to every device, and each device
    applies exactly the edits whose flat indices land in its own slab
    block — no collectives. Indices outside the local block (including
    the batched path's one-past-the-end padding) are remapped out of
    range and dropped by the scatter, never wrapped. Unique global
    indices mean every target is updated once with the same arithmetic
    as the single-device scatter — bitwise equal."""
    n_dev = data_axis_size(mesh, axis_name)
    N = f_hat.shape[0]
    L = _block_size(N, n_dev)
    f_p = _pad_slabs(f_hat, L * n_dev)
    stride = 1
    for s in f_hat.shape[1:]:
        stride *= int(s)
    loc_size = L * stride

    def spmd(fh_loc, idx_g, val_g):
        base = jax.lax.axis_index(axis_name).astype(jnp.int32) \
            * jnp.int32(loc_size)
        local = idx_g.astype(jnp.int32) - base
        oob = (local < 0) | (local >= loc_size)
        local = jnp.where(oob, jnp.int32(loc_size), local)
        flat = fh_loc.reshape(-1)
        flat = flat.at[local].add(val_g.astype(flat.dtype), mode="drop")
        return flat.reshape(fh_loc.shape)

    spec = PartitionSpec(axis_name)
    out = shard_map(spmd, mesh=mesh,
                    in_specs=(spec, PartitionSpec(), PartitionSpec()),
                    out_specs=spec, check_rep=False)(
        f_p, jnp.asarray(idx, jnp.int32), jnp.asarray(val))
    return out[:N]


def sharded_reconstruct(r: jnp.ndarray, step, dtype, mesh: Mesh, *,
                        axis_name: str = DATA_AXIS) -> jnp.ndarray:
    """Inverse transform over the mesh: the in-block cumsums are local;
    the slab-axis cumsum becomes local-cumsum + an exclusive prefix of
    per-device block totals (one all_gather of a single plane). All
    integer arithmetic is exact, and the final dequantization multiply is
    elementwise — bitwise equal to single-device ``sz_inverse``."""
    n_dev = data_axis_size(mesh, axis_name)
    N = r.shape[0]
    L = _block_size(N, n_dev)
    r_p = _pad_slabs(r, L * n_dev)
    step_arr = jnp.asarray(step, dtype)

    def spmd(r_loc):
        from ..compress.szlike import int32_cumsum
        q = int32_cumsum(r_loc, 0)
        totals = jax.lax.all_gather(q[-1], axis_name)      # (n_dev, ...)
        idx = jax.lax.axis_index(axis_name)
        before = (jnp.arange(n_dev) < idx).astype(jnp.int32)
        before = before.reshape((-1,) + (1,) * (q.ndim - 1))
        q = q + jnp.sum(totals * before, axis=0, dtype=jnp.int32)
        for ax in range(1, q.ndim):
            q = int32_cumsum(q, ax)
        return q.astype(dtype) * step_arr

    spec = PartitionSpec(axis_name)
    out = shard_map(spmd, mesh=mesh, in_specs=(spec,), out_specs=spec,
                    check_rep=False)(r_p)
    return out[:N]


# ---------------------------------------------------------------------------
# the registered backend
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedBackend:
    """Slab-sharded SPMD execution over a mesh's ``data`` axis.

    ``mesh=None`` (the registry instance) resolves the active mesh at
    call time; ``resolve_backend``/``fused_fix`` bind it into a concrete
    instance before jit so compilation caches key on the actual mesh.

    ``worklist``: per-device dirty-slab skipping inside ``fix_loop``
    (None = on whenever the decomposition leaves >= 2 slabs per device;
    see ``sharded_fix``). Never changes results — devices whose
    neighborhood is converged merely stop running kernels.
    """
    name: str = "sharded"
    mesh: Optional[Mesh] = None
    axis_name: str = DATA_AXIS
    interpret: Optional[bool] = None
    worklist: Optional[bool] = None

    def with_mesh(self, mesh: Mesh) -> "ShardedBackend":
        """A copy of this backend bound to ``mesh``."""
        return dataclasses.replace(self, mesh=mesh)

    def bind(self) -> "ShardedBackend":
        """Freeze the mesh this instance will run on (explicit mesh wins,
        else the active ``with mesh:`` context)."""
        if self.mesh is not None:
            return self
        m = active_data_mesh(self.axis_name)
        if m is None:
            raise ValueError(
                "sharded backend needs a mesh: pass mesh=..., or enter a "
                f"`with mesh:` context whose mesh has a {self.axis_name!r} "
                "axis")
        return self.with_mesh(m)

    def _interpret(self) -> bool:
        return default_interpret() if self.interpret is None else self.interpret

    def n_data_devices(self) -> int:
        """Devices on this instance's data axis (0 when no mesh is bound
        or active)."""
        mesh = self.mesh if self.mesh is not None \
            else active_data_mesh(self.axis_name)
        return data_axis_size(mesh, self.axis_name)

    def supports(self, shape: Tuple[int, ...], dtype) -> bool:
        """Non-empty 2D/3D floating fields, given >= 1 data device."""
        return (len(shape) in (2, 3) and min(shape) >= 1
                and jnp.issubdtype(jnp.dtype(dtype), jnp.floating)
                and self.n_data_devices() >= 1)

    # -- protocol: one fused iteration on global arrays ----------------
    def fused_step(self, g: jnp.ndarray, topo):
        """Single shard_map-wrapped iteration (pad -> exchange -> kernels
        -> unpad). ``fix_loop`` is the production path — it amortizes the
        topology exchange and the shard_map entry over all iterations."""
        be = self.bind()
        g_p, topo_p, n_dev, N, L = _shard_args(g, topo, be.mesh,
                                               be.axis_name)

        def spmd(g_loc, topo_loc):
            topo_ext = jax.tree_util.tree_map(
                lambda x: with_halo(x, be.axis_name, n_dev), topo_loc)
            return _spmd_step(g_loc, topo_ext, N=N, L=L, n_dev=n_dev,
                              axis_name=be.axis_name,
                              interpret=be._interpret())

        spec = PartitionSpec(be.axis_name)
        g2, viol = shard_map(
            spmd, mesh=be.mesh, in_specs=(spec, spec),
            out_specs=(spec, PartitionSpec()), check_rep=False)(g_p, topo_p)
        return g2[:g.shape[0]], viol

    # -- full-loop fast path consumed by fixes.fused_fix ---------------
    def fix_loop(self, g0: jnp.ndarray, topo, max_iters: int = 512):
        """The whole fused loop inside ONE shard_map (one topology
        halo exchange, per-iteration 1-slab g exchange): (g, iters,
        converged), bitwise equal to the single-device loop."""
        be = self.bind()
        return sharded_fix(g0, topo, be.mesh, max_iters=max_iters,
                           axis_name=be.axis_name,
                           interpret=be._interpret(),
                           worklist=be.worklist)

    # -- device-resident base transform (DESIGN.md §4) ------------------
    def transform(self, f: jnp.ndarray, step) -> jnp.ndarray:
        """Quantize + Lorenzo, each device on its own Z-slab (one
        backward halo slab exchanged)."""
        be = self.bind()
        return sharded_transform(f, step, be.mesh, axis_name=be.axis_name,
                                 interpret=be._interpret())

    def reconstruct(self, r: jnp.ndarray, step, dtype) -> jnp.ndarray:
        """f_hat from residual codes: local cumsums + all_gather
        exclusive prefix over the slab axis; bitwise equal to the
        host codec's reconstruction."""
        be = self.bind()
        return sharded_reconstruct(r, step, dtype, be.mesh,
                                   axis_name=be.axis_name)

    # -- device-resident decompression path (DESIGN.md §5) --------------
    def scatter_edits(self, f_hat: jnp.ndarray, idx, val) -> jnp.ndarray:
        """Edit scatter-add with the replicated edit stream filtered
        to each device's slab range (zero collectives)."""
        be = self.bind()
        return sharded_scatter_edits(f_hat, idx, val, be.mesh,
                                     axis_name=be.axis_name)

    # -- on-device entropy codec (DESIGN.md §8) --------------------------
    def pack_codes(self, r: jnp.ndarray):
        """Chunked-bitplane pack on the global code array. Every
        per-chunk stage (zigzag, plane transpose, width reduction) is
        chunk-independent and the offset scan/compaction are one
        XLA scan + scatter, so GSPMD partitions the jnp codec across
        the mesh without bespoke collectives — and the packed stream
        stays bitwise identical to every other backend's."""
        from ..kernels.pack import pack_codes_jnp
        return pack_codes_jnp(r)

    def unpack_codes(self, words, bits, shape: Tuple[int, ...]
                     ) -> jnp.ndarray:
        """Inverse of ``pack_codes`` on global arrays (same GSPMD
        argument as ``pack_codes``)."""
        from ..kernels.pack import unpack_codes_jnp
        return unpack_codes_jnp(words, bits, tuple(shape))


register_backend(ShardedBackend())
