"""Straggler mitigation scaffolding.

On a real cluster the runtime exposes missed-heartbeat / slow-host signals;
in-process we implement the policy layer: a per-step deadline watchdog that
(a) records step-time EWMA and flags outliers, (b) after `patience`
consecutive deadline misses signals the caller to checkpoint-and-rebalance
(elastic restart excluding the slow host). The decision logic is what's
testable offline; the signal plumbing is environment-specific."""
from __future__ import annotations

import dataclasses
import time
from typing import Optional


@dataclasses.dataclass
class StepWatchdog:
    deadline_factor: float = 3.0     # miss = step > factor * EWMA
    patience: int = 3                # consecutive misses before escalation
    ewma_alpha: float = 0.1
    _ewma: Optional[float] = None
    _misses: int = 0
    steps: int = 0
    flagged_steps: int = 0

    def observe(self, step_seconds: float) -> str:
        """Returns 'ok' | 'slow' | 'rebalance'."""
        self.steps += 1
        if self._ewma is None:
            self._ewma = step_seconds
            return "ok"
        verdict = "ok"
        if step_seconds > self.deadline_factor * self._ewma:
            self._misses += 1
            self.flagged_steps += 1
            verdict = "rebalance" if self._misses >= self.patience else "slow"
        else:
            self._misses = 0
        # EWMA excludes flagged steps so a straggler cannot poison the baseline
        if verdict == "ok":
            self._ewma = (1 - self.ewma_alpha) * self._ewma \
                + self.ewma_alpha * step_seconds
        return verdict

    class _Timer:
        def __init__(self, wd):
            self.wd = wd

        def __enter__(self):
            self.t0 = time.perf_counter()
            return self

        def __exit__(self, *exc):
            self.verdict = self.wd.observe(time.perf_counter() - self.t0)
            return False

    def timed(self) -> "_Timer":
        """Context manager timing one step and feeding the watchdog."""
        return StepWatchdog._Timer(self)
