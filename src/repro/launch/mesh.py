"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods = 512 chips.
    Axes: (data, model) single-pod; (pod, data, model) multi-pod. Requires
    enough (possibly host-platform placeholder) devices — see dryrun.py."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh over the local device — used by the CPU examples
    so the same pjit code paths run everywhere."""
    return jax.make_mesh((1, 1), ("data", "model"))
