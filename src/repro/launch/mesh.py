"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods = 512 chips.
    Axes: (data, model) single-pod; (pod, data, model) multi-pod. Requires
    enough (possibly host-platform placeholder) devices — see dryrun.py."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh over the local device — used by the CPU examples
    so the same pjit code paths run everywhere."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """One-axis ('data',) mesh over the first ``n_devices`` local devices
    (default: all). This is the axis the slab-sharded MSz fix loop
    (repro.distributed.shardfix) decomposes fields over; on CPU hosts set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
    initializes to emulate N devices."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"requested a {n}-device data mesh but {len(devs)} device(s) "
            "are available (set --xla_force_host_platform_device_count "
            "before jax initializes to emulate more)")
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("data",))
