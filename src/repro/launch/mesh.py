"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.

Two data-mesh families feed the sharded MSz backend
(``repro.distributed.shardfix``):

* ``make_data_mesh(n)`` — the legacy one-axis ``('data',)`` chain,
  sharding field axis 0 into Z-slabs;
* ``make_block_mesh(shape_or_auto)`` — 1/2/3-axis block meshes over the
  ``data_z``/``data_y``/``data_x`` axis names (field axes 0/1/2), either
  an explicit shape tuple or auto-factored into the most cube-like shape
  so per-block halo surface, not the full XY plane, sets the exchange
  cost (DESIGN.md §9).

On CPU hosts set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
before jax initializes to emulate N devices; across real hosts call
``init_distributed()`` first so every process sees the global device
set.
"""
from __future__ import annotations

import os
from typing import Sequence, Tuple

import jax
import numpy as np

#: mesh axis names for block meshes, outermost first; the LAST k of
#: these name a k-axis mesh so the slab axis (data_z, field axis 0) is
#: always present and data_x only appears in full 3D decompositions.
BLOCK_AXIS_ORDER = ("data_x", "data_y", "data_z")


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods = 512 chips.
    Axes: (data, model) single-pod; (pod, data, model) multi-pod. Requires
    enough (possibly host-platform placeholder) devices — see dryrun.py."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1x1 mesh over the local device — used by the CPU examples
    so the same pjit code paths run everywhere."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """One-axis ('data',) mesh over the first ``n_devices`` local devices
    (default: all). This is the axis the slab-sharded MSz fix loop
    (repro.distributed.shardfix) decomposes fields over; on CPU hosts set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
    initializes to emulate N devices. For 2D/3D block decompositions use
    :func:`make_block_mesh`."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"requested a {n}-device data mesh but {len(devs)} device(s) "
            "are available (set --xla_force_host_platform_device_count "
            "before jax initializes to emulate more)")
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("data",))


def factor_block_shape(n_devices: int, ndim: int = 2) -> Tuple[int, ...]:
    """Factor ``n_devices`` into the most cube-like ``ndim``-tuple
    (ascending, so the largest factor lands on the innermost ``data_z``
    slab axis): 8 -> (2, 4) or (2, 2, 2), 6 -> (2, 3), primes fall back
    to (1, ..., p). Cube-like shapes minimize total halo face area for a
    given device count — the point of block decomposition."""
    n = int(n_devices)
    if n < 1:
        raise ValueError(f"cannot factor a {n}-device block mesh")
    if ndim == 1:
        return (n,)
    # peel the divisor closest to the ndim-th root, recurse on the rest
    root = round(n ** (1.0 / ndim))
    best = 1
    for cand in range(1, n + 1):
        if n % cand:
            continue
        if abs(cand - root) < abs(best - root) or (
                abs(cand - root) == abs(best - root) and cand < best):
            best = cand
    rest = factor_block_shape(n // best, ndim - 1)
    return tuple(sorted((best,) + rest))


def init_distributed(*, coordinator_address: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None) -> bool:
    """Initialize ``jax.distributed`` for multi-process block meshes.

    Call once per process before any mesh construction so
    ``jax.devices()`` spans every host and the same ``shard_map``
    program runs across processes unchanged. Arguments default to the
    standard ``JAX_COORDINATOR_ADDRESS`` / ``JAX_NUM_PROCESSES`` /
    ``JAX_PROCESS_ID`` environment (as set by launchers); returns False
    without touching jax state when neither arguments nor environment
    request a multi-process run (the single-host emulation path), True
    after a successful ``jax.distributed.initialize``. Idempotent:
    re-initialization attempts are swallowed."""
    addr = coordinator_address or os.environ.get("JAX_COORDINATOR_ADDRESS")
    nproc = num_processes if num_processes is not None else (
        int(os.environ["JAX_NUM_PROCESSES"])
        if "JAX_NUM_PROCESSES" in os.environ else None)
    if addr is None or nproc is None or nproc <= 1:
        return False
    pid = process_id if process_id is not None else (
        int(os.environ["JAX_PROCESS_ID"])
        if "JAX_PROCESS_ID" in os.environ else None)
    try:
        jax.distributed.initialize(coordinator_address=addr,
                                   num_processes=nproc,
                                   process_id=pid)
    except RuntimeError:
        # already initialized (idempotent re-entry from a second caller)
        pass
    return True


def make_block_mesh(shape: Sequence[int] | str | None = "auto", *,
                    ndim: int = 2) -> jax.sharding.Mesh:
    """Block mesh for the 2D/3D block-decomposed sharded fix loop.

    ``shape`` is an explicit mesh-shape tuple — 1, 2, or 3 entries,
    outermost first, mapped onto the LAST k of ``(data_x, data_y,
    data_z)`` so a 2-tuple gives ``('data_y', 'data_z')`` (field axes
    1 and 0) and a 3-tuple the full 3D decomposition — or ``"auto"``
    (the default), which factors every available device into the most
    cube-like ``ndim``-tuple (``make_block_mesh()`` on 8 devices gives a
    (2, 4) ``('data_y', 'data_z')`` mesh; ``ndim=3`` gives (2, 2, 2)).

    Emulation: on CPU hosts set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
    initializes; across real hosts call :func:`init_distributed` first.
    """
    devs = jax.devices()
    if shape is None or (isinstance(shape, str) and shape == "auto"):
        shape_t = factor_block_shape(len(devs), ndim)
    elif isinstance(shape, str):
        raise ValueError(
            f"shape must be a tuple of mesh-axis sizes or 'auto', "
            f"got {shape!r}")
    else:
        shape_t = tuple(int(s) for s in shape)
    if not 1 <= len(shape_t) <= 3 or any(s < 1 for s in shape_t):
        raise ValueError(
            f"block mesh shape must be 1-3 positive axis sizes, "
            f"got {shape_t}")
    n = int(np.prod(shape_t))
    if n > len(devs):
        raise ValueError(
            f"requested a {shape_t} block mesh ({n} devices) but "
            f"{len(devs)} device(s) are available (set "
            "--xla_force_host_platform_device_count in XLA_FLAGS before "
            "jax initializes to emulate more, or run init_distributed() "
            "for a real multi-host mesh)")
    names = BLOCK_AXIS_ORDER[-len(shape_t):]
    arr = np.asarray(devs[:n]).reshape(shape_t)
    return jax.sharding.Mesh(arr, names)
