"""repro.launch — production mesh, dry-run driver, train/serve launchers."""
