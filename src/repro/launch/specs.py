"""Dry-run input specs: ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, zero allocation) plus the PartitionSpec
trees that shard them onto the production mesh."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import (init_decode_cache, init_params, MeshAxes,
                      axes_for_mesh, mesh_shape_dict, tree_param_specs)
from ..models.sharding import MeshAxes  # noqa: F811 (explicit re-export)
from ..models.config import ArchConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def _dp_degree(mesh) -> int:
    ms = mesh_shape_dict(mesh)
    return int(np.prod([v for k, v in ms.items() if k in ("pod", "data")]))


def batch_spec(cfg: ArchConfig, shape: ShapeConfig, mesh) -> Dict[str, Any]:
    """ShapeDtypeStructs for one global batch of this (arch x shape)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": SDS((B, 1), jnp.int32)}
    batch: Dict[str, Any] = {}
    s_text = S
    if cfg.n_img_tokens:
        s_text = S - cfg.n_img_tokens
        batch["image_embeds"] = SDS((B, cfg.n_img_tokens, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = SDS((B, cfg.enc_positions, cfg.d_model),
                              jnp.bfloat16)
    batch["tokens"] = SDS((B, s_text), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = SDS((B, s_text), jnp.int32)
    return batch


def _auto_spec(shape: Tuple[int, ...], ax: MeshAxes, ms: dict,
               batch_dim: Optional[int]) -> P:
    """Shard batch_dim over dp when divisible; then the largest remaining
    dim divisible by tp over model."""
    dp = int(np.prod([ms.get(a, 1) for a in ax.batch]))
    tp = ms.get(ax.model, 1)
    spec: list = [None] * len(shape)
    if batch_dim is not None and shape[batch_dim] % dp == 0 and shape[batch_dim] >= dp:
        spec[batch_dim] = ax.batch if len(ax.batch) > 1 else ax.batch[0]
    cands = [(s, i) for i, s in enumerate(shape)
             if i != batch_dim and s % tp == 0 and s >= tp]
    if cands:
        _, i = max(cands)
        spec[i] = ax.model
    return P(*spec)


def batch_shardings(batch_sds, cfg: ArchConfig, mesh) -> Any:
    ax = axes_for_mesh(mesh)
    ms = mesh_shape_dict(mesh)
    dp = _dp_degree(mesh)

    def spec_of(sds):
        nd = len(sds.shape)
        s: list = [None] * nd
        if sds.shape[0] % dp == 0 and sds.shape[0] >= dp:
            s[0] = ax.batch if len(ax.batch) > 1 else ax.batch[0]
        return NamedSharding(mesh, P(*s))
    return jax.tree.map(spec_of, batch_sds)


def param_structs(cfg: ArchConfig) -> Any:
    """Abstract (no-allocation) parameter pytree via eval_shape."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def param_shardings(cfg: ArchConfig, mesh, zero1: bool = False,
                    data_only: bool = False,
                    replicate_embed: bool = False) -> Any:
    """data_only: exclude the pod axis from FSDP/ZeRO specs (required when
    the pod axis is manual, e.g. compressed cross-pod gradient sync).
    replicate_embed: keep the embedding table unsharded — works around an
    XLA SPMD CHECK-crash partitioning the vocab-sharded gather inside a
    manual(pod) region (EXPERIMENTS.md §Perf-3)."""
    ax = axes_for_mesh(mesh)
    if data_only:
        ax = MeshAxes(batch=("data",), model=ax.model)
    ms = mesh_shape_dict(mesh)
    shapes = param_structs(cfg)
    specs = tree_param_specs(shapes, ax, ms, zero1=zero1)
    if replicate_embed:
        specs = dict(specs)
        specs["embed"] = jax.sharding.PartitionSpec(
            *([None] * len(shapes["embed"].shape)))
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def cache_structs(cfg: ArchConfig, shape: ShapeConfig) -> Any:
    B, S = shape.global_batch, shape.seq_len
    return jax.eval_shape(lambda: init_decode_cache(cfg, B, S))


def cache_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh) -> Any:
    ax = axes_for_mesh(mesh)
    ms = mesh_shape_dict(mesh)
    structs = cache_structs(cfg, shape)

    def spec_of_path(path, sds):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        nd = len(sds.shape)
        tp = ms.get(ax.model, 1)
        # mLSTM matrix memory (.., B, H, D_out, D_in): shard D_out (the
        # contraction OUTPUT of C·q) over model — sharding D_in makes the
        # per-step einsum a sharded contraction and forces an involuntary
        # full rematerialization of the state every token (§Perf-2).
        dp = int(np.prod([ms.get(a, 1) for a in ax.batch]))
        dspec = ax.batch if len(ax.batch) > 1 else ax.batch[0]
        if "mlstm_C" in name:
            # (G, per, B, H, D_out, D_in): B over data, D_out over model;
            # D_in (k side) replicated — the per-step readout is then local
            spec = [None] * nd
            if sds.shape[nd - 4] % dp == 0 and sds.shape[nd - 4] >= dp:
                spec[nd - 4] = dspec
            if sds.shape[-2] % tp == 0:
                spec[-2] = ax.model
            return NamedSharding(mesh, P(*spec))
        if "mlstm_n" in name or "slstm" in name:
            # batch-sharded, feature dims replicated (k is replicated)
            spec = [None] * nd
            bdim = nd - 3
            if sds.shape[bdim] % dp == 0 and sds.shape[bdim] >= dp:
                spec[bdim] = dspec
            return NamedSharding(mesh, P(*spec))
        # rank>=4 KV caches: (L,B,T,Hk,Dh) or (B,T,Hk,Dh): batch then T
        if name.endswith(("k", "v")) and nd >= 4:
            bdim = nd - 4
            spec = _auto_spec(sds.shape, ax, ms, bdim)
            return NamedSharding(mesh, spec)
        if "enc_out" in name:
            return NamedSharding(mesh, _auto_spec(sds.shape, ax, ms, 0))
        # recurrent states: (..., B, H, N/D, D): batch dim is nd-4 for
        # mlstm_C (G,per,B,H,D,D) -> 2 ... find by size match
        bdim = None
        for i, s in enumerate(sds.shape):
            if s == shape.global_batch:
                bdim = i
                break
        return NamedSharding(mesh, _auto_spec(sds.shape, ax, ms, bdim))

    flat, treedef = jax.tree_util.tree_flatten_with_path(structs)
    out = [spec_of_path(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_structs(cfg: ArchConfig) -> Any:
    from ..train import adamw_init
    shapes = param_structs(cfg)
    return jax.eval_shape(adamw_init, shapes)


def opt_state_shardings(cfg: ArchConfig, mesh, zero1: bool = True) -> Any:
    """ZeRO-1: optimizer moments additionally sharded over the data axes."""
    from ..train import AdamWState
    ax = axes_for_mesh(mesh)
    ms = mesh_shape_dict(mesh)
    shapes = param_structs(cfg)
    mspecs = tree_param_specs(shapes, ax, ms, zero1=zero1)
    to_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), mspecs)
    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=to_shard, v=jax.tree.map(lambda s: s, to_shard))
