"""Multi-pod dry-run: .lower().compile() every (architecture x input shape)
on the production meshes, prove memory fits, and extract the roofline terms
(FLOPs / bytes / collective bytes) from the compiled artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
      --shape train_4k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

The 512 placeholder host devices exist ONLY here (the env var below must
precede any jax import); smoke tests and benchmarks see 1 device.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, get_config
from ..models import forward as model_forward
from ..models.config import SHAPES, ArchConfig, ShapeConfig, shape_by_name
from ..serve import make_serve_step
from ..train import (AdamWConfig, TrainState, TrainStepConfig,
                     make_train_step)
from . import specs as S
from .mesh import make_production_mesh
from ..models.sharding import use_mesh

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# archs that cannot serve a 524288-token dense-attention context; the shape
# is defined for sub-quadratic families (see DESIGN.md §4)
FULL_ATTENTION_ARCHS = {
    "llava_next_34b", "grok_1_314b", "qwen3_moe_235b_a22b",
    "deepseek_coder_33b", "smollm_135m", "granite_8b", "gemma2_9b",
    "whisper_base",
}


def cell_is_applicable(arch: str, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and arch in FULL_ATTENTION_ARCHS:
        return False, ("skipped: long_500k requires sub-quadratic decode; "
                       f"{arch} is full-attention (DESIGN.md §4)")
    return True, ""


def _shape_bytes(txt: str) -> int:
    """Total bytes of every typed shape literal in an HLO snippet."""
    total = 0
    for m in re.finditer(r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|"
                         r"pred|c64|c128)\[([0-9,]*)\]", txt):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in optimized HLO.

    Tracks which computation each op lives in: ops inside non-ENTRY
    computations (scan/while bodies) execute once per trip, but
    cost/byte analysis sees them once — `body_bytes` lets the roofline
    apply the known trip count (= layer count) as a correction factor.
    Matches async (-start) variants too."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    entry_bytes = 0
    body_bytes = 0
    in_entry = False
    pat = re.compile(r"%?[\w.-]+\s*=\s*(\(?[^=]*?)\s*("
                     + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("ENTRY "):
            in_entry = True
            continue
        if re.match(r"^%?[\w.-]+\s*\([%\w]", stripped) and stripped.endswith("{"):
            in_entry = False
            continue
        m = pat.match(stripped)
        if not m:
            continue
        result_txt, kind = m.groups()
        call = stripped[m.end() - 1:]
        operand_txt = call.split("), ")[0] if ")" in call else call
        op_bytes = _shape_bytes(operand_txt)
        res_bytes = _shape_bytes(result_txt)
        b = max(op_bytes, res_bytes)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += b
        if in_entry:
            entry_bytes += b
        else:
            body_bytes += b
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    stats["total_count"] = sum(v["count"] for k, v in stats.items()
                               if isinstance(v, dict))
    stats["entry_bytes"] = entry_bytes
    stats["body_bytes"] = body_bytes
    return stats


def _needs_fsdp(cfg: ArchConfig, mesh) -> bool:
    tp = S.mesh_shape_dict(mesh).get("model", 1)
    per_dev_gb = cfg.n_params() * 2 / tp / 2**30
    return per_dev_gb > 4.0


def build_train_lowered(cfg: ArchConfig, shape: ShapeConfig, mesh,
                        tcfg: TrainStepConfig | None = None):
    from ..train import adamw_init
    ms = S.mesh_shape_dict(mesh)
    n_pods = ms.get("pod", 1)
    grad_compress = (os.environ.get("REPRO_GRAD_COMPRESS", "0") == "1"
                     and n_pods > 1)
    tcfg = tcfg or TrainStepConfig(
        remat=True, n_microbatches=1,
        grad_compress=grad_compress,
        grad_compress_bits=int(os.environ.get("REPRO_GC_BITS", "16")),
        n_pods=n_pods)
    opt_cfg = AdamWConfig()
    step_fn = make_train_step(cfg, tcfg, opt_cfg)

    fsdp = _needs_fsdp(cfg, mesh)
    p_shard = S.param_shardings(cfg, mesh, zero1=fsdp,
                                data_only=tcfg.grad_compress,
                                replicate_embed=tcfg.grad_compress)
    o_shard = S.opt_state_shardings(cfg, mesh, zero1=True)
    batch_sds = S.batch_spec(cfg, shape, mesh)
    b_shard = S.batch_shardings(batch_sds, cfg, mesh)

    pstructs = S.param_structs(cfg)
    ostructs = jax.eval_shape(adamw_init, pstructs)
    state_sds = TrainState(params=pstructs, opt=ostructs)
    state_shard = TrainState(params=p_shard, opt=o_shard)

    jitted = jax.jit(step_fn,
                     in_shardings=(state_shard, b_shard),
                     out_shardings=(state_shard, None),
                     donate_argnums=(0,))
    with use_mesh(mesh):
        lowered = jitted.lower(state_sds, batch_sds)
    return lowered, {"fsdp": fsdp}


def build_prefill_lowered(cfg: ArchConfig, shape: ShapeConfig, mesh):
    def prefill_step(params, batch):
        out = model_forward(cfg, params, batch, logits_mode="last",
                            return_cache=True)
        kv = out.cache.get("kv") if isinstance(out.cache, dict) else None
        return out.logits, kv

    p_shard = S.param_shardings(cfg, mesh, zero1=_needs_fsdp(cfg, mesh))
    batch_sds = S.batch_spec(cfg, shape, mesh)
    b_shard = S.batch_shardings(batch_sds, cfg, mesh)
    jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                     out_shardings=None)
    with use_mesh(mesh):
        lowered = jitted.lower(S.param_structs(cfg), batch_sds)
    return lowered, {}


def build_serve_lowered(cfg: ArchConfig, shape: ShapeConfig, mesh):
    serve = make_serve_step(cfg)
    p_shard = S.param_shardings(cfg, mesh, zero1=False)
    c_sds = S.cache_structs(cfg, shape)
    c_shard = S.cache_shardings(cfg, shape, mesh)
    batch_sds = S.batch_spec(cfg, shape, mesh)
    b_shard = S.batch_shardings(batch_sds, cfg, mesh)
    t_sds = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(serve,
                     in_shardings=(p_shard, c_shard, b_shard["tokens"], None),
                     out_shardings=(b_shard["tokens"], None, c_shard),
                     donate_argnums=(1,))
    with use_mesh(mesh):
        lowered = jitted.lower(S.param_structs(cfg), c_sds,
                               batch_sds["tokens"], t_sds)
    return lowered, {}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             hlo_dir: Path | None = None) -> dict:
    from ..models import layers as _layers
    _layers.MOE_EP_MODE = os.environ.get("REPRO_MOE_EP", "0") == "1"
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    ok, why = cell_is_applicable(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        if shape.kind == "train":
            lowered, extra = build_train_lowered(cfg, shape, mesh)
        elif shape.kind == "prefill":
            lowered, extra = build_prefill_lowered(cfg, shape, mesh)
        else:
            lowered, extra = build_serve_lowered(cfg, shape, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_stats(hlo)
        if hlo_dir is not None:
            hlo_dir.mkdir(parents=True, exist_ok=True)
            pod_tag = "pod2" if multi_pod else "pod1"
            (hlo_dir / f"{arch}__{shape_name}__{pod_tag}.hlo.txt"
             ).write_text(hlo[:available_hlo_budget()])
        result = {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "status": "ok", "t_lower_s": round(t_lower, 1),
            "t_compile_s": round(t_compile, 1),
            "memory": {
                "argument_size_gb": _gb(getattr(mem, "argument_size_in_bytes", 0)),
                "output_size_gb": _gb(getattr(mem, "output_size_in_bytes", 0)),
                "temp_size_gb": _gb(getattr(mem, "temp_size_in_bytes", 0)),
                "peak_gb": _gb(getattr(mem, "peak_memory_in_bytes",
                                       getattr(mem, "temp_size_in_bytes", 0))),
            },
            "cost": {
                "flops": cost.get("flops", -1.0),
                "bytes_accessed": cost.get("bytes accessed", -1.0),
            },
            "collectives": coll,
            **extra,
        }
        return result
    except Exception as e:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}


def available_hlo_budget() -> int:
    return 4_000_000


def _gb(x) -> float:
    try:
        return round(float(x) / 2**30, 3)
    except (TypeError, ValueError):
        return -1.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    hlo_dir = out / "hlo" if args.save_hlo else None

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    for arch in archs:
        for sh in shapes:
            for mp in meshes:
                cells.append((arch, sh, mp))

    for arch, sh, mp in cells:
        tag = f"{arch}__{sh}__{'pod2' if mp else 'pod1'}"
        fn = out / f"{tag}.json"
        if fn.exists() and not args.force:
            print(f"[cached] {tag}")
            continue
        print(f"[run] {tag} ...", flush=True)
        res = run_cell(arch, sh, mp, hlo_dir)
        fn.write_text(json.dumps(res, indent=1))
        status = res["status"]
        extra = ""
        if status == "ok":
            extra = (f" compile={res['t_compile_s']}s "
                     f"flops={res['cost']['flops']:.3g} "
                     f"coll={res['collectives']['total_bytes']:.3g}B")
        elif status == "error":
            extra = " " + res["error"][:200]
        print(f"[{status}] {tag}{extra}", flush=True)


if __name__ == "__main__":
    main()
