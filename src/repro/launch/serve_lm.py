"""LM serving launcher: batched greedy decoding with KV/recurrent caches.
(Moved from ``repro.launch.serve``, which now launches the compression
service.)

  PYTHONPATH=src python -m repro.launch.serve_lm --arch smollm-135m --smoke \
      --batch 4 --prompt-len 16 --new-tokens 32

On a single CPU device this runs the reduced config end-to-end; on a pod
the same script shards params/caches over (data, model) via the dry-run's
spec machinery."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models import init_decode_cache, init_params
from ..serve import make_serve_step
from .mesh import make_host_mesh, make_production_mesh
from ..models.sharding import use_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh() if len(jax.devices()) == 1 \
        else make_production_mesh()
    max_len = args.prompt_len + args.new_tokens

    with use_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        cache = init_decode_cache(cfg, args.batch, max_len)
        step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
        rng = np.random.default_rng(args.seed)
        prompt = jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)

        # prefill token-by-token (decode-path prefill works for all
        # families; attention archs can use serve.make_prefill instead)
        t0 = time.perf_counter()
        cur = prompt[:, :1]
        out = []
        for t in range(max_len - 1):
            tok = prompt[:, t:t + 1] if t < args.prompt_len else cur
            nxt, _, cache = step(params, cache, tok, jnp.int32(t))
            if t >= args.prompt_len - 1:
                out.append(nxt)
                cur = nxt
        gen = jnp.concatenate(out, axis=1)
        jax.block_until_ready(gen)
        dt = time.perf_counter() - t0
        tput = args.batch * gen.shape[1] / dt
        print(f"arch={cfg.name} batch={args.batch} "
              f"generated={gen.shape[1]} tok/req in {dt:.2f}s "
              f"({tput:.1f} tok/s aggregate)")
        print("sample:", np.asarray(gen[0])[:16])
        return gen


if __name__ == "__main__":
    main()
