"""Compression-service launcher: drive ``repro.serve.compression`` with
synthetic streaming traffic and report service metrics (DESIGN.md §6).

  PYTHONPATH=src python -m repro.launch.serve --smoke
  PYTHONPATH=src python -m repro.launch.serve --fields 64 --shape 48,48,48 \
      --window 8 --max-batch 4 --stats-port 8080 --verify

Generates a stream of synthetic scalar fields (mixed shapes/bounds when
``--mixed``), submits them through a ``CompressionService`` — coalesced
into batched device dispatches, entropy coding overlapped on worker
threads — then round-trips every artifact through the decompress stream.
``--devices N`` serves stream members slab-sharded over an N-device
('data',) mesh (emulated on CPU hosts); ``--stats-port P`` exposes the
live stats document at ``http://127.0.0.1:P/stats`` while the run is in
flight. ``--verify`` checks exact MSS preservation and byte-identity
against the one-shot pipeline on every request.

The LM serving launcher this module used to hold lives at
``repro.launch.serve_lm``.
"""
from __future__ import annotations

import argparse
import os
import time


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fields", type=int, default=16,
                    help="number of fields in the synthetic request stream")
    ap.add_argument("--shape", default="24,24,24",
                    help="comma-separated field shape (2D or 3D)")
    ap.add_argument("--xi-rel", type=float, default=1e-3,
                    help="error bound as a fraction of each field's range")
    ap.add_argument("--window", type=int, default=8,
                    help="in-flight request bound (backpressure window)")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="dynamic-batching limit per device dispatch")
    ap.add_argument("--coalesce-ms", type=float, default=2.0,
                    help="linger for batch stragglers before dispatching")
    ap.add_argument("--backend", default="auto",
                    help="stencil backend (auto | reference | pallas | "
                         "pallas_tiled | sharded)")
    ap.add_argument("--devices", type=int, default=0,
                    help="serve stream members slab-sharded over an "
                         "N-device ('data',) mesh (emulated on CPU hosts)")
    ap.add_argument("--mixed", action="store_true",
                    help="mix a second field shape and per-request bounds "
                         "into the traffic (exercises per-spec batching)")
    ap.add_argument("--stats-port", type=int, default=0,
                    help="serve GET /stats JSON on this port while running "
                         "(0 = no HTTP endpoint)")
    ap.add_argument("--verify", action="store_true",
                    help="verify MSS preservation + byte-identity vs the "
                         "one-shot pipeline on every request")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny quick-run preset (implies --verify)")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    args = _parse_args(argv)
    if args.devices > 1:
        # must land before jax initializes its backends (imports below)
        flag = f"--xla_force_host_platform_device_count={args.devices}"
        if flag not in os.environ.get("XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = \
                (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()
    if args.smoke:
        args.fields = min(args.fields, 8)
        args.shape = "12,12,12"
        args.verify = True

    import numpy as np

    from repro.compress import compress_preserving_mss
    from repro.core import verify_preservation
    from repro.data import synthetic_field
    from repro.launch.mesh import make_data_mesh
    from repro.serve import CompressionService, ServiceConfig
    from repro.serve.compression import start_stats_server

    shape = tuple(int(s) for s in args.shape.split(","))
    mesh = None
    if args.devices > 1:
        mesh = make_data_mesh(args.devices)
        print(f"# serving over {args.devices} devices "
              f"(mesh axes {dict(mesh.shape)})")

    shapes = [shape] * args.fields
    if args.mixed:
        alt = tuple(max(s // 2, 8) for s in shape)
        shapes = [shape if i % 3 else alt for i in range(args.fields)]
    rng = np.random.default_rng(args.seed)
    fields = [synthetic_field("nyx", shape=sh, seed=int(rng.integers(1 << 30)))
              .astype(np.float32) for sh in shapes]
    xis = [args.xi_rel * float(np.ptp(f)) for f in fields]
    if args.mixed:
        xis = [x * (0.5 if i % 2 else 1.0) for i, x in enumerate(xis)]

    cfg = ServiceConfig(window=args.window, max_batch=args.max_batch,
                        coalesce_ms=args.coalesce_ms, backend=args.backend,
                        mesh=mesh)
    with CompressionService(cfg) as service:
        server = None
        if args.stats_port:
            server = start_stats_server(service, port=args.stats_port)
            host, port = server.server_address[:2]
            print(f"# stats endpoint: http://{host}:{port}/stats")

        t0 = time.perf_counter()
        comp_futs = [service.submit_compress(f, xi)
                     for f, xi in zip(fields, xis)]
        arts = [fut.result() for fut in comp_futs]
        t_comp = time.perf_counter() - t0

        t0 = time.perf_counter()
        dec_futs = [service.submit_decompress(a) for a in arts]
        outs = [fut.result() for fut in dec_futs]
        t_dec = time.perf_counter() - t0

        if args.verify:
            for f, xi, art, g in zip(fields, xis, arts, outs):
                solo = compress_preserving_mss(f, xi)
                assert art.base_payload == solo.base_payload \
                    and art.edit_payload == solo.edit_payload, \
                    "service artifact differs from the one-shot pipeline"
                rep = verify_preservation(f, g, xi)
                assert rep["mss_preserved"] and rep["bound_ok"], rep
            print(f"# verified: {len(arts)} artifacts byte-identical to the "
                  "one-shot path, MSS preserved on every request")

        st = service.stats()
        for leg, dt in (("compress", t_comp), ("decompress", t_dec)):
            s = st[leg]
            print(f"{leg:10s} {args.fields / dt:8.2f} fields/s  "
                  f"batches={s['batches']:3d}  "
                  f"occupancy={s['batch_occupancy']:.2f}  "
                  f"max_in_flight={s['max_in_flight']}  "
                  f"h2d={s['nbytes_h2d']}B d2h={s['nbytes_d2h']}B  "
                  f"cache={s['cache']['hits']}h/{s['cache']['misses']}m")
        if server is not None:
            server.shutdown()
    print("OK")
    return arts


if __name__ == "__main__":
    main()
