"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 256 --smoke --ckpt-dir /tmp/ckpt

Runs the full production stack (pjit shardings, AdamW, checkpointing,
straggler watchdog, optional gradient compression) on whatever mesh the
current devices support. On the CPU container use --smoke (reduced config,
1x1 mesh); on a real pod the same script shards over (data, model)."""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..data import TokenPipeline
from ..distributed import StepWatchdog
from ..models import init_params
from ..train import (AdamWConfig, TrainState, TrainStepConfig, adamw_init,
                     make_train_step)
from .mesh import make_host_mesh, make_production_mesh
from . import specs as S
from ..models.sharding import use_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    mesh = make_host_mesh() if n_dev == 1 else make_production_mesh()
    print(f"arch={cfg.name} params~{cfg.n_params()/1e6:.1f}M "
          f"devices={n_dev} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    tcfg = TrainStepConfig(n_microbatches=args.microbatches,
                           grad_compress=args.grad_compress,
                           n_pods=S.mesh_shape_dict(mesh).get("pod", 1))
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 20, 5),
                          decay_steps=args.steps)
    step_fn = make_train_step(cfg, tcfg, opt_cfg)

    with use_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        state = TrainState(params=params, opt=adamw_init(params))
        p_shard = S.param_shardings(cfg, mesh)
        o_shard = S.opt_state_shardings(cfg, mesh, zero1=n_dev > 1)
        state_shard = TrainState(params=p_shard, opt=o_shard)
        jitted = jax.jit(step_fn, in_shardings=(state_shard, None),
                         out_shardings=(state_shard, None),
                         donate_argnums=(0,))

        pipe = TokenPipeline(vocab_size=cfg.vocab, batch=args.batch,
                             seq_len=args.seq, seed=args.seed)
        mgr = None
        start_step = 0
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, save_every=args.ckpt_every)
            if args.resume:
                try:
                    state, start_step = mgr.restore_latest(state)
                    print(f"resumed from step {start_step}")
                except FileNotFoundError:
                    print("no checkpoint found; starting fresh")

        wd = StepWatchdog()
        losses = []
        for step in range(start_step, args.steps):
            batch = pipe.get_batch(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if cfg.n_img_tokens:
                batch["image_embeds"] = jnp.zeros(
                    (args.batch, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
            if cfg.enc_dec:
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.enc_positions, cfg.d_model), jnp.bfloat16)
            with wd.timed() as timer:
                state, metrics = jitted(state, batch)
                loss = float(metrics["loss"])
            losses.append(loss)
            if timer.verdict == "rebalance":
                print(f"[watchdog] step {step}: persistent straggling — "
                      "checkpoint + elastic restart recommended")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e}")
            if mgr:
                mgr.maybe_save(step + 1, state)

        print(json.dumps({"final_loss": losses[-1],
                          "first_loss": losses[0],
                          "improved": losses[-1] < losses[0]}))
        return losses


if __name__ == "__main__":
    main()
