"""End-to-end driver #1 (the paper's kind): a compression sweep —
datasets x base compressors x error bounds, verify exact MSS
preservation on every cell, and print the paper's metrics (OCR, OBR, edit
ratio, PSNR, right-labeled ratio before correction).

  PYTHONPATH=src python examples/topo_pipeline.py [--full] [--stream]

Both directions default to the DEVICE-RESIDENT paths (DESIGN.md §4/§5);
every flag combination below produces bitwise-identical artifacts and
outputs — the flags change execution strategy only.

  --full           paper-scale dataset sizes and the full bound sweep
  --backend B      stencil backend for the fix loops
                   (auto | reference | pallas | pallas_tiled | sharded)
  --devices N      slab-shard fix loops/transforms over an N-device
                   ('data',) mesh (emulated on CPU hosts; sets
                   --xla_force_host_platform_device_count before jax
                   initializes)
  --host-path      force the host byte-codec COMPRESS path (default:
                   device-resident whenever preconditions hold)
  --decode-path P  decompression path: auto | host | device
  --stream         route each dataset's szlike cells through the
                   streaming scheduler (repro.compress.stream,
                   DESIGN.md §6) instead of one-shot calls, and print
                   its stats line; artifacts stay byte-identical
"""
import argparse
import os
import time


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backend", default="auto",
                    help="stencil backend for the fix loops "
                         "(auto | reference | pallas | pallas_tiled | "
                         "sharded)")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard the fix loops over an N-device ('data',) "
                         "mesh (emulated on CPU hosts)")
    ap.add_argument("--host-path", action="store_true",
                    help="force the host byte-codec path (default: the "
                         "device-resident path whenever its preconditions "
                         "hold; artifacts are bitwise identical either way)")
    ap.add_argument("--decode-path", default="auto",
                    choices=("auto", "host", "device"),
                    help="decompression path: 'device' forces the "
                         "device-resident decode (szlike artifacts only; "
                         "zfplike rows fall back to auto), 'host' the "
                         "byte-codec loop; outputs are bitwise identical")
    ap.add_argument("--stream", action="store_true",
                    help="serve each dataset's szlike cells through the "
                         "streaming scheduler (DESIGN.md §6) instead of "
                         "one-shot calls; artifacts stay byte-identical")
    return ap.parse_args()


def main():
    args = _parse_args()
    if args.devices > 1:
        # must land before jax initializes its backends (imports below)
        flag = f"--xla_force_host_platform_device_count={args.devices}"
        if flag not in os.environ.get("XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = \
                (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()

    import numpy as np
    import jax.numpy as jnp

    from repro.compress import (compress_preserving_mss, decompress_artifact,
                                decompress_preserving_mss, overall_bit_rate,
                                overall_compression_ratio, psnr, sz_roundtrip,
                                zfp_roundtrip)
    from repro.core import segmentation_accuracy, verify_preservation
    from repro.data import synthetic_field
    from repro.launch.mesh import make_data_mesh

    mesh = None
    if args.devices > 1:
        mesh = make_data_mesh(args.devices)
        print(f"# sharding fix loops over {args.devices} devices "
              f"(mesh axes {dict(mesh.shape)})")
    datasets = {
        "molecular": (24, 24, 12),
        "nyx": (24, 24, 24),
        "climate": (48, 96),
    }
    if args.full:
        datasets = {"molecular": (48, 48, 24), "nyx": (64, 64, 64),
                    "climate": (180, 360), "combustion": (64, 64, 64),
                    "fingering": (48, 48, 48)}
    bounds = (1e-4, 1e-3) if not args.full else (1e-5, 1e-4, 1e-3, 1e-2)

    device_path = False if args.host_path else "auto"
    stream = None
    if args.stream:
        from repro.compress import CompressStream
        stream = CompressStream(window=2 * len(bounds), max_batch=len(bounds),
                                backend=args.backend, mesh=mesh,
                                device_path=device_path)
    print(f"{'dataset':12s} {'base':8s} {'rel_xi':8s} {'raw_right%':>10s} "
          f"{'OCR':>6s} {'OBR':>6s} {'edit%':>7s} {'PSNR':>6s} {'t_fix':>6s} "
          f"{'path':6s} ok")
    for name, shape in datasets.items():
        f = synthetic_field(name, shape=shape)
        rng = float(np.ptp(f))
        for base, rt in (("szlike", sz_roundtrip), ("zfplike", zfp_roundtrip)):
            futs = None
            if stream is not None and base == "szlike":
                # every bound's request in flight at once: same-spec cells
                # coalesce into batched device dispatches
                futs = {rel: stream.submit(f, rel * rng) for rel in bounds}
            for rel in bounds:
                xi = rel * rng
                fh, _ = rt(f, xi)
                raw_acc = float(segmentation_accuracy(jnp.asarray(f),
                                                      jnp.asarray(fh)))
                art = futs[rel].result() if futs is not None else \
                    compress_preserving_mss(f, xi, base=base,
                                            backend=args.backend,
                                            mesh=mesh,
                                            device_path=device_path)
                if args.decode_path == "host":
                    g = decompress_artifact(art)
                else:
                    # 'device' forces the device decode for szlike rows;
                    # zfplike has no device reconstruct, so fall back to
                    # auto there (bitwise identical output either way)
                    dp = True if (args.decode_path == "device"
                                  and base == "szlike") else "auto"
                    g = decompress_preserving_mss(art, device_path=dp,
                                                  backend=args.backend,
                                                  mesh=mesh)
                rep = verify_preservation(f, g, xi)
                ok = rep["mss_preserved"] and rep["bound_ok"]
                print(f"{name:12s} {base:8s} {rel:<8g} {100*raw_acc:10.2f} "
                      f"{overall_compression_ratio(f, art):6.2f} "
                      f"{overall_bit_rate(f, art):6.2f} "
                      f"{100*art.edit_ratio:7.3f} {psnr(f, g):6.1f} "
                      f"{art.t_fix:6.2f} {art.path:6s} {ok}")
                assert ok, (name, base, rel)
    if stream is not None:
        st = stream.stats()
        stream.close()
        print(f"# stream: {st['completed']} cells in {st['batches']} batches, "
              f"occupancy={st['batch_occupancy']:.2f}, "
              f"{st['fields_per_sec']:.2f} fields/s")
    print("all cells preserved MSS exactly within bounds")


if __name__ == "__main__":
    main()
