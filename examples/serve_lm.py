"""End-to-end driver #3: batched serving with KV / recurrent-state caches.
Runs greedy decoding for three architecture families (dense GQA, xLSTM
recurrent-state, hymba hybrid ring-buffer SWA) on reduced configs.

  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import greedy_generate


def main():
    for arch in ("smollm-135m", "xlstm-1.3b", "hymba-1.5b"):
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompt = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, size=(2, 8)),
            jnp.int32)
        out = greedy_generate(cfg, params, prompt, n_new=8)
        assert out.shape == (2, 8), out.shape
        assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))
        # determinism: same prompt -> same continuation
        out2 = greedy_generate(cfg, params, prompt, n_new=8)
        assert bool(jnp.all(out == out2))
        print(f"{cfg.name:18s} generated {out.shape[1]} tokens/req "
              f"(batch={out.shape[0]}): {np.asarray(out[0])[:8]}")
    print("OK")


if __name__ == "__main__":
    main()
