"""Quickstart: MSS-preserving compression of a scalar field in ~20 lines.

  PYTHONPATH=src python examples/quickstart.py

Both directions run the DEVICE-RESIDENT production paths by default
(DESIGN.md §4/§5): one h2d of f, on-device transform + fix loop + edit
extraction, one d2h of the residual codes on the write side; the mirror
on the read side. Host-only byte codecs remain available
(``device_path=False`` / ``decompress_artifact``) and produce
byte-identical artifacts. For streaming/batched serving see the
``CompressStream`` section below and ``repro.serve.compression``.

Correction is codec-agnostic (DESIGN.md §11): pass ``--codec zfplike``
to run the same pipeline over the ZFP-like base instead.
"""
import argparse

import numpy as np

from repro.compress import (available_preserving_codecs,
                            compress_preserving_mss,
                            decompress_preserving_mss,
                            overall_compression_ratio)
from repro.core import verify_preservation
from repro.data import synthetic_field

parser = argparse.ArgumentParser(description=__doc__)
parser.add_argument("--codec", default="szlike",
                    choices=available_preserving_codecs(),
                    help="base codec the MSz edits correct (default: szlike)")
CODEC = parser.parse_args().codec

# a cosmology-like 3D scalar field (stands in for the paper's Nyx data)
f = synthetic_field("nyx", shape=(32, 32, 32))
xi = 1e-3 * float(np.ptp(f))          # absolute error bound

# compress with the chosen base compressor + MSz edits (paper Fig. 3);
# the fix loop dispatches to the pallas stencil backend (auto), falling
# back to the jnp reference stencils for unsupported inputs, and the
# whole stage runs device-resident when its preconditions hold
art = compress_preserving_mss(f, xi, codec=CODEC)
g = decompress_preserving_mss(art)    # the device-resident read path

report = verify_preservation(f, g, xi)
print(f"base codec: {art.base} (payload magic {art.base_magic})")
print(f"stencil backend: {art.backend}")
print(f"compression ratio (incl. edits): {overall_compression_ratio(f, art):.2f}x")
print(f"edit ratio: {art.edit_ratio:.4%} of vertices")
print(f"error bound held:       {report['bound_ok']}  (max|f-g|={report['max_abs_err']:.3g} <= {xi:.3g})")
print(f"MS segmentation exact:  {report['mss_preserved']}")
print(f"right-labeled ratio:    {report['right_labeled_ratio']:.4f}")
assert report["mss_preserved"] and report["bound_ok"]

# batched: a short timestep series through ONE vmapped fix loop
from repro.compress import compress_preserving_mss_batch, decompress_artifact
series = [synthetic_field("nyx", shape=(16, 16, 16), seed=s) for s in range(4)]
xis = [1e-3 * float(np.ptp(fi)) for fi in series]
arts = compress_preserving_mss_batch(series, xis, codec=CODEC)
for t, (fi, xi_i, a) in enumerate(zip(series, xis, arts)):
    rep = verify_preservation(fi, decompress_artifact(a), xi_i)
    assert rep["mss_preserved"] and rep["bound_ok"]
print(f"batch of {len(arts)} timesteps: MSS preserved on every member")

# streaming: the same series through the double-buffered scheduler
# (DESIGN.md §6) — dynamic batching + overlapped entropy coding; every
# artifact byte-identical to its one-shot counterpart
from repro.compress import CompressStream
with CompressStream(window=4, max_batch=4) as cs:
    futs = [cs.submit(fi, xi_i, base=CODEC)
            for fi, xi_i in zip(series, xis)]
    stream_arts = [fut.result() for fut in futs]
    occupancy = cs.stats()["batch_occupancy"]
assert all(sa.base_payload == a.base_payload
           and sa.edit_payload == a.edit_payload
           for sa, a in zip(stream_arts, arts))
print(f"stream of {len(stream_arts)} timesteps: batch occupancy "
      f"{occupancy:.2f}, artifacts byte-identical")
print("OK")
