"""End-to-end driver #2: train a (reduced) smollm-135m for a few hundred
steps on CPU with the full production stack — pjit mesh, AdamW, fault-
tolerant checkpointing (kill it mid-run and re-run with --resume), and
SZ-compressed checkpoint payloads.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--resume", action="store_true")
    a = ap.parse_args()
    argv = ["--arch", "smollm-135m", "--smoke", "--steps", str(a.steps),
            "--batch", "8", "--seq", "128", "--lr", "3e-3",
            "--ckpt-dir", "/tmp/repro_ckpt_demo", "--ckpt-every", "50"]
    if a.resume:
        argv.append("--resume")
    losses = train_main(argv)
    assert losses[-1] < losses[0], "training did not improve the loss"
