"""scatter-discipline: no fancy-index ``+=``/``-=`` on arrays (the
PR 4 bug class, DESIGN.md §10).

``a[idx] += v`` with an array index is a buffered numpy gather-modify-
scatter: duplicate entries in ``idx`` apply ONCE, silently dropping the
rest. PR 4 lost accumulated edit deltas exactly this way. The
deterministic spellings are ``np.add.at(a, idx, v)`` (host) and
``a.at[idx].add(v)`` (jax).

The rule flags augmented add/sub assignment into a subscript whose
index is an array-like expression (a name, call, subscript, or
comparison). Scalar subscripts — constants, attributes, arithmetic on
them, loop scalars like ``a[i] += v`` — are fine: a scalar index cannot
carry duplicates. Sites whose index is unique by construction keep the
fast ``+=`` with an inline suppression stating the uniqueness argument.
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import Config, Finding, SourceModule

RULE = "scatter-discipline"

#: index node kinds that can hold many (possibly duplicate) positions
_ARRAY_INDEX = (ast.Name, ast.Call, ast.Subscript, ast.Compare,
                ast.ListComp, ast.List)


def _scalarish(node: ast.AST) -> bool:
    """Index expressions that denote one position (or a plain slice)."""
    if isinstance(node, (ast.Constant, ast.Attribute)):
        return True
    if isinstance(node, ast.Slice):
        return True
    if isinstance(node, ast.UnaryOp):
        return _scalarish(node.operand)
    if isinstance(node, ast.BinOp):
        return _scalarish(node.left) and _scalarish(node.right)
    if isinstance(node, ast.Tuple):
        return all(_scalarish(e) for e in node.elts)
    return False


def check(module: SourceModule, config: Config) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(module.tree):
        if not (isinstance(node, ast.AugAssign)
                and isinstance(node.op, (ast.Add, ast.Sub))
                and isinstance(node.target, ast.Subscript)):
            continue
        index = node.target.slice
        if _scalarish(index):
            continue
        if not isinstance(index, _ARRAY_INDEX):
            continue
        op = "+=" if isinstance(node.op, ast.Add) else "-="
        findings.append(Finding(
            RULE, module.relpath, node.lineno,
            f"fancy-index `{op}` drops duplicate indices (PR 4 bug "
            f"class) — use `np.add.at`/`.at[].add`, or suppress with "
            f"the uniqueness argument"))
    return findings
