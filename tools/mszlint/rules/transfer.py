"""transfer-discipline: device-stage code crosses host<->device only
through the audited explicit seams (DESIGN.md §4–§5, §10).

The device paths contract ONE h2d and ONE d2h per call, every crossing
routed through ``pipeline._h2d``/``_d2h`` (explicit ``jax.device_put``/
``device_get``, counted by the test transfer hook, permitted by
``debug.no_transfers()``). The bug class is the *implicit* sync —
``np.asarray(device_val)``, ``float(device_scalar)``, ``.item()`` — that
silently serializes the dispatch stream and breaks the contract without
failing any test.

Static typing can't tell a traced value from a host one, so the rule is
a choke point, not an inference engine: inside the audited device-stage
functions (``Config.transfer_check_functions``) EVERY conversion call is
banned unless it is visibly explicit. A conversion passes when

* it wraps, or is wrapped by, an allow-listed explicit-transfer call
  (``_h2d``/``_d2h``/``jax.device_put``/``jax.device_get``), or
* its argument is host-by-construction: a literal, ``len(...)``,
  ``time.perf_counter()``, or a ``.size``/``.nbytes``/``.ndim``/
  ``.shape`` access, or
* the enclosing function is jit-compiled (a decorator mentioning
  ``jit``): inside a trace these calls run on static host values —
  a tracer would raise ``TracerConversionError`` loudly on its own.

Anything else needs an inline suppression stating why the value is
host-resident — which is exactly the audit trail the contract wants.
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import (Config, Finding, SourceModule, call_name,
                      dotted_name, name_matches)

RULE = "transfer-discipline"

#: conversion callees that implicitly sync a traced argument
_CONVERSIONS = ("asarray", "ascontiguousarray", "array")
_BUILTINS = ("float", "int", "bool")
_HOST_ATTRS = ("size", "nbytes", "ndim", "shape", "dtype")
_HOST_CALLS = ("len", "perf_counter", "str", "tuple", "range", "repr")


def _is_jitted(fn: ast.AST) -> bool:
    """Whether a function def is trace-context: a jit decorator
    (``@jax.jit``, ``@functools.partial(jax.jit, ...)``, ``@jit``...)
    or a Pallas ``@pl.when(...)`` kernel closure — both run only under
    trace, where conversions act on static host values."""
    if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return False
    for dec in fn.decorator_list:
        for node in ast.walk(dec):
            name = dotted_name(node) if isinstance(
                node, (ast.Name, ast.Attribute)) else ""
            if name.rsplit(".", 1)[-1] in ("jit", "pjit", "when"):
                return True
    return False


def _host_expr(node: ast.AST) -> bool:
    """Conservatively host-by-construction expressions."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Attribute) and node.attr in _HOST_ATTRS:
        return True
    if isinstance(node, ast.Subscript):
        return _host_expr(node.value)
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name_matches(name, _HOST_CALLS):
            return True
    if isinstance(node, ast.BinOp):
        return _host_expr(node.left) and _host_expr(node.right)
    if isinstance(node, ast.UnaryOp):
        return _host_expr(node.operand)
    return False


def _conversion_call(node: ast.Call) -> str:
    """The banned conversion this call performs, or ''."""
    name = call_name(node)
    if not name:
        return ""
    last = name.rsplit(".", 1)[-1]
    if last in _CONVERSIONS and "." in name:      # np.asarray, jnp.array...
        return name
    if name in _BUILTINS and len(node.args) >= 1:  # float(x), int(x), bool(x)
        return name
    if last == "item" and not node.args:           # x.item()
        return name or "item"
    return ""


def check(module: SourceModule, config: Config) -> List[Finding]:
    checked = config.checked_functions(module.relpath)
    if checked is None:
        return []
    allow = config.transfer_allow_calls
    findings: List[Finding] = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        conv = _conversion_call(node)
        if not conv:
            continue
        fn = module.enclosing_function(node)
        if fn is None:
            continue
        if checked != ("*",) and fn.name not in checked:
            continue
        if _is_jitted(fn):
            continue
        # wrapped by an explicit seam: _h2d(np.asarray(...))
        if any(isinstance(anc, ast.Call)
               and name_matches(call_name(anc), allow)
               for anc in module.ancestors(node)):
            continue
        # wraps an explicit seam: int(_d2h(...))
        args = list(node.args) + [kw.value for kw in node.keywords]
        if any(isinstance(sub, ast.Call)
               and name_matches(call_name(sub), allow)
               for a in args for sub in ast.walk(a)):
            continue
        if args and all(_host_expr(a) for a in args[:1]):
            continue
        findings.append(Finding(
            RULE, module.relpath, node.lineno,
            f"implicit host<->device conversion `{conv}(...)` in audited "
            f"device-stage function `{fn.name}` — route through the "
            f"explicit _h2d/_d2h seams (or suppress with the reason the "
            f"value is host-resident)"))
    return findings
