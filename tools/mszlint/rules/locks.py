"""lock-guard: writes to ``# guarded-by: <lock>`` attributes must sit
lexically inside ``with <lock>:`` (the PR 7 race class, DESIGN.md §10).

Annotation convention — the comment goes where the attribute is
*declared* (same line or the line above)::

    self._pending = {}           # guarded-by: self._lock
    _cache = {}                  # guarded-by: _lock      (module level)

Every later write (``=``, ``+=``, ...) to an annotated attribute is
verified to be lexically inside a ``with`` statement over the named
lock expression. Two lexical escapes exist:

* writes inside the function containing the declaration are exempt
  (``__init__`` publishes the object before any concurrency);
* a function whose ``def`` line carries ``# guarded-by: <lock>`` is
  exempt for that lock — the documented "caller must hold" convention
  for helpers invoked with the lock already taken.

The check is lexical, not an escape analysis: it catches the PR 7 bug
shape (a stats counter bumped outside the critical section) while
staying zero-false-positive enough to run on every push.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from ..engine import Config, Finding, SourceModule

RULE = "lock-guard"

_ANNOT_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][\w.]*)")


def _assign_targets(node: ast.AST) -> List[ast.AST]:
    if isinstance(node, ast.Assign):
        return list(node.targets)
    if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        return [node.target]
    return []


def _attr_key(target: ast.AST, in_class: Optional[str]
              ) -> Optional[Tuple[str, str]]:
    """(scope, name) of a guarded-able target: ``self.X`` inside a class
    -> (class, X); a bare module-level name -> ("", X)."""
    if (isinstance(target, ast.Attribute)
            and isinstance(target.value, ast.Name)
            and target.value.id == "self" and in_class):
        return (in_class, target.attr)
    if isinstance(target, ast.Name) and in_class is None:
        return ("", target.id)
    return None


def _enclosing_class(module: SourceModule, node: ast.AST) -> Optional[str]:
    for anc in module.ancestors(node):
        if isinstance(anc, ast.ClassDef):
            return anc.name
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
    return None


def _module_level(module: SourceModule, node: ast.AST) -> bool:
    return not any(isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef))
                   for a in module.ancestors(node))


def _lock_of_with(item: ast.withitem) -> str:
    try:
        return ast.unparse(item.context_expr).strip()
    except Exception:      # pragma: no cover - unparse is total on 3.9+
        return ""


def check(module: SourceModule, config: Config) -> List[Finding]:
    # pass 1: collect annotations -> {(scope, attr): (lock, decl_fn)}
    annot_lines = {}
    for lineno in range(1, len(module.lines) + 1):
        m = _ANNOT_RE.search(module.line_text(lineno))
        if m:
            annot_lines[lineno] = m.group(1)

    guarded: Dict[Tuple[str, str], str] = {}
    decl_fn: Dict[Tuple[str, str], Optional[ast.AST]] = {}
    fn_holds: Dict[ast.AST, Set[str]] = {}
    def_lines: Set[int] = set()
    for node in ast.walk(module.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            def_lines.add(node.lineno)
            lock = annot_lines.get(node.lineno)
            if lock:       # def-line annotation: caller must hold <lock>
                fn_holds.setdefault(node, set()).add(lock)
    for node in ast.walk(module.tree):
        for target in _assign_targets(node):
            # a def-line annotation marks the function, not the first
            # statement of its body, as lock-related
            lock = (annot_lines.get(node.lineno)
                    if node.lineno not in def_lines else None)
            if not lock and (node.lineno - 1) not in def_lines:
                lock = annot_lines.get(node.lineno - 1)
            if not lock:
                continue
            in_class = _enclosing_class(module, node)
            if in_class is None and not _module_level(module, node):
                continue
            key = _attr_key(target, in_class)
            if key and key not in guarded:      # first annotation wins
                guarded[key] = lock
                decl_fn[key] = module.enclosing_function(node)

    if not guarded:
        return []

    # pass 2: verify every write to a guarded attribute
    findings: List[Finding] = []
    for node in ast.walk(module.tree):
        targets = _assign_targets(node)
        if not targets:
            continue
        for target in targets:
            in_class = _enclosing_class(module, node)
            key = _attr_key(target, in_class)
            if key is None and isinstance(target, ast.Name):
                # function-body write to an annotated module global
                if ("", target.id) in guarded and not _module_level(
                        module, node):
                    key = ("", target.id)
            if key is None or key not in guarded:
                continue
            lock = guarded[key]
            fn = module.enclosing_function(node)
            if fn is not None and fn is decl_fn[key]:
                continue                     # the declaring function
            if fn is None and _module_level(module, node):
                continue                     # module import time
            if fn is not None and lock in fn_holds.get(fn, set()):
                continue                     # documented caller-holds fn
            held = any(
                isinstance(anc, ast.With)
                and any(_lock_of_with(it) == lock for it in anc.items)
                for anc in module.ancestors(node))
            if held:
                continue
            name = f"{key[0]}.{key[1]}" if key[0] else key[1]
            findings.append(Finding(
                RULE, module.relpath, node.lineno,
                f"write to `{name}` (guarded-by: {lock}) outside "
                f"`with {lock}:` — PR 7 race class; take the lock, or "
                f"annotate the def line if the caller must hold it"))
    return findings
