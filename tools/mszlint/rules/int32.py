"""int32-range: cumsum-on-int32 call sites need a reachable range
guard (DESIGN.md §10).

The SZ-like reconstruct is ``d`` nested int32 cumsums; an input whose
running sum exceeds 2^31-1 wraps silently and corrupts every downstream
vertex. The codecs therefore gate on ``szlike.check_int32_range`` (field
magnitude vs step) or ``szlike.codes_fit_int32`` before reconstructing.
This rule flags every ``int32_cumsum(...)`` call — and every
``cumsum``/``jnp.cumsum`` call with an int32 dtype argument — in a
function that neither calls one of the guard predicates itself nor is
one of the implementation/guard functions. Call sites whose inputs are
bounded by construction (word counts, prefix sums over >=0 per-chunk
sizes) suppress inline with that argument.
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import Config, Finding, SourceModule, call_name

RULE = "int32-range"

_GUARDS = ("check_int32_range", "codes_fit_int32")
#: functions that ARE the implementation or the guard — exempt
_IMPL = ("int32_cumsum",) + _GUARDS


def _int32_cumsum_call(node: ast.Call) -> bool:
    name = call_name(node)
    last = name.rsplit(".", 1)[-1] if name else ""
    if last == "int32_cumsum":
        return True
    if last in ("cumsum", "cumulative_sum"):
        for kw in node.keywords:
            if kw.arg == "dtype" and "int32" in ast.unparse(kw.value):
                return True
    return False


def check(module: SourceModule, config: Config) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(module.tree):
        if not (isinstance(node, ast.Call) and _int32_cumsum_call(node)):
            continue
        fn = module.enclosing_function(node)
        if fn is not None and fn.name in _IMPL:
            continue
        scope = fn if fn is not None else module.tree
        has_guard = any(
            isinstance(sub, ast.Call)
            and call_name(sub).rsplit(".", 1)[-1] in _GUARDS
            for sub in ast.walk(scope))
        if has_guard:
            continue
        where = f"`{fn.name}`" if fn is not None else "module scope"
        findings.append(Finding(
            RULE, module.relpath, node.lineno,
            f"int32 cumsum in {where} with no reachable "
            f"check_int32_range/codes_fit_int32 guard — overflow wraps "
            f"silently; add the guard or suppress with the boundedness "
            f"argument"))
    return findings
