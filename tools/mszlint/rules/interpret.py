"""interpret-policy: no literal ``interpret=True/False`` outside
``default_interpret`` (DESIGN.md §10).

The Pallas interpret decision is platform policy, centralized in
``kernels.extrema.default_interpret`` (auto-detect + the
``MSZ_PALLAS_INTERPRET`` override). A literal flag hard-wires one
platform's answer into a call site — the PR 7 calibration bug was this
exact shape: a cache key missing the interpret dimension because a
literal had frozen it. The rule flags

* ``interpret=True`` / ``interpret=False`` keyword literals in any
  call (``pl.pallas_call`` sites and wrappers alike), and
* ``interpret: bool = True/False`` literal defaults in function
  signatures (``interpret=None`` -> resolve via ``default_interpret()``
  is the sanctioned idiom).

Tests asserting lowered-vs-interpret bitwise identity legitimately pin
the flag — they suppress inline with that reason (the rule's default
path config also leaves ``tests/`` out).
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import Config, Finding, SourceModule

RULE = "interpret-policy"


def check(module: SourceModule, config: Config) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if (kw.arg == "interpret"
                        and isinstance(kw.value, ast.Constant)
                        and isinstance(kw.value.value, bool)):
                    findings.append(Finding(
                        RULE, module.relpath, kw.value.lineno,
                        f"literal interpret={kw.value.value} hard-wires "
                        f"one platform's Pallas mode — route through "
                        f"default_interpret() (PR 7 cache-key bug class)"))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name == "default_interpret":
                continue
            args = node.args
            all_args = args.posonlyargs + args.args + args.kwonlyargs
            defaults = ([None] * (len(args.posonlyargs + args.args)
                                  - len(args.defaults))
                        + list(args.defaults) + list(args.kw_defaults))
            for arg, default in zip(all_args, defaults):
                if (arg.arg == "interpret" and default is not None
                        and isinstance(default, ast.Constant)
                        and isinstance(default.value, bool)):
                    findings.append(Finding(
                        RULE, module.relpath, arg.lineno,
                        f"signature default interpret={default.value} — "
                        f"default to None and resolve via "
                        f"default_interpret()"))
    return findings
