"""mszlint rule registry: one module per historical bug class."""
from . import (int32, interpret, locks, scatter,  # noqa: F401
               sentinel, transfer)

#: every rule module exposes RULE (its name) and check(module, config)
ALL_RULES = [transfer, sentinel, scatter, locks, int32, interpret]

__all__ = ["ALL_RULES", "transfer", "sentinel", "scatter", "locks",
           "int32", "interpret"]
