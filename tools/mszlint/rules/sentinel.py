"""sentinel-dtype: ``jnp.inf`` sentinels in kernels must carry the
field dtype (the PR 1 bug class, DESIGN.md §10).

An untyped ``jnp.inf``/``np.inf`` literal is float64 (weak float32
under default jax config) — mixed into an f32/bf16 stencil it silently
promotes, and in the PR 1 incident the ±inf padding sentinel compared
unequal to the field's own cast sentinel, corrupting boundary extrema
classification. The fix idiom is an explicit cast at the use site::

    jnp.asarray(-jnp.inf, slabs.dtype)          # ok
    jnp.full_like(m, -jnp.inf)                  # ok: dtype from m
    jnp.full(shape, jnp.inf, dtype)             # ok: explicit dtype
    s = jnp.where(mask, s, -jnp.inf)            # FLAGGED

The rule flags every ``inf`` attribute of a numpy/jnp module unless a
dtype-carrying constructor encloses it within the same expression
(walking up through unary minus, ternaries, and tuple packing).
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import Config, Finding, SourceModule, call_name

RULE = "sentinel-dtype"

#: constructors that give the sentinel an explicit element type
_TYPED_CTORS = ("asarray", "array", "full", "full_like", "astype",
                "float32", "float64", "bfloat16", "float16")
#: ast nodes the sentinel may sit under while still belonging to the
#: same constructor expression
_TRANSPARENT = (ast.UnaryOp, ast.IfExp, ast.Tuple, ast.List)


def _typed_call(node: ast.Call) -> bool:
    name = call_name(node)
    last = name.rsplit(".", 1)[-1] if name else ""
    if last not in _TYPED_CTORS:
        return False
    if last in ("asarray", "array"):
        # dtype must actually be given: 2nd positional or dtype= kw
        return len(node.args) >= 2 or any(
            kw.arg == "dtype" for kw in node.keywords)
    if last == "full":
        return len(node.args) >= 3 or any(
            kw.arg == "dtype" for kw in node.keywords)
    return True    # full_like/astype/float32(...) carry a dtype inherently


def check(module: SourceModule, config: Config) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(module.tree):
        if not (isinstance(node, ast.Attribute) and node.attr == "inf"):
            continue
        cur: ast.AST = node
        typed = False
        for anc in module.ancestors(node):
            if isinstance(anc, ast.Call) and _typed_call(anc):
                typed = True
                break
            if isinstance(anc, _TRANSPARENT):
                cur = anc
                continue
            if isinstance(anc, ast.Call) and cur in anc.args:
                break      # consumed untyped by some other call
            break
        if not typed:
            findings.append(Finding(
                RULE, module.relpath, node.lineno,
                "untyped inf sentinel — cast to the field dtype "
                "(`jnp.asarray(-jnp.inf, x.dtype)` / `jnp.full_like`), "
                "or an f32 field silently promotes (PR 1 bug class)"))
    return findings
