"""mszlint core: source model, suppression parsing, rule driver.

The engine is deliberately small: it parses each file once into an
``ast`` tree with a parent map, hands a ``SourceModule`` to every rule
whose path globs match, and filters the returned findings through the
inline-suppression table. Rules are pure functions ``check(module,
config) -> list[Finding]`` — no shared state, so fixture tests can run
a single rule against a single in-memory file.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import re
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

#: ``# mszlint: disable=rule-a,rule-b -- optional reason`` (same line or
#: line above a finding); ``disable-file=`` scopes to the whole file
_SUPPRESS_RE = re.compile(
    r"#\s*mszlint:\s*disable(?P<file>-file)?\s*=\s*"
    r"(?P<rules>[a-z0-9_-]+(?:\s*,\s*[a-z0-9_-]+)*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at ``path:line``."""
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Config:
    """Which rule applies where (see ``config.DEFAULT`` for the repo's
    contract surface; fixture tests build narrow ones).

    ``rule_paths``: rule name -> path globs (fnmatch, against the
    repo-relative posix path). A rule skips files no glob matches.

    ``transfer_check_functions``: file glob -> function names whose
    bodies the transfer rule audits (the device-stage surface). ``"*"``
    as the name list audits every function in the file.

    ``transfer_allow_calls``: call names (bare or dotted suffix) that
    perform EXPLICIT transfers — conversions wrapping (or wrapped by)
    these are the audited seams and pass.
    """
    rule_paths: Dict[str, Tuple[str, ...]]
    transfer_check_functions: Dict[str, Tuple[str, ...]] = \
        dataclasses.field(default_factory=dict)
    transfer_allow_calls: Tuple[str, ...] = (
        "_h2d", "_d2h", "device_put", "device_get",
        # repo helpers that route host scalars through jax.device_put
        "typed_operand", "_device_scalar")

    def rule_applies(self, rule: str, relpath: str) -> bool:
        return any(fnmatch.fnmatch(relpath, pat)
                   for pat in self.rule_paths.get(rule, ()))

    def checked_functions(self, relpath: str) -> Optional[Tuple[str, ...]]:
        """Audited function names of ``relpath`` for the transfer rule,
        ``("*",)`` meaning all; None when the file has no entry."""
        for pat, names in self.transfer_check_functions.items():
            if fnmatch.fnmatch(relpath, pat):
                return tuple(names)
        return None


class SourceModule:
    """One parsed file: tree + parent map + suppression table."""

    def __init__(self, relpath: str, text: str):
        self.relpath = relpath
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=relpath)
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        self._file_suppressed: Set[str] = set()
        self._line_suppressed: Dict[int, Set[str]] = {}
        for lineno, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group("rules").split(",")}
            if m.group("file"):
                self._file_suppressed |= rules
            else:
                self._line_suppressed.setdefault(lineno, set()).update(rules)

    # -- tree navigation ------------------------------------------------
    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self._parents.get(node)
        while cur is not None:
            yield cur
            cur = self._parents.get(cur)

    def enclosing_function(self, node: ast.AST
                           ) -> Optional[ast.FunctionDef]:
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    # -- suppressions ---------------------------------------------------
    def suppressed(self, rule: str, line: int) -> bool:
        """A finding of ``rule`` at ``line`` is suppressed by a disable
        comment on the same line, in the contiguous comment block
        directly above (multi-line reasons are encouraged), or
        file-wide."""
        if rule in self._file_suppressed:
            return True
        if rule in self._line_suppressed.get(line, set()):
            return True
        at = line - 1
        while at >= 1 and self.line_text(at).lstrip().startswith("#"):
            if rule in self._line_suppressed.get(at, set()):
                return True
            at -= 1
        return False

    def line_text(self, line: int) -> str:
        return self.lines[line - 1] if 0 < line <= len(self.lines) else ""


def call_name(node: ast.AST) -> str:
    """Dotted name of a call's callee ('' when not a call / not a
    name-like callee): ``np.asarray(x)`` -> ``"np.asarray"``."""
    if not isinstance(node, ast.Call):
        return ""
    return dotted_name(node.func)


def dotted_name(node: ast.AST) -> str:
    """``a.b.c`` for Name/Attribute chains, else ''."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return ""


def name_matches(name: str, patterns: Sequence[str]) -> bool:
    """Whether a dotted callee name matches a pattern list: a pattern
    hits on exact match or as the trailing component (``device_put``
    matches ``jax.device_put``)."""
    if not name:
        return False
    last = name.rsplit(".", 1)[-1]
    return any(name == p or last == p for p in patterns)


def iter_py_files(paths: Sequence[str]) -> Iterator[Path]:
    for p in paths:
        path = Path(p)
        if path.is_file() and path.suffix == ".py":
            yield path
        elif path.is_dir():
            yield from sorted(path.rglob("*.py"))


def lint_source(relpath: str, text: str, config: Config,
                rules: Optional[Sequence] = None) -> List[Finding]:
    """Run every applicable rule over one in-memory file (the fixture-
    test entry point). Returns unsuppressed findings."""
    from . import rules as rules_pkg
    active = rules_pkg.ALL_RULES if rules is None else list(rules)
    relposix = Path(relpath).as_posix()
    applicable = [r for r in active
                  if config.rule_applies(r.RULE, relposix)]
    if not applicable:
        return []
    try:
        module = SourceModule(relposix, text)
    except SyntaxError as e:
        return [Finding("parse-error", relposix, e.lineno or 1,
                        f"could not parse: {e.msg}")]
    out: List[Finding] = []
    for rule in applicable:
        for f in rule.check(module, config):
            if not module.suppressed(f.rule, f.line):
                out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


def lint_paths(paths: Sequence[str], config: Config,
               rules: Optional[Sequence] = None) -> List[Finding]:
    """Lint every ``.py`` file under ``paths`` (files or directories),
    returning all unsuppressed findings."""
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        text = path.read_text(encoding="utf-8")
        findings.extend(lint_source(path.as_posix(), text, config, rules))
    return findings
