"""mszlint: repo-contract static analysis (DESIGN.md §10).

One rule module per historical bug class — each rule mechanizes a
contract that used to be enforced by convention alone and that a past
PR broke anyway:

================== ======================================================
rule               contract (historical bug)
================== ======================================================
transfer-discipline device-stage code moves data host<->device only
                    through the audited ``_h2d``/``_d2h`` seams — no
                    implicit ``np.asarray``/``float()``/``.item()``
                    syncs (the DESIGN.md §4–§5 ONE-h2d/ONE-d2h claim)
sentinel-dtype      ``jnp.inf`` sentinels in kernels must be cast to the
                    field dtype (PR 1: f32 ±inf sentinel bug)
scatter-discipline  no fancy-index ``+=``/``-=`` — duplicate indices
                    silently drop; use ``.at[].add``/``np.add.at``
                    (PR 4)
lock-guard          writes to ``# guarded-by: <lock>`` attributes happen
                    lexically inside ``with <lock>:`` (PR 7: SpecCache
                    race)
int32-range         cumsum-on-int32 call sites carry a reachable
                    ``check_int32_range``/``codes_fit_int32`` guard
interpret-policy    no literal ``interpret=True/False`` outside
                    ``default_interpret`` (PR 7: stale calibration
                    cache key)
================== ======================================================

Suppression syntax (same line or the line above; every intentional
suppression should carry a reason after the rule list)::

    x = np.asarray(v)   # mszlint: disable=transfer-discipline -- host list
    # mszlint: disable-file=scatter-discipline -- numpy-only module

Run: ``python -m tools.mszlint src tests benchmarks``. The runtime
companions (``no_transfers``/``no_recompiles``) live in
``repro.debug.guards``.
"""
from .engine import Config, Finding, lint_paths, lint_source  # noqa: F401
