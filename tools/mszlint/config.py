"""The repo's default lint surface — which rule audits which files.

This is the contract map from DESIGN.md §10: each rule runs only where
its bug class can occur, so a clean ``python -m tools.mszlint src tests
benchmarks`` is a meaningful statement, not a fought-down noise floor.

* ``transfer-discipline`` audits the *device-stage* functions of the
  compress/decompress pipeline, the stream scheduler's device path, the
  distributed layer, and the kernels package (everything there is
  device-facing, so ``"*"``).
* ``sentinel-dtype`` covers the kernels package — the PR 1 ±inf padding
  bug lived in the extrema stencil, and kernels are where an untyped
  sentinel meets an f32/bf16 block.
* ``scatter-discipline`` runs repo-wide over src, tests, and benchmarks:
  a duplicate-dropping ``+=`` is wrong anywhere.
* ``lock-guard`` covers the threaded modules: the stream scheduler, the
  serve-side compression manager, and calibration's process-wide caches.
* ``int32-range`` / ``interpret-policy`` cover all of ``src/repro``.
"""
from __future__ import annotations

from .engine import Config

#: device-stage functions audited by transfer-discipline, per file.
#: ``("*",)`` audits every function in the file.
_TRANSFER_CHECKED = {
    "*/compress/pipeline.py": (
        "_pull_packed",
        "_device_compress",
        "_device_compress_batch",
        "_batch_transform",
        "_pull_batch_codes",
        "_device_batch_stage",
        "_encode_batch_member",
        "_device_pipelined_stage",
        "decompress_preserving_mss",
        "decompress_artifact_batch",
    ),
    "*/compress/stream.py": (
        "_run_device_stage",
        "_device_stage",
        "_pack_batch",
    ),
    # preserve.py: the codec-agnostic correction layer is host-side by
    # design except the device twin of the checked edit encoder, which
    # re-verifies lossy edit dtypes on DEVICE arrays
    "*/compress/preserve.py": (
        "encode_edits_checked_dev",
    ),
    # pack.py: only the device codec entry points — the *_host/_np
    # functions at the bottom are the host mirrors of the codec and
    # convert numpy inputs by contract (first match wins, so this entry
    # precedes the kernels glob)
    "*/kernels/pack.py": (
        "pack_codes_pallas", "unpack_codes_pallas",
        "pack_codes_jnp", "unpack_codes_jnp",
    ),
    "*/distributed/*.py": ("*",),
    "*/kernels/*.py": ("*",),
}

DEFAULT = Config(
    rule_paths={
        "transfer-discipline": (
            "*/compress/pipeline.py",
            "*/compress/preserve.py",
            "*/compress/stream.py",
            "*/distributed/*.py",
            "*/kernels/*.py",
        ),
        "sentinel-dtype": (
            "*/kernels/*.py",
        ),
        "scatter-discipline": (
            "src/*", "*/src/*",
            "tests/*", "*/tests/*",
            "benchmarks/*", "*/benchmarks/*",
        ),
        "lock-guard": (
            "*/compress/stream.py",
            "*/compress/calibrate.py",
            "*/serve/compression.py",
        ),
        "int32-range": (
            "*/repro/*",
        ),
        "interpret-policy": (
            "*/repro/*",
        ),
    },
    transfer_check_functions=_TRANSFER_CHECKED,
)
