"""CLI: ``python -m tools.mszlint [--rule NAME]... PATH...``

Lints every ``.py`` under the given paths against the repo contract
(``config.DEFAULT``), prints ``path:line: [rule] message`` per finding,
and exits 1 if anything fired. CI runs::

    python -m tools.mszlint src tests benchmarks
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from . import rules as rules_pkg
from .config import DEFAULT
from .engine import lint_paths


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="mszlint",
        description="repo-contract static analysis (DESIGN.md §10)")
    parser.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    parser.add_argument("--rule", action="append", default=None,
                        metavar="NAME",
                        help="run only this rule (repeatable)")
    args = parser.parse_args(argv)

    active = None
    if args.rule:
        by_name = {r.RULE: r for r in rules_pkg.ALL_RULES}
        unknown = [n for n in args.rule if n not in by_name]
        if unknown:
            parser.error(f"unknown rule(s): {', '.join(unknown)}; "
                         f"known: {', '.join(sorted(by_name))}")
        active = [by_name[n] for n in args.rule]

    findings = lint_paths(args.paths, DEFAULT, rules=active)
    for f in findings:
        print(f.render())
    if findings:
        print(f"mszlint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
