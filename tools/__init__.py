"""Repo tooling (not shipped with the ``repro`` package).

``tools.mszlint`` is the repo-contract static-analysis pass
(DESIGN.md §10); CI runs ``python -m tools.mszlint src tests
benchmarks`` in the lint job.
"""
