"""Unit + property tests for the core MSz algorithm (paper Sections 4-6)."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hyp_compat import given, settings, st

from repro.core import (steepest_dirs, mss_labels, derive_edits, apply_edits,
                        verify_preservation, segmentation_accuracy,
                        field_topology, false_critical_masks)
from repro.core import ref as R


def _rand_field(rng, shape, dtype=np.float32):
    return rng.normal(size=shape).astype(dtype)


@pytest.mark.parametrize("shape", [(5, 7), (8, 8), (4, 5, 6), (6, 6, 6)])
def test_steepest_dirs_match_oracle(shape):
    rng = np.random.default_rng(42)
    f = _rand_field(rng, shape)
    up, dn = steepest_dirs(jnp.asarray(f))
    upr, dnr = R.steepest_dirs_ref(f)
    np.testing.assert_array_equal(np.asarray(up), upr)
    np.testing.assert_array_equal(np.asarray(dn), dnr)


@pytest.mark.parametrize("shape", [(6, 6), (4, 5, 6)])
def test_mss_labels_match_oracle(shape):
    rng = np.random.default_rng(7)
    f = _rand_field(rng, shape)
    M, m = mss_labels(jnp.asarray(f))
    Mr, mr = R.mss_labels_ref(f)
    np.testing.assert_array_equal(np.asarray(M), Mr)
    np.testing.assert_array_equal(np.asarray(m), mr)


def test_sos_handles_ties():
    # constant field is maximally non-Morse; SoS must still give a total order
    f = np.zeros((5, 5), np.float32)
    M, m = mss_labels(jnp.asarray(f))
    Mr, mr = R.mss_labels_ref(f)
    np.testing.assert_array_equal(np.asarray(M), Mr)
    np.testing.assert_array_equal(np.asarray(m), mr)
    # with SoS by index, the unique max is the largest index, min the smallest
    assert np.all(np.asarray(M) == f.size - 1)
    assert np.all(np.asarray(m) == 0)


@pytest.mark.parametrize("mode", ["fused", "paper"])
@pytest.mark.parametrize("shape", [(9, 11), (6, 7, 8)])
def test_fix_preserves_mss_and_bound(mode, shape):
    rng = np.random.default_rng(3)
    f = _rand_field(rng, shape)
    xi = 0.25
    fh = (f + rng.uniform(-xi, xi, size=shape) * 0.999).astype(np.float32)
    res = derive_edits(f, fh, xi, mode=mode)
    assert res.converged
    v = verify_preservation(f, res.g, xi)
    assert v["mss_preserved"], v
    assert v["bound_ok"], v
    assert v["right_labeled_ratio"] == 1.0
    # all edits are decreasing (Eq. 1)
    assert np.all(res.edits_val <= 0.0)
    # decompression-side application reproduces g exactly
    g2 = apply_edits(fh, res.edits_idx, res.edits_val)
    np.testing.assert_array_equal(g2, res.g)


def test_identity_input_needs_no_edits():
    rng = np.random.default_rng(0)
    f = _rand_field(rng, (8, 9))
    res = derive_edits(f, f.copy(), xi=0.1, mode="fused")
    assert res.edits_idx.size == 0
    assert res.iters <= 1


def test_bound_violation_rejected():
    rng = np.random.default_rng(0)
    f = _rand_field(rng, (6, 6))
    fh = f + 1.0
    with pytest.raises(ValueError, match="error bound"):
        derive_edits(f, fh, xi=0.1)


def test_segmentation_accuracy_metric():
    rng = np.random.default_rng(1)
    f = _rand_field(rng, (16, 16))
    assert float(segmentation_accuracy(jnp.asarray(f), jnp.asarray(f))) == 1.0
    noisy = f + rng.uniform(-0.5, 0.5, f.shape).astype(np.float32)
    acc = float(segmentation_accuracy(jnp.asarray(f), jnp.asarray(noisy)))
    assert 0.0 <= acc <= 1.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), xi=st.floats(0.05, 0.8))
def test_property_2d_fused(seed, xi):
    """Invariants: error bound holds, MSS exactly preserved, edits <= 0.

    Fixed shape: every distinct shape re-jits the while_loop; drawing shapes
    from hypothesis makes the suite compile-bound on CPU."""
    h, w = 9, 11
    rng = np.random.default_rng(seed)
    f = _rand_field(rng, (h, w))
    fh = (f + rng.uniform(-xi, xi, size=(h, w)) * 0.99).astype(np.float32)
    res = derive_edits(f, fh, xi, mode="fused")
    assert res.converged
    v = verify_preservation(f, res.g, xi)
    assert v["mss_preserved"]
    assert v["bound_ok"]
    assert np.all(res.edits_val <= 0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), xi=st.floats(0.05, 0.5))
def test_property_3d_fused(seed, xi):
    rng = np.random.default_rng(seed)
    f = _rand_field(rng, (5, 6, 7))
    fh = (f + rng.uniform(-xi, xi, size=(5, 6, 7)) * 0.99).astype(np.float32)
    res = derive_edits(f, fh, xi, mode="fused")
    assert res.converged
    v = verify_preservation(f, res.g, xi)
    assert v["mss_preserved"] and v["bound_ok"]


def test_false_critical_masks_classes():
    """Hand-built false-critical cases on a monotone ramp."""
    f = np.arange(25, dtype=np.float32).reshape(5, 5)  # true max at (4,4)
    xi = 30.0
    g = f.copy()
    g[2, 2] = 37.0    # above every neighbor -> FPmax (|37-12| <= xi)
    g[4, 4] = 18.5    # below neighbor (3,4)=19 -> the true max is lost: FNmax
    topo = field_topology(jnp.asarray(f), xi)
    fm = false_critical_masks(jnp.asarray(g), topo)
    assert bool(fm.fpmax[2, 2])
    assert bool(fm.fnmax[4, 4])
    # and the fix restores both within bound
    res = derive_edits(f, g, xi, mode="fused")
    v = verify_preservation(f, res.g, xi)
    assert v["mss_preserved"] and v["bound_ok"]
