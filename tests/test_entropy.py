"""Conformance suite for the on-device entropy codec (DESIGN.md §8).

Three implementations of the chunked bitplane packer must agree BIT FOR
BIT on the framed stream — the numpy host mirror (the format's reference
semantics), the jnp codec (reference/sharded backends), and the Pallas
kernel (pallas backends) — because a device-pack artifact written by any
one of them must be readable by all consumers, host decode included.
On top of the kernel identity sit the format-level contracts: SZP1 blobs
round-trip against the DEFLATE SZJ2 codec byte-for-byte at the residual
level (cross-decode equality), artifacts record their codec, truncated
or over-long streams hard-error, and the whole thing holds under
batching and under slab-sharded meshes (1/2/4/8 emulated devices —
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; skipped cleanly
on smaller hosts).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.compress import (compress_preserving_mss,
                            compress_preserving_mss_batch,
                            decompress_artifact_batch,
                            decompress_preserving_mss)
from repro.compress import szlike
from repro.core.backend import resolve_backend
from repro.data import synthetic_field
from repro.distributed import ShardedBackend
from repro.kernels import pack
from repro.launch.mesh import make_data_mesh

N_AVAIL = len(jax.devices())

INT32_MIN, INT32_MAX = np.int32(-2**31), np.int32(2**31 - 1)


def _adversarial_cases():
    """int32 code arrays that stress the bitplane layout: chunk-boundary
    sizes, full-width magnitudes, sign edges, constants, empties."""
    rng = np.random.default_rng(7)
    C = pack.CHUNK
    return {
        "empty": np.zeros(0, np.int32),
        "zeros": np.zeros(3 * C + 11, np.int32),
        "ones": np.ones(C - 1, np.int32),
        "minus_one": np.full(C + 1, -1, np.int32),
        "int32_min": np.full(17, INT32_MIN, np.int32),
        "int32_extremes": np.array(
            [INT32_MIN, INT32_MAX, 0, -1, 1,
             INT32_MIN + 1, INT32_MAX - 1], np.int32),
        "small": rng.integers(-5, 6, size=C // 2).astype(np.int32),
        "mixed_chunks": np.concatenate([
            rng.integers(-3, 4, size=C),             # narrow chunk
            rng.integers(-2**20, 2**20, size=C),     # wide chunk
            np.zeros(C, np.int32),                   # zero chunk (b=0)
            rng.integers(-2**30, 2**30, size=37),    # ragged tail
        ]).astype(np.int32),
        "chunk_exact": rng.integers(-1000, 1000, size=2 * C).astype(np.int32),
        "powers": np.array([-(2**k) for k in range(31)] +
                           [2**k for k in range(31)], np.int32),
    }


@pytest.mark.parametrize("name,codes", sorted(_adversarial_cases().items()))
def test_pack_three_way_bit_identity(name, codes):
    """host mirror == jnp == pallas on words, bits, and n_words."""
    w_h, b_h = pack.pack_codes_host(codes)
    w_j, b_j, n_j = pack.pack_codes_jnp(jnp.asarray(codes))
    w_p, b_p, n_p = pack.pack_codes_pallas(jnp.asarray(codes))
    for tag, (w, b, n) in [("jnp", (w_j, b_j, n_j)),
                           ("pallas", (w_p, b_p, n_p))]:
        assert int(n) == w_h.size, (name, tag)
        np.testing.assert_array_equal(
            np.asarray(w)[:int(n)], w_h, err_msg=f"{name}/{tag} words")
        np.testing.assert_array_equal(
            np.asarray(b), b_h, err_msg=f"{name}/{tag} bits")
    # and every unpacker inverts every packer's stream
    back_h = pack.unpack_codes_host(w_h, b_h, codes.size)
    np.testing.assert_array_equal(back_h, codes)
    back_j = np.asarray(pack.unpack_codes_jnp(
        jnp.asarray(w_h), jnp.asarray(b_h.astype(np.int32)), (codes.size,)))
    np.testing.assert_array_equal(back_j, codes)
    back_p = np.asarray(pack.unpack_codes_pallas(
        jnp.asarray(w_h), jnp.asarray(b_h.astype(np.int32)), (codes.size,)))
    np.testing.assert_array_equal(back_p, codes)


def test_unpack_host_rejects_corrupt_streams():
    codes = np.arange(-600, 600, dtype=np.int32)
    words, bits = pack.pack_codes_host(codes)
    with pytest.raises(ValueError):                    # truncated words
        pack.unpack_codes_host(words[:-1], bits, codes.size)
    with pytest.raises(ValueError):                    # over-long words
        pack.unpack_codes_host(
            np.concatenate([words, words[:1]]), bits, codes.size)
    with pytest.raises(ValueError):                    # bits table missized
        pack.unpack_codes_host(words, bits[:-1], codes.size)
    bad = bits.copy()
    bad[0] = 33                                        # bits out of range
    with pytest.raises(ValueError):
        pack.unpack_codes_host(words, bad, codes.size)


def test_szp1_blob_roundtrip_and_entropy_probe():
    r = np.arange(-130, 126, dtype=np.int64).reshape(16, 16)
    step = 0.25
    sz = szlike.sz_encode_residuals(r, r.shape, np.dtype(np.float32), step)
    dp = szlike.sz_encode_residuals(r, r.shape, np.dtype(np.float32), step,
                                    entropy="device-pack")
    assert szlike.sz_blob_entropy(sz) == "deflate"
    assert szlike.sz_blob_entropy(dp) == "device-pack"
    with pytest.raises(ValueError):
        szlike.sz_blob_entropy(b"JUNKJUNKJUNKJUNK")
    # cross-decode: both codecs reconstruct the identical residual array
    np.testing.assert_array_equal(szlike.sz_decode_residuals(sz)[0],
                                  szlike.sz_decode_residuals(dp)[0])
    np.testing.assert_array_equal(szlike.sz_decode_residuals(dp)[0], r)
    # truncation / trailing garbage hard-error
    with pytest.raises(ValueError):
        szlike.sz_parse_packed(dp[:-3])
    with pytest.raises(ValueError):
        szlike.sz_parse_packed(dp + b"\x00")
    with pytest.raises(ValueError):
        szlike.sz_parse_packed(dp[:20])


@pytest.mark.parametrize("shape", [(8, 8, 8), (12, 10)])
def test_artifact_cross_codec_bitwise(shape):
    """One field, both codecs, host and device paths: every decompression
    route lands on the identical array."""
    f = synthetic_field("nyx", shape=shape, seed=3).astype(np.float32)
    xi = 1e-3 * float(np.ptp(f))
    arts = {}
    for entropy in szlike.ENTROPIES:
        for dev in (True, False):
            a = compress_preserving_mss(f, xi, entropy=entropy,
                                        device_path=dev)
            assert a.entropy == entropy
            assert szlike.sz_blob_entropy(a.base_payload) == entropy
            arts[(entropy, dev)] = a
    # device and host writers of one codec emit identical payloads
    for entropy in szlike.ENTROPIES:
        assert arts[(entropy, True)].base_payload == \
            arts[(entropy, False)].base_payload
    gs = {k: decompress_preserving_mss(a) for k, a in arts.items()}
    ref = gs[("deflate", False)]
    for k, g in gs.items():
        np.testing.assert_array_equal(g, ref, err_msg=str(k))
    # the device read fast path and the forced host read agree too
    g_host = decompress_preserving_mss(arts[("device-pack", True)],
                                       device_path=False)
    np.testing.assert_array_equal(g_host, ref)


def test_artifact_cross_codec_f64():
    from jax.experimental import enable_x64
    f = synthetic_field("nyx", shape=(6, 7, 8), seed=5).astype(np.float64)
    xi = 1e-6 * float(np.ptp(f))
    with enable_x64():
        a_sz = compress_preserving_mss(f, xi, entropy="deflate")
        a_dp = compress_preserving_mss(f, xi, entropy="device-pack")
        g_sz = decompress_preserving_mss(a_sz)
        g_dp = decompress_preserving_mss(a_dp)
    assert a_dp.dtype == "float64"
    np.testing.assert_array_equal(g_sz, g_dp)


def test_constant_field_device_pack():
    f = np.full((8, 8, 8), 2.5, np.float32)
    a = compress_preserving_mss(f, 1e-3, entropy="device-pack")
    g = decompress_preserving_mss(a)
    assert np.max(np.abs(g - f)) <= 1e-3 * (1 + 1e-9)


def test_batch_cross_codec_bitwise():
    fields = [synthetic_field("nyx", shape=(8, 8, 8), seed=s)
              .astype(np.float32) for s in range(3)]
    xi = [1e-3 * float(np.ptp(f)) for f in fields]
    solo = [compress_preserving_mss(f, x, entropy="device-pack")
            for f, x in zip(fields, xi)]
    batch = compress_preserving_mss_batch(fields, xi, entropy="device-pack")
    for a, s in zip(batch, solo):
        assert a.base_payload == s.base_payload
        assert a.edit_payload == s.edit_payload
        assert a.entropy == "device-pack"
    want = [decompress_preserving_mss(s) for s in solo]
    got = decompress_artifact_batch(batch)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_entropy_validation_errors():
    f = synthetic_field("nyx", shape=(8, 8, 8), seed=0).astype(np.float32)
    with pytest.raises(ValueError, match="entropy"):
        compress_preserving_mss(f, 1e-3, entropy="huffman")
    with pytest.raises(ValueError, match="szlike"):
        compress_preserving_mss(f, 1e-3, base="zfplike",
                                entropy="device-pack")
    with pytest.raises(ValueError, match="entropy"):
        szlike.sz_encode_residuals(np.zeros(4, np.int64), (4,),
                                   np.dtype(np.float32), 0.1,
                                   entropy="huffman")


@pytest.mark.parametrize("n_dev", [1, 2, 4, 8])
def test_sharded_pack_matches_host(n_dev):
    if N_AVAIL < n_dev:
        pytest.skip(
            f"needs {n_dev} devices, have {N_AVAIL} (run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    mesh = make_data_mesh(n_dev)
    rng = np.random.default_rng(n_dev)
    codes = rng.integers(-2**25, 2**25,
                         size=3 * pack.CHUNK + 100).astype(np.int32)
    be = ShardedBackend(mesh=mesh)
    w, b, n = be.pack_codes(jnp.asarray(codes))
    w_h, b_h = pack.pack_codes_host(codes)
    assert int(n) == w_h.size
    np.testing.assert_array_equal(np.asarray(w)[:int(n)], w_h)
    np.testing.assert_array_equal(np.asarray(b), b_h)
    back = be.unpack_codes(jnp.asarray(w_h),
                           jnp.asarray(b_h.astype(np.int32)), (codes.size,))
    np.testing.assert_array_equal(np.asarray(back), codes)


@pytest.mark.parametrize("n_dev", [2, 4])
def test_sharded_artifact_device_pack_parity(n_dev):
    if N_AVAIL < n_dev:
        pytest.skip(
            f"needs {n_dev} devices, have {N_AVAIL} (run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    mesh = make_data_mesh(n_dev)
    f = synthetic_field("nyx", shape=(8, 8, 8), seed=1).astype(np.float32)
    xi = 1e-3 * float(np.ptp(f))
    ref = compress_preserving_mss(f, xi, entropy="device-pack")
    a = compress_preserving_mss(f, xi, entropy="device-pack", mesh=mesh)
    assert a.base_payload == ref.base_payload  # mesh changes execution only
    assert a.edit_payload == ref.edit_payload
    g = decompress_preserving_mss(a, mesh=mesh)
    np.testing.assert_array_equal(g, decompress_preserving_mss(ref))


def test_backend_protocol_entries_agree():
    """reference and pallas backend protocol entries match the host
    mirror on a residual-shaped payload (what the pipeline feeds them)."""
    rng = np.random.default_rng(2)
    codes = rng.integers(-300, 300, size=(9, 9, 9)).astype(np.int32)
    flat = codes.ravel()
    w_h, b_h = pack.pack_codes_host(flat)
    for name in ("reference", "pallas"):
        be = resolve_backend(name, codes.shape, np.dtype(np.float32))
        w, b, n = be.pack_codes(jnp.asarray(codes))
        assert int(n) == w_h.size, name
        np.testing.assert_array_equal(np.asarray(w)[:int(n)], w_h,
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(b), b_h, err_msg=name)
        back = be.unpack_codes(jnp.asarray(w_h),
                               jnp.asarray(b_h.astype(np.int32)),
                               codes.shape)
        np.testing.assert_array_equal(np.asarray(back), codes, err_msg=name)
