"""Stencil-backend dispatch: parity, tiling, batching, registry.

The contract under test (core.backend): every backend — and every
execution strategy within the pallas backend (untiled / Z-tiled /
batched) — produces bitwise-identical fields AND iteration counts.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (PallasBackend, ReferenceBackend, available_backends,
                        derive_edits, derive_edits_batch, field_topology,
                        fused_fix, fused_fix_batch, get_backend,
                        resolve_backend, verify_preservation)
from repro.compress import (compress_preserving_mss,
                            compress_preserving_mss_batch,
                            decompress_artifact)


def _pair(shape, seed=0, xi=0.3):
    rng = np.random.default_rng(seed)
    f = rng.normal(size=shape).astype(np.float32)
    fh = (f + rng.uniform(-xi, xi, size=shape) * 0.999).astype(np.float32)
    return f, fh, xi


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_contents():
    names = available_backends()
    assert "reference" in names and "pallas" in names
    assert get_backend("reference").name == "reference"
    assert get_backend(PallasBackend(z_tile=2)).z_tile == 2
    with pytest.raises(ValueError, match="unknown stencil backend"):
        get_backend("no_such_backend")


def test_resolve_auto_prefers_pallas_and_falls_back():
    assert resolve_backend("auto", (8, 8, 8), np.float32).name == "pallas"
    # integer fields are outside the pallas contract -> reference
    assert resolve_backend("auto", (8, 8), np.int32).name == "reference"
    with pytest.raises(ValueError, match="does not support"):
        resolve_backend("pallas", (8, 8), np.int32)


# ---------------------------------------------------------------------------
# bitwise parity reference <-> pallas, 2D and 3D
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(9, 11), (12, 16), (5, 6, 7), (8, 6, 10)])
def test_backend_parity_bitwise(shape):
    f, fh, xi = _pair(shape, seed=hash(shape) % 97)
    topo = field_topology(jnp.asarray(f), xi)
    g_r, it_r, ok_r = fused_fix(jnp.asarray(fh), topo, backend="reference")
    g_p, it_p, ok_p = fused_fix(jnp.asarray(fh), topo, backend="pallas")
    np.testing.assert_array_equal(np.asarray(g_r), np.asarray(g_p))
    assert int(it_r) == int(it_p)
    assert bool(ok_r) and bool(ok_p)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("shape", [(9, 11), (6, 7, 8)])
def test_derive_edits_end_to_end_per_backend(backend, shape):
    f, fh, xi = _pair(shape, seed=3)
    res = derive_edits(f, fh, xi, backend=backend)
    assert res.converged
    assert res.backend == backend
    v = verify_preservation(f, res.g, xi)
    assert v["mss_preserved"], v
    assert v["bound_ok"], v


def test_default_production_path_is_pallas():
    f, fh, xi = _pair((6, 7, 8), seed=9)
    res = derive_edits(f, fh, xi)          # defaults: mode=fused, auto
    assert res.backend == "pallas"


# ---------------------------------------------------------------------------
# Z-tiled execution (pMSz-style halo exchange)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,tile", [((13, 6, 7), 3), ((29, 11), 5)])
def test_tiled_matches_untiled_bitwise(shape, tile):
    f, fh, xi = _pair(shape, seed=5)
    topo = field_topology(jnp.asarray(f), xi)
    g_u, it_u, ok_u = fused_fix(jnp.asarray(fh), topo, backend="pallas")
    tiled = PallasBackend(z_tile=tile)
    g_t, it_t, ok_t = fused_fix(jnp.asarray(fh), topo, backend=tiled)
    np.testing.assert_array_equal(np.asarray(g_u), np.asarray(g_t))
    assert int(it_u) == int(it_t)
    assert bool(ok_u) and bool(ok_t)


def test_vmem_budget_triggers_tiling():
    """A field taller than the slab budget must auto-tile — and still match
    the untiled result exactly."""
    f, fh, xi = _pair((12, 5, 6), seed=6)
    topo = field_topology(jnp.asarray(f), xi)
    budgeted = PallasBackend(vmem_slab_budget=4)
    assert budgeted._pick_tile(12) == 4           # tiling engages
    g_t, it_t, _ = fused_fix(jnp.asarray(fh), topo, backend=budgeted)
    g_u, it_u, _ = fused_fix(jnp.asarray(fh), topo, backend="pallas")
    np.testing.assert_array_equal(np.asarray(g_t), np.asarray(g_u))
    assert int(it_t) == int(it_u)


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_fused_fix_batch_matches_solo(backend):
    shape, xi, B = (5, 6, 7), 0.3, 4
    rng = np.random.default_rng(11)
    fs = np.stack([rng.normal(size=shape).astype(np.float32)
                   for _ in range(B)])
    fhs = np.stack([(fi + rng.uniform(-xi, xi, size=shape) * 0.999)
                    .astype(np.float32) for fi in fs])
    results = derive_edits_batch(fs, fhs, xi, backend=backend)
    assert len(results) == B
    for fi, fhi, res in zip(fs, fhs, results):
        solo = derive_edits(fi, fhi, xi, backend=backend)
        np.testing.assert_array_equal(res.g, solo.g)
        assert res.iters == solo.iters
        assert res.converged
        assert verify_preservation(fi, res.g, xi)["mss_preserved"]


def test_pipeline_batch_roundtrip_preserves_mss():
    """>=4 fields through the batch compression API: every member must
    decompress to a field with the original's exact MSS."""
    from repro.data import synthetic_field
    B, shape = 4, (10, 12, 8)
    fields = [synthetic_field("molecular", shape=shape, seed=s)
              for s in range(B)]
    xi = [0.02 * float(np.ptp(fi)) for fi in fields]
    arts = compress_preserving_mss_batch(fields, xi, base="szlike")
    assert len(arts) == B
    for fi, xi_i, art in zip(fields, xi, arts):
        g = decompress_artifact(art)
        v = verify_preservation(fi, g, xi_i)
        assert v["mss_preserved"], v
        assert v["bound_ok"], v
        assert art.backend == "pallas"
    # batch artifacts match solo-pipeline artifacts byte-for-byte
    solo = compress_preserving_mss(fields[0], xi[0], base="szlike")
    assert arts[0].edit_payload == solo.edit_payload
    assert arts[0].base_payload == solo.base_payload
