"""Sharded-parity suite: the slab-sharded SPMD fix loop
(repro.distributed.shardfix) must be BITWISE equal to the single-device
``reference`` and ``pallas`` backends — fields, violation counts, and
iteration counts — across device counts, 2D and 3D, including slab
counts not divisible by the device count.

Multi-device cases need emulated devices:
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the second tier-1
CI job sets this); on a 1-device host they skip cleanly.
"""
import functools

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (available_backends, derive_edits, derive_edits_batch,
                        field_topology, fused_fix, get_backend,
                        resolve_backend, verify_preservation)
from repro.compress import compress_preserving_mss, decompress_artifact
from repro.distributed import (ShardedBackend, active_data_mesh,
                               data_axis_size, sharded_fix)
from repro.launch.mesh import make_data_mesh

N_AVAIL = len(jax.devices())


def _mesh_or_skip(n_dev: int):
    if N_AVAIL < n_dev:
        pytest.skip(
            f"needs {n_dev} devices, have {N_AVAIL} (run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    return make_data_mesh(n_dev)


def _pair(shape, seed=0, xi=0.3):
    rng = np.random.default_rng(seed)
    f = rng.normal(size=shape).astype(np.float32)
    fh = (f + rng.uniform(-xi, xi, size=shape) * 0.999).astype(np.float32)
    return f, fh, xi


@functools.lru_cache(maxsize=None)
def _solo_results(shape):
    """Single-device (reference, pallas) trajectories for one test pair."""
    f, fh, xi = _pair(shape, seed=sum(shape))
    topo = field_topology(jnp.asarray(f), xi)
    g_r, it_r, ok_r = fused_fix(jnp.asarray(fh), topo, backend="reference")
    g_p, it_p, ok_p = fused_fix(jnp.asarray(fh), topo, backend="pallas")
    np.testing.assert_array_equal(np.asarray(g_r), np.asarray(g_p))
    assert int(it_r) == int(it_p) and bool(ok_r) and bool(ok_p)
    return f, fh, xi, topo, np.asarray(g_p), int(it_p)


# ---------------------------------------------------------------------------
# registry / resolution
# ---------------------------------------------------------------------------

def test_registry_has_sharded():
    assert "sharded" in available_backends()
    assert get_backend("sharded").name == "sharded"


def test_sharded_unusable_without_mesh_raises():
    be = ShardedBackend()
    if active_data_mesh() is None:
        with pytest.raises(ValueError, match="needs a mesh"):
            be.bind()


def test_auto_selects_sharded_under_active_mesh():
    mesh = _mesh_or_skip(2)
    with mesh:
        assert data_axis_size(active_data_mesh()) == 2
        be = resolve_backend("auto", (8, 6, 10), np.float32)
        assert be.name == "sharded" and be.mesh is not None
    # outside the context the single-device default is unchanged
    assert resolve_backend("auto", (8, 6, 10), np.float32).name == "pallas"
    # explicit mesh wins without a context
    be = resolve_backend("auto", (8, 6, 10), np.float32, mesh=mesh)
    assert be.name == "sharded"
    # a 1-device mesh is NOT worth the SPMD detour in auto mode
    assert resolve_backend("auto", (8, 6, 10), np.float32,
                           mesh=make_data_mesh(1)).name == "pallas"


# ---------------------------------------------------------------------------
# bitwise parity of the full fix loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_dev,shape", [
    (1, (13, 6, 7)),            # degenerate chain (runs on any host)
    (2, (13, 6, 7)),            # 13 slabs over 2 -> pad 1
    (4, (13, 6, 7)),            # pad 3
    (8, (13, 6, 7)),            # pad 3, blocks of 2
    (2, (8, 6, 10)),            # divisible
    (4, (12, 16)),              # 2D, divisible
    (2, (29, 11)),              # 2D, pad 1
    (8, (29, 11)),              # 2D, pad 3
])
def test_sharded_parity_bitwise(n_dev, shape):
    mesh = _mesh_or_skip(n_dev)
    f, fh, xi, topo, g_solo, it_solo = _solo_results(shape)
    g_s, it_s, ok_s = fused_fix(jnp.asarray(fh), topo, backend="sharded",
                                mesh=mesh)
    np.testing.assert_array_equal(np.asarray(g_s), g_solo)
    assert int(it_s) == it_solo
    assert bool(ok_s)


def test_more_devices_than_slabs():
    """8-device chain over a 3-slab field: five devices hold only padding
    and must not perturb the result."""
    mesh = _mesh_or_skip(8)
    f, fh, xi, topo, g_solo, it_solo = _solo_results((3, 5, 6))
    g_s, it_s, ok_s = fused_fix(jnp.asarray(fh), topo, backend="sharded",
                                mesh=mesh)
    np.testing.assert_array_equal(np.asarray(g_s), g_solo)
    assert int(it_s) == it_solo and bool(ok_s)


def test_sharded_fix_direct_entrypoint():
    mesh = _mesh_or_skip(2)
    f, fh, xi, topo, g_solo, it_solo = _solo_results((8, 6, 10))
    g_s, it_s, ok_s = sharded_fix(jnp.asarray(fh), topo, mesh)
    np.testing.assert_array_equal(np.asarray(g_s), g_solo)
    assert int(it_s) == it_solo and bool(ok_s)


def test_single_step_parity():
    """One fused_step through the protocol: sharded == pallas, including
    the violation count (the convergence predicate)."""
    mesh = _mesh_or_skip(4)
    f, fh, xi, topo, _, _ = _solo_results((13, 6, 7))
    g2_p, v_p = get_backend("pallas").fused_step(jnp.asarray(fh), topo)
    g2_s, v_s = ShardedBackend(mesh=mesh).fused_step(jnp.asarray(fh), topo)
    np.testing.assert_array_equal(np.asarray(g2_p), np.asarray(g2_s))
    assert int(v_p) == int(v_s)


# ---------------------------------------------------------------------------
# end-to-end: derive_edits / compression artifacts byte-for-byte
# ---------------------------------------------------------------------------

def test_derive_edits_sharded_end_to_end():
    mesh = _mesh_or_skip(4)
    f, fh, xi = _pair((13, 6, 7), seed=17)
    solo = derive_edits(f, fh, xi, backend="pallas")
    res = derive_edits(f, fh, xi, mesh=mesh)
    assert res.backend == "sharded"
    assert res.converged and res.iters == solo.iters
    np.testing.assert_array_equal(res.g, solo.g)
    np.testing.assert_array_equal(res.edits_idx, solo.edits_idx)
    np.testing.assert_array_equal(res.edits_val, solo.edits_val)
    v = verify_preservation(f, res.g, xi)
    assert v["mss_preserved"] and v["bound_ok"], v


def test_compress_artifact_parity():
    """Artifacts from the sharded path are byte-for-byte the single-device
    artifacts (so a sharded compressor farm and a single-chip decompressor
    interoperate freely)."""
    mesh = _mesh_or_skip(2)
    from repro.data import synthetic_field
    f = synthetic_field("molecular", shape=(10, 12, 8), seed=3)
    xi = 0.02 * float(np.ptp(f))
    solo = compress_preserving_mss(f, xi, base="szlike")
    shard = compress_preserving_mss(f, xi, base="szlike", mesh=mesh)
    assert shard.backend == "sharded"
    assert shard.edit_payload == solo.edit_payload
    assert shard.base_payload == solo.base_payload
    g = decompress_artifact(shard)
    v = verify_preservation(f, g, xi)
    assert v["mss_preserved"] and v["bound_ok"], v


def test_derive_edits_batch_sharded_matches_solo():
    mesh = _mesh_or_skip(2)
    shape, xi, B = (8, 6, 10), 0.3, 2
    rng = np.random.default_rng(23)
    fs = np.stack([rng.normal(size=shape).astype(np.float32)
                   for _ in range(B)])
    fhs = np.stack([(fi + rng.uniform(-xi, xi, size=shape) * 0.999)
                    .astype(np.float32) for fi in fs])
    results = derive_edits_batch(fs, fhs, xi, mesh=mesh)
    assert len(results) == B
    for fi, fhi, res in zip(fs, fhs, results):
        assert res.backend == "sharded"
        solo = derive_edits(fi, fhi, xi, backend="pallas")
        np.testing.assert_array_equal(res.g, solo.g)
        assert res.iters == solo.iters and res.converged
