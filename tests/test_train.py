"""Integration tests: training loop learns, checkpoint round-trips,
optimizer math, straggler watchdog policy, gradient compression bounds."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint, restore_checkpoint, CheckpointManager
from repro.configs import get_smoke_config
from repro.data import TokenPipeline
from repro.distributed import StepWatchdog
from repro.distributed.compression import quantize_tree, dequantize_tree
from repro.models import init_params
from repro.train import (AdamWConfig, TrainState, TrainStepConfig, adamw_init,
                         make_train_step, cross_entropy)
from repro.train.step import chunked_cross_entropy


def _smoke_state(arch="smollm-135m", seed=0):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, TrainState(params=params, opt=adamw_init(params))


def test_train_loss_decreases():
    cfg, state = _smoke_state()
    tcfg = TrainStepConfig(remat=False)
    opt = AdamWConfig(lr_peak=1e-2, warmup_steps=2, decay_steps=60)
    step = jax.jit(make_train_step(cfg, tcfg, opt))
    pipe = TokenPipeline(vocab_size=cfg.vocab, batch=4, seq_len=64)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(0).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_microbatched_matches_single():
    cfg, state = _smoke_state()
    opt = AdamWConfig(lr_peak=1e-3, warmup_steps=1, decay_steps=10)
    pipe = TokenPipeline(vocab_size=cfg.vocab, batch=4, seq_len=32)
    batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(0).items()}
    outs = []
    for nmb in (1, 2):
        tcfg = TrainStepConfig(remat=False, n_microbatches=nmb)
        step = jax.jit(make_train_step(cfg, tcfg, opt))
        s2, m = step(state, batch)
        outs.append(s2.params["final_norm"])
    np.testing.assert_allclose(np.asarray(outs[0], np.float32),
                               np.asarray(outs[1], np.float32),
                               rtol=0, atol=5e-3)


def test_chunked_ce_matches_dense():
    rng = np.random.default_rng(0)
    B, S, d, V = 2, 16, 8, 32
    hidden = jnp.asarray(rng.normal(size=(B, S, d)).astype(np.float32))
    unembed = jnp.asarray(rng.normal(size=(d, V)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, V, size=(B, S)).astype(np.int32))
    logits = jnp.einsum("bsd,dv->bsv", hidden, unembed)
    dense, n1 = cross_entropy(logits, labels, z_loss=1e-4)
    chunked, n2 = chunked_cross_entropy(hidden, unembed, labels,
                                        softcap=None, z_loss=1e-4, chunk=4)
    np.testing.assert_allclose(float(dense), float(chunked), rtol=1e-5)
    assert float(n1) == float(n2)


def test_checkpoint_roundtrip(tmp_path):
    cfg, state = _smoke_state()
    p = save_checkpoint(tmp_path, 7, state)
    assert p.name == "step_0000000007"
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, step = restore_checkpoint(tmp_path, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_survives_corruption(tmp_path):
    cfg, state = _smoke_state()
    save_checkpoint(tmp_path, 1, state)
    p2 = save_checkpoint(tmp_path, 2, state)
    # corrupt the newest checkpoint's first tensor
    victim = next(p2.glob("t*.bin"))
    victim.write_bytes(b"garbage")
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, step = restore_checkpoint(tmp_path, like)
    assert step == 1  # fell back to the older valid checkpoint


def test_checkpoint_manager_retention(tmp_path):
    cfg, state = _smoke_state()
    mgr = CheckpointManager(tmp_path, save_every=1, keep=2)
    for s in range(1, 5):
        mgr.maybe_save(s, {"x": jnp.ones((2,)) * s})
    ckpts = sorted(tmp_path.glob("step_*"))
    assert len(ckpts) == 2
    assert ckpts[-1].name == "step_0000000004"


def test_grad_compression_error_bound():
    rng = np.random.default_rng(1)
    tree = {"a": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(128,)).astype(np.float32))}
    for bits in (8, 16):
        codes, steps = quantize_tree(tree, rel_bound=1e-3, bits=bits)
        back = dequantize_tree(codes, steps, tree)
        for k in tree:
            amax = float(jnp.max(jnp.abs(tree[k])))
            err = float(jnp.max(jnp.abs(tree[k] - back[k])))
            # bound: half a quantization step (step >= amax*2e-3)
            qmax = 2 ** (bits - 1) - 1
            bound = max(amax * 1e-3, amax / qmax) * 1.01
            assert err <= bound, (k, bits, err, bound)


def test_watchdog_policy():
    wd = StepWatchdog(deadline_factor=2.0, patience=2)
    assert wd.observe(1.0) == "ok"
    assert wd.observe(1.0) == "ok"
    assert wd.observe(5.0) == "slow"
    assert wd.observe(5.0) == "rebalance"
    assert wd.observe(1.0) == "ok"   # recovers


def test_resume_continues_step_count(tmp_path):
    cfg, state = _smoke_state()
    tcfg = TrainStepConfig(remat=False)
    opt = AdamWConfig(lr_peak=1e-3, warmup_steps=1, decay_steps=10)
    step = jax.jit(make_train_step(cfg, tcfg, opt))
    pipe = TokenPipeline(vocab_size=cfg.vocab, batch=2, seq_len=16)
    batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(0).items()}
    state, _ = step(state, batch)
    save_checkpoint(tmp_path, 1, state)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, s = restore_checkpoint(tmp_path, like)
    assert int(restored.opt.step) == 1 and s == 1
    restored, _ = step(restored, batch)
    assert int(restored.opt.step) == 2
