"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in repro.kernels.ref."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (field_topology, mss_labels, self_code, steepest_dirs)
from repro.kernels import ref as kref
from repro.kernels.extrema import extrema_masks_pallas
from repro.kernels.fixpass import fix_pass_pallas
from repro.kernels.lorenzo import lorenzo_quant_pallas

SHAPES_3D = [(4, 5, 6), (6, 8, 8), (3, 16, 16), (8, 4, 12)]
SHAPES_2D = [(5, 7), (9, 11), (4, 16)]
SHAPES = SHAPES_3D + SHAPES_2D


def _setup(shape, seed=0, xi=0.3, dtype=np.float32):
    rng = np.random.default_rng(seed)
    f = rng.normal(size=shape).astype(dtype)
    g = (f + rng.uniform(-xi, xi, size=shape)).astype(dtype)
    Mf, mf = mss_labels(jnp.asarray(f))
    upf, dnf = steepest_dirs(jnp.asarray(f))
    sc = self_code(len(shape))
    return (jnp.asarray(f), jnp.asarray(g), Mf, mf,
            (upf == sc), (dnf == sc), upf, dnf)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("seed", [0, 7])
def test_extrema_kernel_matches_ref(shape, seed):
    f, g, Mf, mf, maxf, minf, _, dnf = _setup(shape, seed)
    got = extrema_masks_pallas(g, Mf, mf, maxf.astype(jnp.int32),
                               minf.astype(jnp.int32), interpret=True)
    want = kref.extrema_masks_ref(g, Mf, mf, maxf, minf)
    for a, b, name in zip(got, want,
                          ["up_c", "dn_c", "self", "demote", "promote"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"mismatch in {name}")


@pytest.mark.parametrize("shape", [SHAPES_3D[0], SHAPES_2D[0]])
def test_extrema_kernel_dtype_sweep(shape):
    # f32 and f64 fields must classify identically for integer outputs
    for dtype in (np.float32, np.float64):
        f, g, Mf, mf, maxf, minf, _, dnf = _setup(shape, 3, dtype=dtype)
        got = extrema_masks_pallas(g, Mf, mf, maxf.astype(jnp.int32),
                                   minf.astype(jnp.int32), interpret=True)
        want = kref.extrema_masks_ref(g, Mf, mf, maxf, minf)
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))


@pytest.mark.parametrize("shape", SHAPES_3D + SHAPES_2D[:1])
@pytest.mark.parametrize("seed", [1, 11])
def test_fixpass_kernel_matches_ref(shape, seed):
    f, g, Mf, mf, maxf, minf, upf, dnf = _setup(shape, seed)
    xi = 0.3
    lower = f - xi
    up_c, dn_c, selfe, dem, pro = kref.extrema_masks_ref(g, Mf, mf, maxf, minf)
    g2k, violk, tgtk = fix_pass_pallas(g, lower, selfe, dem, pro, up_c, dnf,
                                       interpret=True)
    g2r, violr = kref.fix_pass_ref(g, lower, selfe, dem, pro, up_c, dnf)
    np.testing.assert_array_equal(np.asarray(g2k), np.asarray(g2r))
    assert int(jnp.sum(violk)) == int(violr)
    # per-slab target counts (the dirty-slab bitmap input): one count per
    # slab, consistent with where the pass actually edited g
    assert tgtk.shape == (g.shape[0],)
    edited = np.any(np.asarray(g2k) != np.asarray(g),
                    axis=tuple(range(1, g.ndim)))
    assert np.all((np.asarray(tgtk) > 0) >= edited)


@pytest.mark.parametrize("shape", SHAPES_3D)
@pytest.mark.parametrize("step", [0.01, 0.2])
def test_lorenzo_kernel_matches_ref(shape, step):
    rng = np.random.default_rng(5)
    f = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    got = lorenzo_quant_pallas(f, step, interpret=True)
    want = kref.lorenzo_quant_ref(f, step)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tiled_kernel_placement_matches_full():
    """Global-coordinate tiling: running the extrema kernel on an interior
    z-tile (slab_lo/n_slabs_total) must reproduce the full-field outputs on
    every slab whose 1-slab halo lies inside the tile."""
    shape = (9, 5, 6)
    f, g, Mf, mf, maxf, minf, _, dnf = _setup(shape, 4)
    full = extrema_masks_pallas(g, Mf, mf, maxf.astype(jnp.int32),
                                minf.astype(jnp.int32), interpret=True)
    a, b = 2, 8                     # tile [2, 8); interior slabs [3, 7)
    tile = extrema_masks_pallas(
        g[a:b], Mf[a:b], mf[a:b],
        maxf[a:b].astype(jnp.int32), minf[a:b].astype(jnp.int32),
        interpret=True, slab_lo=a, n_slabs_total=shape[0])
    for got, want, name in zip(tile, full,
                               ["up_c", "dn_c", "self", "demote", "promote"]):
        np.testing.assert_array_equal(
            np.asarray(got)[1:-1], np.asarray(want)[a + 1:b - 1],
            err_msg=f"tiled mismatch in {name}")


def test_kernel_fix_loop_end_to_end():
    """Drive the fused fix loop entirely through the Pallas kernels and
    check it reaches the same fixpoint as the reference-backend driver."""
    from repro.core import derive_edits
    shape = (5, 6, 7)
    rng = np.random.default_rng(2)
    f = rng.normal(size=shape).astype(np.float32)
    xi = 0.25
    fh = (f + rng.uniform(-xi, xi, size=shape) * 0.99).astype(np.float32)
    Mf, mf = mss_labels(jnp.asarray(f))
    upf, dnf = steepest_dirs(jnp.asarray(f))
    sc = self_code(len(shape))
    maxf, minf = (upf == sc).astype(jnp.int32), (dnf == sc).astype(jnp.int32)
    lower = jnp.asarray(f) - xi

    g = jnp.asarray(fh)
    for _ in range(200):
        up_c, dn_c, selfe, dem, pro = extrema_masks_pallas(
            g, Mf, mf, maxf, minf, interpret=True)
        g2, viol, _ = fix_pass_pallas(g, lower, selfe, dem, pro, up_c, dnf,
                                      interpret=True)
        if int(jnp.sum(viol)) == 0:
            break
        g = g2
    res = derive_edits(f, fh, xi, mode="fused", backend="reference")
    np.testing.assert_allclose(np.asarray(g), res.g, rtol=0, atol=0)
