"""Tests for the error-bounded base compressors, the edit codec, and the
end-to-end MSS-preserving pipeline."""
import time

import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.compress import (sz_roundtrip, zfp_roundtrip, encode_edits,
                            decode_edits, compress_preserving_mss,
                            decompress_artifact, overall_compression_ratio,
                            overall_bit_rate, psnr)
from repro.compress import szlike
from repro.compress.codec import _varint_decode, _varint_encode
from repro.compress.szlike import (check_int32_range, effective_step,
                                   sz_compress, sz_decompress, sz_inverse,
                                   sz_transform)
from repro.core import verify_preservation
from repro.data import synthetic_field
import jax
import jax.numpy as jnp


@pytest.mark.parametrize("xi", [1e-1, 1e-2, 1e-3])
@pytest.mark.parametrize("shape", [(33, 47), (17, 19, 23)])
def test_sz_error_bound(xi, shape):
    rng = np.random.default_rng(0)
    f = rng.normal(size=shape).astype(np.float32)
    fh, nbytes = sz_roundtrip(f, xi)
    assert fh.shape == f.shape and fh.dtype == f.dtype
    assert np.max(np.abs(f - fh)) <= xi * (1 + 1e-9)
    assert nbytes < f.nbytes  # should actually compress gaussian noise @1e-1
    # determinism
    fh2, _ = sz_roundtrip(f, xi)
    np.testing.assert_array_equal(fh, fh2)


def test_sz_jax_path_matches_host():
    """The jit'd TPU-target transform must agree with the exact host codec
    within its documented int32 range."""
    rng = np.random.default_rng(1)
    f = rng.normal(size=(16, 24)).astype(np.float32)
    xi = 1e-2
    step = 2.0 * xi
    r = np.asarray(sz_transform(jnp.asarray(f), jnp.float32(step)))
    back = np.asarray(sz_inverse(jnp.asarray(r), jnp.float32(step)))
    assert np.max(np.abs(f - back)) <= xi * (1 + 1e-5)


# ---------------------------------------------------------------------------
# device/host codec parity: the arithmetic contract of DESIGN.md §4 —
# sz_inverse(sz_transform(f)) must be BITWISE equal to the f_hat that
# sz_decompress(sz_compress(f)) reconstructs
# ---------------------------------------------------------------------------

def _tie_field(shape, step):
    """Plateaus and values on exact quantization midpoints (k + 1/2)*step —
    the round-half-even edge both paths must take identically."""
    f = np.zeros(shape, np.float32)
    f.reshape(-1)[::3] = np.float32(2.5 * step)
    f.reshape(-1)[1::5] = np.float32(-0.5 * step)
    f[tuple(s // 2 for s in shape)] = np.float32(7 * step)
    return f


def _parity_case(f, xi):
    fh_host = sz_decompress(sz_compress(f, xi))
    step = effective_step(f, xi)
    sj = jnp.asarray(np.asarray(step, f.dtype))
    r = sz_transform(jnp.asarray(f), sj)
    fh_dev = np.asarray(sz_inverse(r, sj))
    assert fh_dev.dtype == f.dtype
    np.testing.assert_array_equal(fh_host, fh_dev)
    # and the device residual codes re-encode to the identical blob
    blob = szlike.sz_encode_residuals(np.asarray(r), f.shape, f.dtype, step)
    assert blob == sz_compress(f, xi)


@pytest.mark.parametrize("xi", [1e-1, 1e-3])
@pytest.mark.parametrize("shape", [(33, 47), (17, 19, 23)])
def test_device_host_codec_parity_f32(shape, xi):
    rng = np.random.default_rng(7)
    _parity_case(rng.normal(size=shape).astype(np.float32), xi)


@pytest.mark.parametrize("shape", [(21, 27), (9, 11, 13)])
def test_device_host_codec_parity_ties_plateaus(shape):
    xi = 0.05
    _parity_case(_tie_field(shape, 2 * xi), xi)
    _parity_case(np.zeros(shape, np.float32), xi)          # all-plateau


@pytest.mark.parametrize("shape", [(21, 27), (9, 11, 13)])
def test_device_host_codec_parity_f64(shape):
    """f64 parity needs f64 device arithmetic — run the jit path under
    x64 mode (the device pipeline only auto-selects f64 when x64 is on)."""
    from jax.experimental import enable_x64
    rng = np.random.default_rng(8)
    f = rng.normal(size=shape)
    assert f.dtype == np.float64
    with enable_x64():
        _parity_case(f, 1e-3)


def test_int32_range_precondition_checked():
    """The szlike docstring promises a runtime check of the int32 range
    precondition — both directly and through the device pipeline."""
    f = np.array([[1e9, -1e9], [5e8, 0.0]], np.float32)
    with pytest.raises(ValueError, match="device path precondition"):
        check_int32_range(f, 1e-3)
    with pytest.raises(ValueError, match="device path precondition"):
        sz_transform(f, np.float32(2e-3))
    with pytest.raises(ValueError, match="device_path=True"):
        compress_preserving_mss(f, 1e-3, device_path=True)
    # f64 fields get the looser int32 limit: 2^21 < ratio < 2^28 passes
    f64 = f.astype(np.float64)
    check_int32_range(f64, 100.0)               # ratio 1e7: ok for f64
    with pytest.raises(ValueError, match="int32 cumsum"):
        check_int32_range(f64, 1e-3)            # ratio 1e12: overflows
    # auto mode classifies the f32 field as host-path-only
    from repro.compress.pipeline import _device_path_reason
    reason, step = _device_path_reason(f, 1e-3, "szlike", "fused")
    assert reason is not None and "precondition" in reason and step is None
    with pytest.raises(ValueError, match="positive"):
        check_int32_range(f, 0.0)


@pytest.mark.parametrize("xi", [1e-1, 1e-2, 1e-3])
@pytest.mark.parametrize("shape", [(32, 48), (16, 20, 24), (33, 47)])
def test_zfp_error_bound(xi, shape):
    rng = np.random.default_rng(0)
    f = rng.normal(size=shape).astype(np.float32)
    fh, nbytes = zfp_roundtrip(f, xi)
    assert fh.shape == f.shape
    assert np.max(np.abs(f - fh)) <= xi * (1 + 1e-9)


def test_zfp_constant_field():
    f = np.full((8, 8), 3.25, np.float32)
    fh, _ = zfp_roundtrip(f, 1e-3)
    assert np.max(np.abs(f - fh)) <= 1e-3


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(0, 500))
def test_edit_codec_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    idx = np.sort(rng.choice(10_000, size=n, replace=False)).astype(np.int64)
    val = rng.normal(size=n).astype(np.float32)
    blob = encode_edits(idx, val)
    idx2, val2 = decode_edits(blob)
    np.testing.assert_array_equal(idx, idx2)
    np.testing.assert_array_equal(val, val2)


def test_varint_decode_vectorized_roundtrip_guard():
    """Round-trip microbenchmark guard for the vectorized LEB128 decode:
    the former per-byte Python loop took several seconds on a million-edit
    stream; the numpy scan must stay well under the wall-clock budget
    (generous enough for slow CI, ~10x above the vectorized time)."""
    rng = np.random.default_rng(12)
    deltas = rng.integers(0, 2 ** 40, size=1_000_000, dtype=np.int64)
    deltas[::3] = rng.integers(0, 100, size=deltas[::3].size)  # mixed widths
    buf = _varint_encode(deltas)
    # best-of-3: a single sample flakes under full-suite load (VM
    # scheduler stalls), while the regression this guards against — the
    # per-byte Python loop — is slow on every run
    elapsed = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        got = _varint_decode(buf, deltas.size)
        elapsed = min(elapsed, time.perf_counter() - t0)
    np.testing.assert_array_equal(got, deltas)
    assert elapsed < 3.0, f"varint decode regressed: {elapsed:.2f}s for 1M"
    # boundary widths: 1-byte, 2-byte, and full-uint63 values
    edge = np.array([0, 1, 127, 128, 16383, 16384, 2 ** 62], np.int64)
    np.testing.assert_array_equal(_varint_decode(_varint_encode(edge),
                                                 edge.size), edge)
    with pytest.raises(ValueError, match="truncated varint"):
        _varint_decode(_varint_encode(edge)[:-1], edge.size)


def test_edit_codec_bf16_mode():
    idx = np.array([3, 77, 1024], np.int64)
    val = np.array([-0.5, -0.125, -3.0], np.float32)  # bf16-exact values
    blob = encode_edits(idx, val, "bf16")
    idx2, val2 = decode_edits(blob)
    np.testing.assert_array_equal(idx, idx2)
    np.testing.assert_array_equal(val, val2)


@pytest.mark.parametrize("base", ["szlike", "zfplike"])
def test_pipeline_preserves_mss(base):
    f = synthetic_field("molecular", shape=(20, 20, 12), seed=3)
    xi = 0.02 * float(np.ptp(f))
    art = compress_preserving_mss(f, xi, base=base)
    g = decompress_artifact(art)
    v = verify_preservation(f, g, xi)
    assert v["mss_preserved"], v
    assert v["bound_ok"], v
    ocr = overall_compression_ratio(f, art)
    obr = overall_bit_rate(f, art)
    assert ocr > 1.0          # must beat raw storage
    assert 0 < obr < 32.0
    assert psnr(f, g) > 20.0


def test_pipeline_metrics_fields():
    f = synthetic_field("climate", shape=(48, 96), seed=1)
    xi = 1e-2 * float(np.ptp(f))
    art = compress_preserving_mss(f, xi, base="szlike")
    assert art.t_base >= 0 and art.t_fix >= 0
    assert 0 <= art.edit_ratio < 0.5


# ---------------------------------------------------------------------------
# edit-codec hardening (PR 7 bugfixes)
# ---------------------------------------------------------------------------

def _f32_bits(u):
    return np.array([u], np.uint32).view(np.float32)


def test_bf16_rounds_ties_to_even():
    """The old ``(v32 + 0x8000) >> 16`` rounded every halfway case up —
    a systematic magnitude bias. IEEE round-to-nearest-even must leave
    an even result's trailing bit clear on exact ties."""
    # 1.0 + 2^-8: exactly halfway between bf16 0x3F80 (even) and 0x3F81
    tie_down = _f32_bits(0x3F808000)
    # next representable up from 0x3F81: halfway, odd lsb -> round UP to
    # 0x3F82 (even); plain truncation would give 0x3F81
    tie_up = _f32_bits(0x3F818000)
    # just above a tie must still round up
    above = _f32_bits(0x3F808001)
    idx = np.array([1, 2, 3], np.int64)
    blob = encode_edits(idx, np.concatenate([tie_down, tie_up, above]),
                        value_dtype="bf16")
    _, out = decode_edits(blob)
    got = out.view(np.uint32) >> 16
    assert got.tolist() == [0x3F80, 0x3F82, 0x3F81], \
        [hex(g) for g in got]


def test_bf16_preserves_nan_and_inf():
    """NaN payloads in the low mantissa bits must not decay to Inf (the
    +0x8000 carry used to ripple into the exponent), negative NaNs must
    not wrap to +0 via uint32 overflow, and Inf stays Inf."""
    vals = np.concatenate([
        _f32_bits(0x7F800001),      # +NaN, payload only in dropped bits
        _f32_bits(0xFF800001),      # -NaN (old code: uint32 wrap -> +0)
        _f32_bits(0x7F800000),      # +Inf
        _f32_bits(0xFF800000),      # -Inf
        _f32_bits(0x7FC00000),      # quiet NaN with surviving payload
    ])
    blob = encode_edits(np.arange(5), vals, value_dtype="bf16")
    _, out = decode_edits(blob)
    assert np.isnan(out[0])
    assert np.isnan(out[1]) and np.signbit(out[1])
    assert np.isposinf(out[2])
    assert np.isneginf(out[3])
    assert np.isnan(out[4])


def test_bf16_error_bound_unchanged_for_finite_values():
    rng = np.random.default_rng(3)
    val = rng.normal(size=256).astype(np.float32)
    idx = np.arange(val.size, dtype=np.int64)
    _, out = decode_edits(encode_edits(idx, val, value_dtype="bf16"))
    # RNE halves the worst case vs truncation: <= 2^-9 relative
    rel = np.abs(out - val) / np.maximum(np.abs(val), 1e-30)
    assert np.max(rel) <= 2.0 ** -8


def test_decode_edits_rejects_truncated_and_overlong_blobs():
    idx = np.array([5, 9, 100], np.int64)
    val = np.array([1.0, 2.0, 3.0], np.float32)
    blob = encode_edits(idx, val)
    i2, v2 = decode_edits(blob)                    # the intact blob is fine
    np.testing.assert_array_equal(i2, idx)
    with pytest.raises(ValueError, match="length mismatch"):
        decode_edits(blob[:-1])                    # truncated value stream
    with pytest.raises(ValueError, match="length mismatch"):
        decode_edits(blob[:len(blob) // 2])        # truncated mid-stream
    with pytest.raises(ValueError, match="truncated"):
        decode_edits(blob[:10])                    # shorter than the header
    with pytest.raises(ValueError, match="length mismatch"):
        decode_edits(blob + b"\x00")               # trailing garbage


def test_varint_decode_rejects_trailing_values():
    enc = _varint_encode(np.array([1, 2, 3], np.int64))
    np.testing.assert_array_equal(_varint_decode(enc, 3), [1, 2, 3])
    with pytest.raises(ValueError, match="over-long"):
        _varint_decode(enc, 2)                     # a whole extra value
    with pytest.raises(ValueError, match="truncated"):
        _varint_decode(enc, 4)
    with pytest.raises(ValueError, match="over-long"):
        _varint_decode(enc + b"\x05", 3)           # dangling terminated byte
    with pytest.raises(ValueError, match="over-long"):
        _varint_decode(enc + b"\x80", 3)           # dangling continuation
    with pytest.raises(ValueError, match="0 values"):
        _varint_decode(b"\x07", 0)
