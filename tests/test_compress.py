"""Tests for the error-bounded base compressors, the edit codec, and the
end-to-end MSS-preserving pipeline."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.compress import (sz_roundtrip, zfp_roundtrip, encode_edits,
                            decode_edits, compress_preserving_mss,
                            decompress_artifact, overall_compression_ratio,
                            overall_bit_rate, psnr)
from repro.compress.szlike import sz_transform, sz_inverse
from repro.core import verify_preservation
from repro.data import synthetic_field
import jax.numpy as jnp


@pytest.mark.parametrize("xi", [1e-1, 1e-2, 1e-3])
@pytest.mark.parametrize("shape", [(33, 47), (17, 19, 23)])
def test_sz_error_bound(xi, shape):
    rng = np.random.default_rng(0)
    f = rng.normal(size=shape).astype(np.float32)
    fh, nbytes = sz_roundtrip(f, xi)
    assert fh.shape == f.shape and fh.dtype == f.dtype
    assert np.max(np.abs(f - fh)) <= xi * (1 + 1e-9)
    assert nbytes < f.nbytes  # should actually compress gaussian noise @1e-1
    # determinism
    fh2, _ = sz_roundtrip(f, xi)
    np.testing.assert_array_equal(fh, fh2)


def test_sz_jax_path_matches_host():
    """The jit'd TPU-target transform must agree with the exact host codec
    within its documented int32 range."""
    rng = np.random.default_rng(1)
    f = rng.normal(size=(16, 24)).astype(np.float32)
    xi = 1e-2
    step = 2.0 * xi
    r = np.asarray(sz_transform(jnp.asarray(f), jnp.float32(step)))
    back = np.asarray(sz_inverse(jnp.asarray(r), jnp.float32(step)))
    assert np.max(np.abs(f - back)) <= xi * (1 + 1e-5)


@pytest.mark.parametrize("xi", [1e-1, 1e-2, 1e-3])
@pytest.mark.parametrize("shape", [(32, 48), (16, 20, 24), (33, 47)])
def test_zfp_error_bound(xi, shape):
    rng = np.random.default_rng(0)
    f = rng.normal(size=shape).astype(np.float32)
    fh, nbytes = zfp_roundtrip(f, xi)
    assert fh.shape == f.shape
    assert np.max(np.abs(f - fh)) <= xi * (1 + 1e-9)


def test_zfp_constant_field():
    f = np.full((8, 8), 3.25, np.float32)
    fh, _ = zfp_roundtrip(f, 1e-3)
    assert np.max(np.abs(f - fh)) <= 1e-3


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(0, 500))
def test_edit_codec_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    idx = np.sort(rng.choice(10_000, size=n, replace=False)).astype(np.int64)
    val = rng.normal(size=n).astype(np.float32)
    blob = encode_edits(idx, val)
    idx2, val2 = decode_edits(blob)
    np.testing.assert_array_equal(idx, idx2)
    np.testing.assert_array_equal(val, val2)


def test_edit_codec_bf16_mode():
    idx = np.array([3, 77, 1024], np.int64)
    val = np.array([-0.5, -0.125, -3.0], np.float32)  # bf16-exact values
    blob = encode_edits(idx, val, "bf16")
    idx2, val2 = decode_edits(blob)
    np.testing.assert_array_equal(idx, idx2)
    np.testing.assert_array_equal(val, val2)


@pytest.mark.parametrize("base", ["szlike", "zfplike"])
def test_pipeline_preserves_mss(base):
    f = synthetic_field("molecular", shape=(20, 20, 12), seed=3)
    xi = 0.02 * float(np.ptp(f))
    art = compress_preserving_mss(f, xi, base=base)
    g = decompress_artifact(art)
    v = verify_preservation(f, g, xi)
    assert v["mss_preserved"], v
    assert v["bound_ok"], v
    ocr = overall_compression_ratio(f, art)
    obr = overall_bit_rate(f, art)
    assert ocr > 1.0          # must beat raw storage
    assert 0 < obr < 32.0
    assert psnr(f, g) > 20.0


def test_pipeline_metrics_fields():
    f = synthetic_field("climate", shape=(48, 96), seed=1)
    xi = 1e-2 * float(np.ptp(f))
    art = compress_preserving_mss(f, xi, base="szlike")
    assert art.t_base >= 0 and art.t_fix >= 0
    assert 0 <= art.edit_ratio < 0.5
