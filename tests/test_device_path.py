"""Device-resident compression path (DESIGN.md §4).

The contract under test: ``compress_preserving_mss(..., device_path=True)``
(and "auto" whenever the preconditions hold) produces artifacts BYTE-FOR-
BYTE identical to the host-path artifact's — base payload, edit payload,
and decompressed field — on 2D and 3D fields, for the reference, pallas,
and sharded backends, while moving at most one host->device and one
device->host transfer of field-sized data per call.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.compress import (compress_preserving_mss,
                            compress_preserving_mss_batch,
                            decompress_artifact)
from repro.compress import pipeline
from repro import debug
from repro.core import verify_preservation
from repro.core.backend import get_backend
from repro.data import synthetic_field
from repro.launch.mesh import make_data_mesh

N_AVAIL = len(jax.devices())

SHAPES = [(26, 18), (12, 10, 9)]


def _case(shape, seed=3, rel=0.02):
    f = synthetic_field("molecular", shape=shape, seed=seed)
    return f, rel * float(np.ptp(f))


def _assert_identical(a, b):
    assert a.base_payload == b.base_payload
    assert a.edit_payload == b.edit_payload
    np.testing.assert_array_equal(decompress_artifact(a),
                                  decompress_artifact(b))


# ---------------------------------------------------------------------------
# bitwise parity host <-> device, per backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("shape", SHAPES)
def test_device_path_bitwise_identical(shape, backend):
    f, xi = _case(shape)
    host = compress_preserving_mss(f, xi, device_path=False, backend=backend)
    dev = compress_preserving_mss(f, xi, device_path=True, backend=backend)
    assert host.path == "host" and dev.path == "device"
    assert dev.version == pipeline.ARTIFACT_VERSION
    assert dev.backend == backend
    assert dev.t_transform > 0.0 and host.t_transform == 0.0
    _assert_identical(host, dev)
    g = decompress_artifact(dev)
    v = verify_preservation(f, g, xi)
    assert v["mss_preserved"] and v["bound_ok"], v


@pytest.mark.parametrize("shape", SHAPES)
def test_device_path_sharded_bitwise_identical(shape):
    if N_AVAIL < 2:
        pytest.skip("needs >= 2 devices (run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    mesh = make_data_mesh(min(N_AVAIL, 4))
    f, xi = _case(shape)
    host = compress_preserving_mss(f, xi, device_path=False)
    dev = compress_preserving_mss(f, xi, device_path=True, backend="sharded",
                                  mesh=mesh)
    assert dev.path == "device" and dev.backend == "sharded"
    _assert_identical(host, dev)


def test_auto_picks_device_path_and_matches():
    f, xi = _case((12, 10, 9))
    auto = compress_preserving_mss(f, xi)               # defaults
    host = compress_preserving_mss(f, xi, device_path=False)
    assert auto.path == "device"
    _assert_identical(auto, host)


def test_auto_falls_back_to_host():
    # zfplike's block transform has no device implementation
    f, xi = _case((26, 18))
    art = compress_preserving_mss(f, xi, base="zfplike")
    assert art.path == "host"
    # f64 needs x64 mode for device arithmetic -> host path off-x64
    f64, xi64 = _case((26, 18))
    f64 = f64.astype(np.float64)
    art64 = compress_preserving_mss(f64, xi64)
    assert art64.path == "host"
    v = verify_preservation(f64, decompress_artifact(art64), xi64)
    assert v["mss_preserved"] and v["bound_ok"]
    # paper mode always runs host-side
    art_p = compress_preserving_mss(f, xi, mode="paper")
    assert art_p.path == "host"
    with pytest.raises(ValueError, match="device_path=True"):
        compress_preserving_mss(f, xi, base="zfplike", device_path=True)


# ---------------------------------------------------------------------------
# transfer counting: the device path moves field-sized data across the
# host/device boundary exactly once in each direction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
def test_device_path_transfer_count(shape, monkeypatch):
    f, xi = _case(shape)
    log = []
    monkeypatch.setattr(pipeline, "_transfer_hook",
                        lambda d, n: log.append((d, n)))
    compress_preserving_mss(f, xi, device_path=True)   # warm-up: compiles
    log.clear()
    # the jax transfer guard bans IMPLICIT syncs outright; the hook then
    # counts the surviving EXPLICIT seam crossings — together they state
    # the full contract: exactly one field-sized crossing each way, and
    # nothing else crosses at all
    with debug.no_transfers():
        compress_preserving_mss(f, xi, device_path=True)
    field_sized = [(d, n) for d, n in log if n >= f.nbytes]
    assert sum(1 for d, _ in field_sized if d == "h2d") == 1, log
    assert sum(1 for d, _ in field_sized if d == "d2h") == 1, log


def test_device_path_batch_transfer_count(monkeypatch):
    B = 3
    fields = [synthetic_field("molecular", shape=(10, 12, 8), seed=s)
              for s in range(B)]
    xis = [0.02 * float(np.ptp(fi)) for fi in fields]
    log = []
    monkeypatch.setattr(pipeline, "_transfer_hook",
                        lambda d, n: log.append((d, n)))
    compress_preserving_mss_batch(fields, xis)         # warm-up: compiles
    log.clear()
    with debug.no_transfers():
        compress_preserving_mss_batch(fields, xis)
    batch_bytes = B * fields[0].nbytes
    field_sized = [(d, n) for d, n in log if n >= batch_bytes]
    assert sum(1 for d, _ in field_sized if d == "h2d") == 1, log
    assert sum(1 for d, _ in field_sized if d == "d2h") == 1, log


# ---------------------------------------------------------------------------
# batched device path
# ---------------------------------------------------------------------------

def test_batch_device_path_matches_solo():
    B = 4
    fields = [synthetic_field("molecular", shape=(10, 12, 8), seed=s)
              for s in range(B)]
    xis = [0.02 * float(np.ptp(fi)) for fi in fields]
    arts = compress_preserving_mss_batch(fields, xis)
    assert len(arts) == B
    for fi, xi_i, art in zip(fields, xis, arts):
        assert art.path == "device"
        solo = compress_preserving_mss(fi, xi_i, device_path=True)
        assert art.base_payload == solo.base_payload
        assert art.edit_payload == solo.edit_payload
        v = verify_preservation(fi, decompress_artifact(art), xi_i)
        assert v["mss_preserved"] and v["bound_ok"], v


def test_batch_device_path_sharded_matches_solo():
    if N_AVAIL < 2:
        pytest.skip("needs >= 2 devices (run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    mesh = make_data_mesh(2)
    fields = [synthetic_field("molecular", shape=(10, 12, 8), seed=s)
              for s in range(2)]
    xis = [0.02 * float(np.ptp(fi)) for fi in fields]
    arts = compress_preserving_mss_batch(fields, xis, mesh=mesh)
    for fi, xi_i, art in zip(fields, xis, arts):
        assert art.path == "device" and art.backend == "sharded"
        solo = compress_preserving_mss(fi, xi_i, device_path=True)
        assert art.base_payload == solo.base_payload
        assert art.edit_payload == solo.edit_payload


def test_batch_device_path_2d():
    B = 3
    fields = [synthetic_field("climate", shape=(20, 26), seed=s)
              for s in range(B)]
    xi = 0.01 * float(np.ptp(fields[0]))
    arts = compress_preserving_mss_batch(fields, xi)
    host = compress_preserving_mss_batch(fields, xi, device_path=False)
    for a, h in zip(arts, host):
        assert a.path == "device" and h.path == "host"
        _assert_identical(a, h)


# ---------------------------------------------------------------------------
# the backend transform/reconstruct protocol itself
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(9, 11), (6, 7, 8)])
def test_backend_transform_parity(shape):
    rng = np.random.default_rng(5)
    f = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    step = np.float32(0.04)
    ref = get_backend("reference")
    pal = get_backend("pallas")
    r_ref = np.asarray(ref.transform(f, step))
    r_pal = np.asarray(pal.transform(f, step))
    np.testing.assert_array_equal(r_ref, r_pal)
    fh_ref = np.asarray(ref.reconstruct(jnp.asarray(r_ref), step, f.dtype))
    fh_pal = np.asarray(pal.reconstruct(jnp.asarray(r_ref), step, f.dtype))
    np.testing.assert_array_equal(fh_ref, fh_pal)
    if N_AVAIL >= 2:
        sb = get_backend("sharded").with_mesh(make_data_mesh(min(N_AVAIL, 4)))
        np.testing.assert_array_equal(r_ref, np.asarray(sb.transform(f, step)))
        np.testing.assert_array_equal(
            fh_ref, np.asarray(sb.reconstruct(jnp.asarray(r_ref), step,
                                              f.dtype)))


def test_edit_extraction_on_device_matches_host():
    from repro.core.driver import extract_edits
    rng = np.random.default_rng(9)
    f_hat = rng.normal(size=(7, 8, 9)).astype(np.float32)
    g = f_hat.copy()
    picks = rng.choice(f_hat.size, size=40, replace=False)
    # mszlint: disable=scatter-discipline -- replace=False makes picks unique
    g.reshape(-1)[picks] -= 0.125
    idx, val = extract_edits(jnp.asarray(f_hat), jnp.asarray(g))
    delta = g - f_hat
    want_idx = np.flatnonzero(delta != 0)
    np.testing.assert_array_equal(np.asarray(idx), want_idx)
    np.testing.assert_array_equal(np.asarray(val),
                                  delta.reshape(-1)[want_idx])
    # no edits
    idx0, val0 = extract_edits(jnp.asarray(f_hat), jnp.asarray(f_hat))
    assert idx0.size == 0 and val0.size == 0
