"""Runtime sanitizer guards (DESIGN.md §10, ``repro.debug``).

These are the unit tests for the guards themselves; the device-path and
stream test modules exercise them in anger (``no_transfers`` around the
transfer-count assertions, ``MSZ_SANITIZERS=1`` around the scheduler's
device stage).
"""
import contextlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import debug
from repro.debug import guards


# ---------------------------------------------------------------------------
# no_transfers
# ---------------------------------------------------------------------------

def test_no_transfers_catches_implicit_h2d():
    f = jax.jit(lambda x: x + 1)
    x = np.ones(8, np.float32)
    f(x)                                    # warm-up: compile outside guard
    with pytest.raises(Exception, match="Disallowed"):
        with debug.no_transfers():
            f(x)                            # numpy arg -> implicit h2d


def test_no_transfers_permits_explicit_and_resident():
    f = jax.jit(lambda x: x + 1)
    x = np.ones(8, np.float32)
    f(x)                                    # warm-up
    with debug.no_transfers():
        xd = jax.device_put(x)              # explicit: the audited seam
        y = f(xd)                           # resident arg: no crossing
    np.testing.assert_array_equal(jax.device_get(y), x + 1)


def test_no_transfers_direction_narrowing():
    f = jax.jit(lambda x: x + 1)
    x = np.ones(8, np.float32)
    f(x)
    with debug.no_transfers(h2d=False):     # d2h-only guard: h2d is fine
        f(x)


# ---------------------------------------------------------------------------
# no_recompiles
# ---------------------------------------------------------------------------

def test_no_recompiles_passes_on_stable_cache_key():
    f = jax.jit(lambda x: x * 2)
    x = jnp.arange(8, dtype=jnp.float32)
    f(x)                                    # warm-up compile
    with debug.no_recompiles():
        for _ in range(3):
            f(x)


def test_no_recompiles_raises_on_churn():
    x = jnp.arange(8, dtype=jnp.float32)
    with pytest.raises(debug.RecompileError, match="churn-fixture"):
        with debug.no_recompiles(label="churn-fixture"):
            # a fresh jit wrapper per call never hits the cache — the
            # PR 7 calibration cache-key bug class in miniature
            for k in range(2):
                jax.jit(lambda v, k=k: v + k)(x)


def test_no_recompiles_budget_allows_expected_compiles():
    x = jnp.arange(8, dtype=jnp.float32)
    with debug.no_recompiles(max_compiles=1) as messages:
        jax.jit(lambda v: v - 3)(x)
    assert any(m.startswith("Compiling ") for m in messages)


def test_no_recompiles_propagates_block_exception():
    with pytest.raises(KeyError):
        with debug.no_recompiles():
            raise KeyError("inner errors win over budget accounting")


# ---------------------------------------------------------------------------
# the MSZ_SANITIZERS knob
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("value,expect", [
    ("", False), ("0", False), ("no", False), ("OFF", False),
    ("1", True), ("true", True), ("YES", True), ("on", True),
])
def test_sanitizers_enabled_parsing(monkeypatch, value, expect):
    monkeypatch.setenv(guards.ENV_VAR, value)
    assert debug.sanitizers_enabled() is expect


def test_sanitizers_enabled_rejects_garbage(monkeypatch):
    monkeypatch.setenv(guards.ENV_VAR, "maybe")
    with pytest.raises(ValueError, match="MSZ_SANITIZERS"):
        debug.sanitizers_enabled()


def test_sanitize_transfers_is_noop_when_off(monkeypatch):
    monkeypatch.delenv(guards.ENV_VAR, raising=False)
    ctx = debug.sanitize_transfers()
    assert isinstance(ctx, contextlib.nullcontext)
    f = jax.jit(lambda x: x + 1)
    x = np.ones(4, np.float32)
    f(x)
    with ctx:
        f(x)                                # implicit h2d allowed: no-op


def test_sanitize_transfers_arms_guard_when_on(monkeypatch):
    monkeypatch.setenv(guards.ENV_VAR, "1")
    f = jax.jit(lambda x: x + 1)
    x = np.ones(4, np.float32)
    f(x)
    with pytest.raises(Exception, match="Disallowed"):
        with debug.sanitize_transfers():
            f(x)
