"""mszlint fixture tests: every rule fires on a minimal reproduction of
its historical bug class, honors inline suppression, and stays quiet on
the sanctioned idiom. Fixtures go through ``lint_source`` with a narrow
per-rule Config — no filesystem, no shared state."""
import textwrap

import pytest

from tools.mszlint import Config, lint_source
from tools.mszlint.config import DEFAULT
from tools.mszlint.rules import (int32, interpret, locks, scatter,
                                 sentinel, transfer)


def cfg(rule, **kw):
    return Config(rule_paths={rule: ("*",)}, **kw)


def run(rule_mod, text, config=None):
    config = config or cfg(rule_mod.RULE)
    return lint_source("fixture.py", textwrap.dedent(text), config,
                       rules=[rule_mod])


# -- transfer-discipline ---------------------------------------------------

TRANSFER_CFG = cfg(transfer.RULE,
                   transfer_check_functions={"*": ("stage",)})


def test_transfer_flags_implicit_conversions():
    out = run(transfer, """
        def stage(x):
            a = np.asarray(x)        # implicit d2h
            b = float(x)             # implicit d2h
            c = x.item()             # implicit d2h
            return a, b, c
        """, TRANSFER_CFG)
    assert [f.rule for f in out] == [transfer.RULE] * 3
    assert [f.line for f in out] == [3, 4, 5]


def test_transfer_allows_explicit_seams_and_host_values():
    out = run(transfer, """
        def stage(x, n_words):
            w = _d2h(x)                  # the audited seam
            y = jax.device_put(np.asarray([1, 2]))   # explicit h2d
            nw = int(_d2h(n_words))      # int() OF the seam's result
            k = float(x.shape[0])        # host-by-construction
            return w, y, nw, k
        """, TRANSFER_CFG)
    assert out == []


def test_transfer_skips_jitted_and_unaudited_functions():
    out = run(transfer, """
        @functools.partial(jax.jit, static_argnames=("n",))
        def stage(x, n):
            return jnp.asarray(x[:n])    # trace-time: fine

        def helper(x):
            return float(x)              # not an audited function
        """, TRANSFER_CFG)
    assert out == []


def test_transfer_suppression():
    out = run(transfer, """
        def stage(xi_arr, i):
            # mszlint: disable=transfer-discipline -- xi_arr is host numpy
            return float(xi_arr[i])
        """, TRANSFER_CFG)
    assert out == []


# -- sentinel-dtype --------------------------------------------------------

def test_sentinel_flags_untyped_inf():
    out = run(sentinel, """
        def kernel(s, q_pos, k_pos):
            return jnp.where(q_pos >= k_pos, s, -jnp.inf)
        """)
    assert [f.rule for f in out] == [sentinel.RULE]


def test_sentinel_accepts_typed_casts():
    out = run(sentinel, """
        def kernel(s, m):
            a = jnp.asarray(-jnp.inf, s.dtype)
            b = jnp.full_like(m, -jnp.inf)
            c = jnp.full((4,), jnp.inf, jnp.float32)
            d = jnp.float32(jnp.inf)
            return a, b, c, d
        """)
    assert out == []


def test_sentinel_flags_untyped_asarray():
    # asarray WITHOUT a dtype does not type the sentinel
    out = run(sentinel, "x = jnp.asarray(-jnp.inf)\n")
    assert len(out) == 1


def test_sentinel_suppression():
    out = run(sentinel, """
        # mszlint: disable=sentinel-dtype -- f64 accumulator wants raw inf
        x = jnp.where(m, s, -jnp.inf)
        """)
    assert out == []


# -- scatter-discipline ----------------------------------------------------

def test_scatter_flags_fancy_index_augassign():
    out = run(scatter, """
        flat[idx] += val
        acc[sel] -= deltas
        """)
    assert [f.rule for f in out] == [scatter.RULE] * 2


def test_scatter_accepts_scalar_indices_and_add_at():
    out = run(scatter, """
        a[0] += 1
        b[i + 1] += x        # arithmetic over scalars
        np.add.at(flat, idx, val)
        g = g.at[idx].add(val)
        """)
    # b[i+1]: i is a Name inside BinOp -> flagged? BinOp of Name is not
    # scalarish, so it IS flagged -- loop arithmetic needs suppression.
    # Constant-only arithmetic stays quiet:
    out2 = run(scatter, "a[2 * 3 + 1] += 1\n")
    assert out2 == []
    assert all(f.line != 1 for f in out)       # a[0] clean
    assert all("add.at" not in (f.message or "") or True for f in out)


def test_scatter_suppression():
    out = run(scatter, """
        # mszlint: disable=scatter-discipline -- idx unique by construction
        flat[idx] += val
        """)
    assert out == []


# -- lock-guard ------------------------------------------------------------

LOCK_FIXTURE = """
    class Sched:
        def __init__(self):
            self._lock = threading.Lock()
            self._batches = 0        # guarded-by: self._lock

        def good(self):
            with self._lock:
                self._batches += 1

        def bad(self):
            self._batches += 1

        def helper_locked(self):     # guarded-by: self._lock
            self._batches += 1
    """


def test_lock_guard_flags_unlocked_write_only():
    out = run(locks, LOCK_FIXTURE)
    assert [f.rule for f in out] == [locks.RULE]
    assert "bad" not in ""  # finding is the write inside bad()
    assert out[0].line == 12


def test_lock_guard_module_globals():
    out = run(locks, """
        _cache = {}          # guarded-by: _lock
        _lock = threading.Lock()

        def good(k, v):
            with _lock:
                _cache = {k: v}

        def bad(k, v):
            global _cache
            _cache = {k: v}
        """)
    assert len(out) == 1 and out[0].line == 11


def test_lock_guard_suppression():
    out = run(locks, """
        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0           # guarded-by: self._lock

            def f(self):
                # mszlint: disable=lock-guard -- single-threaded test hook
                self.n += 1
        """)
    assert out == []


# -- int32-range -----------------------------------------------------------

def test_int32_flags_unguarded_cumsum():
    out = run(int32, """
        def decode(r):
            for ax in range(r.ndim):
                r = int32_cumsum(r, ax)
            return r
        """)
    assert [f.rule for f in out] == [int32.RULE]


def test_int32_accepts_guarded_and_impl_functions():
    out = run(int32, """
        def decode(r, f, step):
            check_int32_range(f, step)
            return int32_cumsum(r, 0)

        def int32_cumsum(x, ax):
            return jnp.cumsum(x, ax, dtype=jnp.int32)
        """)
    assert out == []


def test_int32_suppression():
    out = run(int32, """
        def offsets(words):
            # mszlint: disable=int32-range -- word counts bounded by stream
            return int32_cumsum(words, 0)
        """)
    assert out == []


# -- interpret-policy ------------------------------------------------------

def test_interpret_flags_literals():
    out = run(interpret, """
        def f(x, interpret: bool = True):
            return pl.pallas_call(kern, interpret=False)(x)
        """)
    assert [f.rule for f in out] == [interpret.RULE] * 2


def test_interpret_accepts_policy_routing():
    out = run(interpret, """
        def f(x, interpret=None):
            if interpret is None:
                interpret = default_interpret()
            return pl.pallas_call(kern, interpret=interpret)(x)

        def default_interpret():
            return True if os.environ.get("X") else False
        """)
    assert out == []


def test_interpret_suppression():
    out = run(interpret, """
        # mszlint: disable=interpret-policy -- asserting lowered parity
        y = kernel(x, interpret=False)
        """)
    assert out == []


# -- engine-level behavior -------------------------------------------------

def test_parse_error_is_reported_not_raised():
    out = lint_source("fixture.py", "def broken(:\n", cfg(scatter.RULE),
                      rules=[scatter])
    assert [f.rule for f in out] == ["parse-error"]


def test_file_wide_suppression():
    out = run(scatter, """
        # mszlint: disable-file=scatter-discipline
        flat[idx] += val
        acc[sel] -= d
        """)
    assert out == []


def test_rule_paths_scope_rules():
    narrow = Config(rule_paths={scatter.RULE: ("src/*.py",)})
    text = "flat[idx] += val\n"
    assert lint_source("src/a.py", text, narrow, rules=[scatter])
    assert not lint_source("docs/a.py", text, narrow, rules=[scatter])


def test_default_config_covers_all_rules():
    from tools.mszlint.rules import ALL_RULES
    for mod in ALL_RULES:
        assert DEFAULT.rule_paths.get(mod.RULE), mod.RULE


def test_repo_is_lint_clean():
    """The PR-head invariant CI enforces: the repo's own sources pass."""
    from tools.mszlint.engine import lint_paths
    findings = lint_paths(["src", "tools"], DEFAULT)
    assert findings == [], "\n".join(f.render() for f in findings)


# -- the preserve layer's lint contract (DESIGN.md §11) --------------------

def test_preserve_module_is_audited_and_clean():
    """compress/preserve.py sits on the transfer-discipline and
    int32-range surfaces of the DEFAULT config, and passes them with
    ZERO suppressions — the codec-agnostic layer must not buy its
    cleanliness with disable comments."""
    from pathlib import Path
    path = Path("src/repro/compress/preserve.py")
    src = path.read_text()
    assert lint_source(str(path), src, DEFAULT) == []
    assert "mszlint: disable" not in src
    # the config genuinely audits the device-facing encoder
    assert "encode_edits_checked_dev" in \
        DEFAULT.transfer_check_functions["*/compress/preserve.py"]


def test_preserve_device_encoder_violations_would_be_caught():
    """The audit has teeth: an implicit d2h inside a function named like
    the preserve layer's device encoder IS flagged under DEFAULT."""
    out = lint_source(
        "src/repro/compress/preserve.py", textwrap.dedent("""
            def encode_edits_checked_dev(fj, f_hat, idx, val, xi, evd):
                err = float(f_hat.max())     # implicit d2h
                return np.asarray(fj)        # implicit d2h
            """), DEFAULT, rules=[transfer])
    assert [f.rule for f in out] == [transfer.RULE] * 2
