"""Property tests for the preservation oracle layer (DESIGN.md §11).

Randomized (hypothesis, skipped cleanly when not installed) and
deterministic edge-case coverage of the codec-agnostic contract, for
BOTH registered codecs:

* the fix loop converges within its finite iteration bound and
  ``verify_preservation`` accepts the pipeline's own output;
* re-deriving edits for an already-corrected field is a strict fixed
  point (zero new edits: g is inside the bound and MSS(g) == MSS(f),
  so no violation exists to fix);
* fully re-compressing a corrected field preserves the LABELS again
  (the byte stream may differ — the quantization grid re-anchors on g —
  but the segmentation is idempotent);
* the numpy oracle (``apply_edits_ref`` / ``verify_preservation_ref``)
  agrees bitwise with the production ``apply_edits`` /
  ``verify_preservation``;
* plateau/tie, constant, and single-voxel fields go through both codecs.
"""
import numpy as np
import pytest

from _hyp_compat import given, settings, st

from repro.compress import (compress_preserving_mss, decode_edits,
                            decompress_artifact)
from repro.core import ref as R
from repro.core.driver import apply_edits, derive_edits, verify_preservation

CODECS = ("szlike", "zfplike")
XI = 0.08


def _random_field(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.normal(size=shape).astype(np.float32)


def _plateau_field(shape, seed, levels=3):
    """Coarsely quantized field: long plateaus and many exact ties, the
    Simulation-of-Simplicity stress regime."""
    f = _random_field(shape, seed)
    return (np.round(f * levels) / levels).astype(np.float32)


def _roundtrip(f, xi, codec_name):
    art = compress_preserving_mss(f, xi, codec=codec_name)
    g = decompress_artifact(art)
    return art, g


# ---------------------------------------------------------------------------
# pipeline output properties (randomized)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec_name", CODECS)
@pytest.mark.parametrize("shape", [(7, 8), (4, 5, 4)], ids=["2d", "3d"])
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_verify_accepts_own_output(codec_name, shape, seed):
    f = _random_field(shape, seed)
    art, g = _roundtrip(f, XI, codec_name)
    assert art.fix_iters <= 512       # converged inside the finite bound
    v = verify_preservation(f, g, XI)
    assert v["mss_preserved"] and v["bound_ok"], v


@pytest.mark.parametrize("codec_name", CODECS)
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_rederivation_is_strict_fixed_point(codec_name, seed):
    """g already satisfies both constraints against f, so a fresh edit
    derivation over (f, g) must find NOTHING to fix."""
    f = _random_field((7, 8), seed)
    _, g = _roundtrip(f, XI, codec_name)
    res = derive_edits(f, g, XI)
    assert res.converged and res.iters <= 1   # one pass, nothing found
    assert res.edits_idx.size == 0
    np.testing.assert_array_equal(res.g, g)


@pytest.mark.parametrize("codec_name", CODECS)
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_recompression_is_label_idempotent(codec_name, seed):
    """Re-compressing a corrected field re-anchors the quantization grid
    on g (bytes may differ) but the segmentation must survive again —
    and still equal the ORIGINAL field's oracle labels transitively."""
    f = _random_field((7, 8), seed)
    _, g = _roundtrip(f, XI, codec_name)
    _, g2 = _roundtrip(g, XI, codec_name)
    v = verify_preservation(g, g2, XI)
    assert v["mss_preserved"] and v["bound_ok"], v
    assert R.labels_equal_ref(f, g2)


# ---------------------------------------------------------------------------
# oracle <-> production agreement
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), n_edits=st.integers(0, 20))
def test_apply_edits_ref_matches_production(seed, n_edits):
    rng = np.random.default_rng(seed)
    f_hat = rng.normal(size=(6, 7)).astype(np.float32)
    idx = rng.choice(f_hat.size, size=min(n_edits, f_hat.size),
                     replace=False).astype(np.int64)
    val = rng.normal(size=idx.size).astype(np.float32) * 0.1
    g_ref = R.apply_edits_ref(f_hat, idx, val)
    g_prod = apply_edits(f_hat, idx, val)
    np.testing.assert_array_equal(g_ref, g_prod)   # bitwise


def test_apply_edits_ref_rejects_corrupt_streams():
    f_hat = np.zeros((4, 4), np.float32)
    with pytest.raises(ValueError, match="duplicate"):
        R.apply_edits_ref(f_hat, [3, 3], [1.0, 2.0])
    with pytest.raises(ValueError, match="out of range"):
        R.apply_edits_ref(f_hat, [16], [1.0])
    with pytest.raises(ValueError, match="length mismatch"):
        R.apply_edits_ref(f_hat, [1, 2], [1.0])


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       broken=st.booleans())
def test_verify_preservation_ref_agrees_with_production(seed, broken):
    rng = np.random.default_rng(seed)
    f = rng.normal(size=(6, 7)).astype(np.float32)
    g = (f + rng.uniform(-XI, XI, size=f.shape) * 0.5).astype(np.float32)
    if broken:
        g[0, 0] += np.float32(5.0)    # blows both bound and labels
    v_ref = R.verify_preservation_ref(f, g, XI)
    v = verify_preservation(f, g, XI)
    for key in ("bound_ok", "max_labels_ok", "min_labels_ok",
                "mss_preserved"):
        assert v_ref[key] == v[key], key
    assert v_ref["right_labeled_ratio"] == pytest.approx(
        v["right_labeled_ratio"])


# ---------------------------------------------------------------------------
# degenerate fields: plateaus/ties, constants, single voxels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec_name", CODECS)
@pytest.mark.parametrize("shape", [(7, 8), (4, 5, 4)], ids=["2d", "3d"])
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_plateau_tie_fields_preserved(codec_name, shape, seed):
    f = _plateau_field(shape, seed)
    _, g = _roundtrip(f, XI, codec_name)
    assert R.labels_equal_ref(f, g)
    assert float(np.max(np.abs(f - g))) <= XI * (1 + 1e-6)


@pytest.mark.parametrize("codec_name", CODECS)
def test_constant_field_roundtrip(codec_name):
    f = np.full((6, 6), 2.25, np.float32)
    art, g = _roundtrip(f, 1e-3, codec_name)
    v = verify_preservation(f, g, 1e-3)
    assert v["mss_preserved"] and v["bound_ok"], v
    # a constant field has no false criticals to fix: zero edits, one
    # empty-handed convergence pass
    idx, _ = decode_edits(art.edit_payload)
    assert idx.size == 0 and art.fix_iters <= 1


@pytest.mark.parametrize("codec_name", CODECS)
@pytest.mark.parametrize("shape", [(1, 1), (1, 1, 1)], ids=["2d", "3d"])
def test_single_voxel_field_roundtrip(codec_name, shape):
    f = np.full(shape, -0.75, np.float32)
    art, g = _roundtrip(f, 1e-3, codec_name)
    assert g.shape == shape and g.dtype == np.float32
    v = verify_preservation(f, g, 1e-3)
    assert v["mss_preserved"] and v["bound_ok"], v
    assert R.labels_equal_ref(f, g)
