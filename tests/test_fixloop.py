"""PR-6 fix-loop execution strategies: active-member compaction,
dirty-slab worklists, the Pallas interpret policy, and the calibrated
stream batching threshold.

The invariant under test everywhere: every strategy — compacted batch,
dirty-slab worklist, sharded worklist, fused legacy — produces fields
AND iteration counts bitwise identical to the solo per-member loop.
"""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (derive_edits, derive_edits_batch, field_topology,
                        fused_fix, fused_fix_batch, fused_fix_worklist,
                        get_backend)
from repro.core.backend import PallasBackend
from repro.compress import calibrate, compress_preserving_mss
from repro.compress.stream import CompressStream
from repro.kernels.extrema import default_interpret


def _mixed_members(shape=(6, 7, 8), xi=0.3):
    """A deliberately mixed-convergence batch: an already-converged
    member (fh == f, 1 iteration), a constant field, a light and a heavy
    perturbation, and an empty-ish near-zero field."""
    rng = np.random.default_rng(11)
    smooth = np.add.outer(np.add.outer(np.linspace(0, 1, shape[0]),
                                       np.linspace(0, .5, shape[1])),
                          np.linspace(0, .25, shape[2])).astype(np.float32)
    members = [
        smooth,                                              # converged twin
        np.full(shape, 3.25, np.float32),                    # constant field
        rng.normal(size=shape).astype(np.float32),           # light noise
        rng.normal(size=shape).astype(np.float32),           # heavy noise
        np.zeros(shape, np.float32),                         # empty field
    ]
    fs, fhs = [], []
    for i, f in enumerate(members):
        if i in (0, 1):
            fh = f.copy()                # bitwise-exact base: 0-edit member
        else:
            amp = 0.2 if i == 2 else 0.999
            fh = (f + rng.uniform(-xi, xi, shape) * amp).astype(np.float32)
        fs.append(f)
        fhs.append(fh)
    return np.stack(fs), np.stack(fhs), xi


def _solo_results(f_b, fh_b, xi):
    out = []
    for i in range(f_b.shape[0]):
        topo = field_topology(jnp.asarray(f_b[i]), xi)
        g, it, ok = fused_fix(jnp.asarray(fh_b[i]), topo)
        out.append((np.asarray(g), int(it), bool(ok)))
    return out


# ---------------------------------------------------------------------------
# active-member compaction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("every", [1, 3, 8])
def test_compact_bitwise_matches_solo_mixed_convergence(every):
    f_b, fh_b, xi = _mixed_members()
    solo = _solo_results(f_b, fh_b, xi)
    assert solo[0][1] == 1 and solo[1][1] == 1     # converged members
    assert max(s[1] for s in solo) > 1             # and real stragglers
    topos = [field_topology(jnp.asarray(f), xi) for f in f_b]
    topo_b = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *topos)
    g, it, ok = fused_fix_batch(jnp.asarray(fh_b), topo_b,
                                batching="compact", compact_every=every)
    for i, (g_s, it_s, ok_s) in enumerate(solo):
        np.testing.assert_array_equal(np.asarray(g)[i], g_s)
        assert int(np.asarray(it)[i]) == it_s
        assert bool(np.asarray(ok)[i]) == ok_s


def test_compact_matches_fused_driver_exactly():
    f_b, fh_b, xi = _mixed_members()
    topos = [field_topology(jnp.asarray(f), xi) for f in f_b]
    topo_b = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *topos)
    out_f = fused_fix_batch(jnp.asarray(fh_b), topo_b, batching="fused")
    out_c = fused_fix_batch(jnp.asarray(fh_b), topo_b, batching="compact")
    for a, b in zip(out_f, out_c):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compact_max_iters_stragglers_not_converged():
    f_b, fh_b, xi = _mixed_members()
    solo_full = _solo_results(f_b, fh_b, xi)
    cap = max(s[1] for s in solo_full) - 1      # one short of the straggler
    topos = [field_topology(jnp.asarray(f), xi) for f in f_b]
    topo_b = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *topos)
    g, it, ok = fused_fix_batch(jnp.asarray(fh_b), topo_b, max_iters=cap,
                                batching="compact", compact_every=3)
    ok = np.asarray(ok)
    assert not ok.all() and ok.any()            # stragglers hit the cap...
    for i, (_, it_s, _) in enumerate(solo_full):
        if it_s <= cap:                         # ...converged members do not
            assert bool(ok[i])
            assert int(np.asarray(it)[i]) == it_s


def test_derive_edits_batch_compact_honors_per_member_xi():
    f_b, fh_b, _ = _mixed_members()
    xis = [0.3, 0.3, 0.35, 0.4, 0.3]
    res_b = derive_edits_batch(f_b, fh_b, xis, batching="compact",
                               compact_every=2)
    for i, r in enumerate(res_b):
        solo = derive_edits(f_b[i], fh_b[i], xis[i])
        np.testing.assert_array_equal(r.g, solo.g)
        np.testing.assert_array_equal(r.edits_idx, solo.edits_idx)
        np.testing.assert_array_equal(r.edits_val, solo.edits_val)
        assert r.iters == solo.iters
        assert r.max_abs_err <= xis[i] * (1 + 1e-6)


def test_batch_batching_validation():
    f_b, fh_b, xi = _mixed_members()
    topos = [field_topology(jnp.asarray(f), xi) for f in f_b]
    topo_b = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *topos)
    with pytest.raises(ValueError, match="batching"):
        fused_fix_batch(jnp.asarray(fh_b), topo_b, batching="eager")
    with pytest.raises(ValueError, match="compact_every"):
        fused_fix_batch(jnp.asarray(fh_b), topo_b, compact_every=0)


# ---------------------------------------------------------------------------
# dirty-slab worklist
# ---------------------------------------------------------------------------

def _localized_pair(shape=(40, 6, 7), xi=0.25):
    """Violations confined to a few interior slabs — the case the
    worklist exists for."""
    rng = np.random.default_rng(5)
    f = np.linspace(0, 1, int(np.prod(shape)), dtype=np.float32) \
        .reshape(shape)
    fh = f.copy()
    lo, hi = shape[0] // 2 - 3, shape[0] // 2 + 3
    fh[lo:hi] += (0.9 * xi * rng.uniform(-1, 1, (hi - lo,) + shape[1:])) \
        .astype(np.float32)
    return f, fh, xi


def test_worklist_bitwise_and_skips_slabs():
    f, fh, xi = _localized_pair()
    topo = field_topology(jnp.asarray(f), xi)
    g_d, it_d, ok_d = fused_fix(jnp.asarray(fh), topo, backend="pallas")
    g_w, it_w, ok_w, skipped = fused_fix_worklist(jnp.asarray(fh), topo)
    np.testing.assert_array_equal(np.asarray(g_w), np.asarray(g_d))
    assert int(it_w) == int(it_d) and bool(ok_w) == bool(ok_d)
    assert int(skipped) > 0      # the acceptance criterion: real skips


def test_worklist_dense_noise_still_bitwise():
    f, fh, xi = (lambda s: ( (x := np.random.default_rng(9)
                              .normal(size=s).astype(np.float32)),
                             (x + np.random.default_rng(10)
                              .uniform(-0.3, 0.3, s) * 0.999)
                             .astype(np.float32), 0.3))((24, 6, 7))
    topo = field_topology(jnp.asarray(f), xi)
    g_d, it_d, _ = fused_fix(jnp.asarray(fh), topo, backend="pallas")
    g_w, it_w, _, _ = fused_fix_worklist(jnp.asarray(fh), topo)
    np.testing.assert_array_equal(np.asarray(g_w), np.asarray(g_d))
    assert int(it_w) == int(it_d)


def test_use_worklist_policy():
    be_auto = get_backend("pallas")
    assert not be_auto.use_worklist((8, 8, 8))          # under the floor
    assert be_auto.use_worklist((be_auto.worklist_min_slabs, 8, 8))
    be_on = PallasBackend(worklist=True)
    assert be_on.use_worklist((4, 8, 8))
    assert not be_on.use_worklist((1, 8, 8))            # degenerate depth
    be_off = PallasBackend(worklist=False)
    assert not be_off.use_worklist((256, 8, 8))


def test_fused_fix_worklist_rejects_plain_backends():
    f, fh, xi = _localized_pair((12, 6, 7))
    topo = field_topology(jnp.asarray(f), xi)
    with pytest.raises(ValueError, match="worklist"):
        fused_fix_worklist(jnp.asarray(fh), topo, backend="reference")


# ---------------------------------------------------------------------------
# Pallas interpret policy
# ---------------------------------------------------------------------------

def test_interpret_env_override(monkeypatch):
    monkeypatch.setenv("MSZ_PALLAS_INTERPRET", "1")
    assert default_interpret() is True
    monkeypatch.setenv("MSZ_PALLAS_INTERPRET", "off")
    assert default_interpret() is False
    monkeypatch.setenv("MSZ_PALLAS_INTERPRET", "maybe")
    with pytest.raises(ValueError, match="MSZ_PALLAS_INTERPRET"):
        default_interpret()
    monkeypatch.delenv("MSZ_PALLAS_INTERPRET")
    expect = jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")
    assert default_interpret() is expect


def test_interpret_forced_on_still_bitwise(monkeypatch):
    # forcing interpret mode through the env must not change results
    monkeypatch.setenv("MSZ_PALLAS_INTERPRET", "true")
    f, fh, xi = _localized_pair((12, 6, 7))
    topo = field_topology(jnp.asarray(f), xi)
    g_r, it_r, _ = fused_fix(jnp.asarray(fh), topo, backend="reference")
    g_p, it_p, _ = fused_fix(jnp.asarray(fh), topo, backend="pallas")
    np.testing.assert_array_equal(np.asarray(g_p), np.asarray(g_r))
    assert int(it_p) == int(it_r)


@pytest.mark.skipif(jax.default_backend() in ("cpu",),
                    reason="lowered-vs-interpret identity needs a GPU/TPU "
                           "runtime that can actually lower Pallas kernels")
def test_lowered_vs_interpret_bitwise():
    from repro.kernels.extrema import extrema_masks_pallas
    f, fh, xi = _localized_pair((16, 8, 8))
    topo = field_topology(jnp.asarray(f), xi)
    g_i, it_i, _ = fused_fix(jnp.asarray(fh), topo,
                             backend=PallasBackend(interpret=True))
    g_l, it_l, _ = fused_fix(jnp.asarray(fh), topo,
                             backend=PallasBackend(interpret=False))
    np.testing.assert_array_equal(np.asarray(g_l), np.asarray(g_i))
    assert int(it_l) == int(it_i)
    del extrema_masks_pallas


# ---------------------------------------------------------------------------
# calibration + stream policy
# ---------------------------------------------------------------------------

def test_calibration_env_override(monkeypatch):
    monkeypatch.setenv(calibrate.ENV_VAR, "12345")
    cal = calibrate.fused_fix_threshold("pallas")
    assert cal.threshold_voxels == 12345 and cal.source == "env"
    monkeypatch.setenv(calibrate.ENV_VAR, "many")
    with pytest.raises(ValueError, match=calibrate.ENV_VAR):
        calibrate.fused_fix_threshold("pallas")
    monkeypatch.setenv(calibrate.ENV_VAR, "-3")
    with pytest.raises(ValueError, match=calibrate.ENV_VAR):
        calibrate.fused_fix_threshold("pallas")


def test_calibration_measures_clamps_and_caches(monkeypatch):
    monkeypatch.delenv(calibrate.ENV_VAR, raising=False)
    cal = calibrate.fused_fix_threshold("reference")
    assert cal.source == "measured"
    assert isinstance(cal.threshold_voxels, int)
    assert calibrate.CLAMP[0] <= cal.threshold_voxels <= calibrate.CLAMP[1]
    before = calibrate.measure_count
    again = calibrate.fused_fix_threshold("reference")
    assert again is cal                       # cache hit, no re-measure
    assert calibrate.measure_count == before


def test_stream_mixed_convergence_bitwise_and_mode_stats(monkeypatch):
    # pin the policy via the env override: exercises the stream's lazy
    # threshold fill without paying a measurement in the test suite
    monkeypatch.setenv(calibrate.ENV_VAR, "100000")
    f_b, fh_b, xi = _mixed_members()
    del fh_b   # the stream compresses f from scratch; fh was solo-only
    fields = list(f_b)
    with CompressStream(window=8, max_batch=8) as cs:
        arts = cs.map(fields, xi)
        st = cs.stats()
    assert st["fused_fix_voxels"] == 100000
    assert sum(st["fix_modes"].values()) == st["batches"] >= 1
    assert st["fix_modes"].get("fused", 0) >= 1     # 6*7*8 << the override
    for f, a in zip(fields, arts):
        solo = compress_preserving_mss(f, xi)
        assert a.base_payload == solo.base_payload
        assert a.edit_payload == solo.edit_payload


def test_stream_forced_pipelined_mode_counted(monkeypatch):
    monkeypatch.delenv(calibrate.ENV_VAR, raising=False)
    rng = np.random.default_rng(2)
    fields = [rng.normal(size=(5, 6, 7)).astype(np.float32)
              for _ in range(4)]
    with CompressStream(window=4, max_batch=4,
                        fix_batching="pipelined") as cs:
        arts = cs.map(fields, 0.3)
        st = cs.stats()
    assert st["fix_modes"] == {"pipelined": st["batches"]}
    assert st["fused_fix_voxels"] is None   # forced mode never calibrates
    for f, a in zip(fields, arts):
        solo = compress_preserving_mss(f, 0.3)
        assert a.base_payload == solo.base_payload
        assert a.edit_payload == solo.edit_payload


def test_calibration_cache_keyed_on_interpret_policy(monkeypatch):
    """The cache key must include the backend's RESOLVED Pallas interpret
    decision: an interpreted stencil is orders of magnitude slower per
    iteration than the compiled one, so a threshold measured under one
    policy is wrong for the other — the old key silently served the
    stale number when ``MSZ_PALLAS_INTERPRET`` flipped mid-process."""
    monkeypatch.delenv(calibrate.ENV_VAR, raising=False)
    calibrate.clear_cache()
    measured = []

    def fake_measure(be, dtype):
        measured.append(bool(be._interpret())
                        if hasattr(be, "_interpret") else None)
        return calibrate.FixCalibration(
            threshold_voxels=1000 + len(measured), overhead_s=0.0,
            solo_voxel_s=0.0, batched_voxel_s=0.0, source="measured")

    monkeypatch.setattr(calibrate, "_measure", fake_measure)
    monkeypatch.setenv("MSZ_PALLAS_INTERPRET", "1")
    cal_on = calibrate.fused_fix_threshold("pallas")
    monkeypatch.setenv("MSZ_PALLAS_INTERPRET", "0")
    cal_off = calibrate.fused_fix_threshold("pallas")
    # the policy flip re-measures under a distinct key (the old shared
    # key returned cal_on here) ...
    assert measured == [True, False]
    assert cal_on.threshold_voxels != cal_off.threshold_voxels
    # ... and each policy then hits its own cached entry
    monkeypatch.setenv("MSZ_PALLAS_INTERPRET", "1")
    assert calibrate.fused_fix_threshold("pallas") is cal_on
    monkeypatch.setenv("MSZ_PALLAS_INTERPRET", "0")
    assert calibrate.fused_fix_threshold("pallas") is cal_off
    assert len(measured) == 2
    calibrate.clear_cache()
