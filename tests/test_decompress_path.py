"""Device-resident decompression path (DESIGN.md §5).

The contract under test: ``decompress_preserving_mss(art,
device_path=True)`` (and "auto" whenever the preconditions hold) is
BITWISE identical to the host-side ``decompress_artifact`` on every
artifact the compress paths produce — 2D and 3D, f32 and f64-under-x64,
solo and batched, reference/pallas/sharded — while moving at most one
field-sized transfer in each direction, plus the edge cases around the
edit-application and bound-accounting bugfixes that ride with it.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.compress import (compress_preserving_mss, decompress_artifact,
                            decompress_artifact_batch,
                            decompress_preserving_mss, encode_edits, psnr)
from repro.compress import codec, pipeline, szlike, zfplike
from repro import debug
from repro.core import verify_preservation
from repro.core.driver import apply_edits, apply_edits_device
from repro.data import synthetic_field
from repro.launch.mesh import make_data_mesh

N_AVAIL = len(jax.devices())

SHAPES = [(26, 18), (12, 10, 9)]


def _case(shape, seed=3, rel=0.02):
    f = synthetic_field("molecular", shape=shape, seed=seed)
    return f, rel * float(np.ptp(f))


def _artifact(shape, seed=3, rel=0.02, **kw):
    f, xi = _case(shape, seed=seed, rel=rel)
    return f, xi, compress_preserving_mss(f, xi, **kw)


# ---------------------------------------------------------------------------
# bitwise parity host <-> device decode, per backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("shape", SHAPES)
def test_decode_bitwise_identical(shape, backend):
    f, xi, art = _artifact(shape)
    g_host = decompress_artifact(art)
    g_dev = decompress_preserving_mss(art, device_path=True, backend=backend)
    np.testing.assert_array_equal(g_host, g_dev)
    assert g_dev.dtype == f.dtype and g_dev.shape == f.shape
    v = verify_preservation(f, g_dev, xi)
    assert v["mss_preserved"] and v["bound_ok"], v


@pytest.mark.parametrize("shape", SHAPES)
def test_decode_host_path_artifact_parity(shape):
    """Host-produced szlike artifacts (byte-identical to device-produced
    ones here) also decode on device, via the decode-side range check."""
    f, xi, art = _artifact(shape, device_path=False)
    assert art.path == "host"
    np.testing.assert_array_equal(
        decompress_artifact(art),
        decompress_preserving_mss(art, device_path=True))


@pytest.mark.parametrize("n_dev", [1, 2, 4, 8])
@pytest.mark.parametrize("shape", SHAPES)
def test_decode_sharded_bitwise_identical(shape, n_dev):
    if N_AVAIL < n_dev:
        pytest.skip("needs >= %d devices (run under XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)" % n_dev)
    mesh = make_data_mesh(n_dev)
    f, xi, art = _artifact(shape)
    g = decompress_preserving_mss(art, device_path=True, backend="sharded",
                                  mesh=mesh)
    np.testing.assert_array_equal(decompress_artifact(art), g)


def test_decode_f64_under_x64():
    from jax.experimental import enable_x64
    f, xi = _case((12, 10, 9))
    f = f.astype(np.float64)
    with enable_x64():
        art = compress_preserving_mss(f, xi)
        g_host = decompress_artifact(art)
        g_dev = decompress_preserving_mss(art, device_path=True)
        assert g_dev.dtype == np.float64
        np.testing.assert_array_equal(g_host, g_dev)


def test_decode_auto_falls_back():
    f, xi = _case((26, 18))
    # zfplike base: no device reconstruct
    artz = compress_preserving_mss(f, xi, base="zfplike")
    np.testing.assert_array_equal(decompress_preserving_mss(artz),
                                  decompress_artifact(artz))
    with pytest.raises(ValueError, match="device_path=True"):
        decompress_preserving_mss(artz, device_path=True)
    # f64 artifacts need x64 for device arithmetic
    art64 = compress_preserving_mss(f.astype(np.float64), xi)
    assert art64.path == "host"
    np.testing.assert_array_equal(decompress_preserving_mss(art64),
                                  decompress_artifact(art64))


def test_decode_range_check_falls_back():
    """Host-path artifacts whose codes overflow the int32 reconstruction
    must be caught by the decoded-stream check, not silently wrapped.
    Pipeline-produced artifacts only reach this state as f64 (an f32
    field meeting its bound has max|f|/xi < 2^24 < 2^28)."""
    from jax.experimental import enable_x64
    rng = np.random.default_rng(0)
    f = 1e7 * (1 + 0.1 * rng.normal(size=(10, 12)))    # f64
    xi = 1e-4      # max|f|/xi ~ 1e11 >> 2^28: int64 host codec only
    art = compress_preserving_mss(f, xi, device_path=False)
    r, _, _, _ = szlike.sz_decode_residuals(art.base_payload)
    assert not szlike.codes_fit_int32(r)
    with enable_x64():
        g = decompress_preserving_mss(art)           # auto -> host fallback
        np.testing.assert_array_equal(g, decompress_artifact(art))
        with pytest.raises(ValueError, match="int32"):
            decompress_preserving_mss(art, device_path=True)


def test_decode_range_check_guards_constructed_f32_artifact():
    """Directly-constructed f32 artifacts bypass the pipeline's compress-
    time bound enforcement, so the decode-side check must catch their
    overflowing codes too (sz_compress happily quantizes a field far
    beyond the bound its blob can reconstruct in f32)."""
    rng = np.random.default_rng(1)
    f = (1e6 * (1 + 0.1 * rng.normal(size=(12, 10)))).astype(np.float32)
    payload = szlike.sz_compress(f, 1e-4)     # max|f|/xi ~ 1e10 >> 2^28
    art = pipeline.CompressedArtifact(
        base="szlike", base_payload=payload,
        edit_payload=encode_edits(np.zeros(0, np.int64),
                                  np.zeros(0, np.float32)),
        shape=f.shape, dtype=str(f.dtype), xi=1e-4)
    r, _, _, _ = szlike.sz_decode_residuals(art.base_payload)
    assert not szlike.codes_fit_int32(r)
    np.testing.assert_array_equal(decompress_preserving_mss(art),
                                  decompress_artifact(art))
    with pytest.raises(ValueError, match="int32"):
        decompress_preserving_mss(art, device_path=True)
    with pytest.raises(ValueError, match="int32"):
        decompress_artifact_batch([art, art], device_path=True)


def test_codes_fit_int32_intermediates():
    # per-element codes fit int32 but the axis-0 cumsum overflows
    r = np.full((3, 2), 2 ** 30, np.int64)
    assert not szlike.codes_fit_int32(r)
    assert szlike.codes_fit_int32(np.zeros((0, 4), np.int64))
    assert szlike.codes_fit_int32(np.ones((5, 5), np.int64))


# ---------------------------------------------------------------------------
# transfer counting: <= 1 field-sized crossing each way
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
def test_decode_transfer_count(shape, monkeypatch):
    f, xi, art = _artifact(shape)
    log = []
    monkeypatch.setattr(pipeline, "_transfer_hook",
                        lambda d, n: log.append((d, n)))
    decompress_preserving_mss(art, device_path=True)   # warm-up: compiles
    log.clear()
    # guard bans implicit syncs; the hook counts the explicit seams
    with debug.no_transfers():
        decompress_preserving_mss(art, device_path=True)
    field_sized = [(d, n) for d, n in log if n >= f.nbytes]
    assert sum(1 for d, _ in field_sized if d == "h2d") <= 1, log
    assert sum(1 for d, _ in field_sized if d == "d2h") == 1, log


def test_decode_batch_transfer_count(monkeypatch):
    B = 3
    arts = [compress_preserving_mss(
        synthetic_field("molecular", shape=(10, 12, 8), seed=s),
        0.02 * float(np.ptp(synthetic_field("molecular", shape=(10, 12, 8),
                                            seed=s))))
            for s in range(B)]
    log = []
    monkeypatch.setattr(pipeline, "_transfer_hook",
                        lambda d, n: log.append((d, n)))
    decompress_artifact_batch(arts, device_path=True)  # warm-up: compiles
    log.clear()
    with debug.no_transfers():
        decompress_artifact_batch(arts, device_path=True)
    member_bytes = int(np.prod((10, 12, 8))) * 4
    # pipelined: one member-sized h2d per member (residual codes), ONE
    # batch-sized d2h of the stacked g — no duplicate crossings
    h2d = [n for d, n in log if d == "h2d" and n >= member_bytes]
    assert len(h2d) == B, log
    d2h = [n for d, n in log if d == "d2h" and n >= member_bytes]
    assert d2h == [B * member_bytes], log


# ---------------------------------------------------------------------------
# batched decode
# ---------------------------------------------------------------------------

def test_decode_batch_matches_solo():
    B = 4
    arts, hosts = [], []
    for s in range(B):
        f = synthetic_field("molecular", shape=(10, 12, 8), seed=s)
        xi = (0.01 + 0.01 * s) * float(np.ptp(f))    # per-member steps
        arts.append(compress_preserving_mss(f, xi))
        hosts.append(decompress_artifact(arts[-1]))
    for g, h in zip(decompress_artifact_batch(arts, device_path=True), hosts):
        np.testing.assert_array_equal(g, h)


def test_decode_batch_sharded_matches_solo():
    if N_AVAIL < 2:
        pytest.skip("needs >= 2 devices (run under XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    mesh = make_data_mesh(2)
    arts = []
    for s in range(2):
        f = synthetic_field("molecular", shape=(10, 12, 8), seed=s)
        arts.append(compress_preserving_mss(f, 0.02 * float(np.ptp(f))))
    gb = decompress_artifact_batch(arts, device_path=True, backend="sharded",
                                   mesh=mesh)
    for a, g in zip(arts, gb):
        np.testing.assert_array_equal(decompress_artifact(a), g)


def test_decode_batch_heterogeneous_and_empty():
    assert decompress_artifact_batch([]) == []
    f2, xi2, a2 = _artifact((26, 18))
    f3, xi3, a3 = _artifact((12, 10, 9))
    az = compress_preserving_mss(f2, xi2, base="zfplike")
    out = decompress_artifact_batch([a2, a3, az])    # mixed: member-by-member
    for a, g in zip([a2, a3, az], out):
        np.testing.assert_array_equal(decompress_artifact(a), g)


# ---------------------------------------------------------------------------
# edge cases: zero edits, xi == 0 verification, empty/constant zfp fields
# ---------------------------------------------------------------------------

def test_decode_zero_edit_artifact():
    f, xi = _case((12, 10, 9))
    payload = szlike.sz_compress(f, xi)
    art = pipeline.CompressedArtifact(
        base="szlike", base_payload=payload,
        edit_payload=encode_edits(np.zeros(0, np.int64),
                                  np.zeros(0, np.float32)),
        shape=f.shape, dtype=str(f.dtype), xi=xi)
    g_host = decompress_artifact(art)
    np.testing.assert_array_equal(g_host, szlike.sz_decompress(payload))
    np.testing.assert_array_equal(
        g_host, decompress_preserving_mss(art, device_path=True))
    # batched zero-edit members: the padded scatter must be a no-op too
    np.testing.assert_array_equal(
        g_host, decompress_artifact_batch([art, art], device_path=True)[1])


def test_verify_preservation_xi_zero():
    f, _ = _case((10, 12))
    v = verify_preservation(f, f.copy(), 0.0)
    assert v["bound_ok"] and v["mss_preserved"]
    assert v["max_abs_err"] == 0.0
    g = f.copy()
    g[0, 0] += np.float32(1e-3)
    assert not verify_preservation(f, g, 0.0)["bound_ok"]


@pytest.mark.parametrize("shape", [(0, 8), (4, 0, 8)])
def test_zfp_empty_field_roundtrip(shape):
    f = np.zeros(shape, np.float32)
    fh = zfplike.zfp_decompress(zfplike.zfp_compress(f, 1e-3))
    assert fh.shape == f.shape


def test_zfp_constant_field_roundtrip():
    f = np.full((8, 12), -7.5, np.float32)
    fh = zfplike.zfp_decompress(zfplike.zfp_compress(f, 1e-4))
    assert np.max(np.abs(f - fh)) <= 1e-4


def test_sz_empty_field_roundtrip():
    f = np.zeros((0, 6), np.float32)
    fh, _ = szlike.sz_roundtrip(f, 1e-3)
    assert fh.shape == f.shape and fh.dtype == f.dtype


# ---------------------------------------------------------------------------
# the bound-accounting bugfix: zfp's f32-cast headroom (half-ULP, not 2^-22)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rel", [2.0 ** -19, 2.0 ** -16, 1e-3])
def test_zfp_bound_holds_inclusive_of_f32_cast_near_margin(rel):
    """The absolute bound must hold AFTER the final f32 cast, including
    bounds within a few octaves of the f32 representability floor
    (~amax * 2^-23) where the cast headroom dominates the budget."""
    rng = np.random.default_rng(11)
    # large offset: amax >> range, the regime where cast headroom binds
    f = (1000.0 + rng.normal(size=(16, 16))).astype(np.float32)
    amax = float(np.max(np.abs(f)))
    xi = rel * amax
    fh = zfplike.zfp_decompress(zfplike.zfp_compress(f, xi))
    assert fh.dtype == np.float32
    assert float(np.max(np.abs(f.astype(np.float64) - fh))) <= xi


def test_zfp_headroom_not_overreserved():
    """The old amax * 2^-22 reserve ate 8x the true half-ULP cast cost;
    with the correct accounting a bound at 4x the old reserve must not
    lose more than ~the true cast headroom off the effective budget."""
    rng = np.random.default_rng(7)
    f = (100.0 + 0.1 * rng.normal(size=(16, 16))).astype(np.float32)
    amax = float(np.max(np.abs(f)))
    xi = amax * 2.0 ** -20
    fh = zfplike.zfp_decompress(zfplike.zfp_compress(f, xi))
    err = float(np.max(np.abs(f.astype(np.float64) - fh)))
    assert err <= xi


# ---------------------------------------------------------------------------
# the edit-application bugfix: duplicates accumulate / are refused
# ---------------------------------------------------------------------------

def test_apply_edits_duplicate_indices_accumulate():
    f_hat = np.zeros((2, 3), np.float32)
    idx = np.array([1, 1, 4], np.int64)
    val = np.array([0.25, 0.25, -1.0], np.float32)
    g = apply_edits(f_hat, idx, val)
    # buffered fancy += would leave 0.25 at flat index 1
    assert g.reshape(-1)[1] == np.float32(0.5)
    assert g.reshape(-1)[4] == np.float32(-1.0)
    # unsorted but unique still lands on the fast path correctly
    g2 = apply_edits(f_hat, np.array([4, 1]), np.array([1.0, 2.0],
                                                      np.float32))
    assert g2.reshape(-1)[4] == 1.0 and g2.reshape(-1)[1] == 2.0


def test_encode_edits_rejects_duplicates():
    idx = np.array([3, 7, 7], np.int64)
    val = np.ones(3, np.float32)
    with pytest.raises(ValueError, match="duplicate edit index 7"):
        encode_edits(idx, val)
    # unsorted-but-unique is still fine (sorted internally)
    blob = encode_edits(np.array([7, 3], np.int64), val[:2])
    i2, v2 = codec.decode_edits(blob)
    np.testing.assert_array_equal(i2, [3, 7])


def test_apply_edits_device_matches_host():
    rng = np.random.default_rng(5)
    f_hat = rng.normal(size=(9, 8)).astype(np.float32)
    idx = np.sort(rng.choice(f_hat.size, size=12, replace=False))
    val = rng.normal(size=12).astype(np.float32)
    g_host = apply_edits(f_hat, idx, val)
    g_dev = np.asarray(apply_edits_device(jnp.asarray(f_hat), idx, val))
    np.testing.assert_array_equal(g_host, g_dev)
    # out-of-range (padding) indices drop instead of wrapping
    idx_pad = np.concatenate([idx, [f_hat.size, f_hat.size]])
    val_pad = np.concatenate([val, [5.0, 5.0]]).astype(np.float32)
    g_pad = np.asarray(apply_edits_device(jnp.asarray(f_hat), idx_pad,
                                          val_pad))
    np.testing.assert_array_equal(g_host, g_pad)


# ---------------------------------------------------------------------------
# psnr: range normalization (the paper's/SZ's convention)
# ---------------------------------------------------------------------------

def test_psnr_range_normalized_shift_invariant():
    rng = np.random.default_rng(2)
    f = rng.normal(size=(32, 32)).astype(np.float64)
    g = f + 1e-3 * rng.normal(size=f.shape)
    base = psnr(f, g)
    shifted = psnr(f + 1e4, g + 1e4)
    # the old max|f| normalization inflated the shifted case by ~80 dB
    assert abs(base - shifted) < 1e-6
    assert psnr(f, f) == float("inf")
    c = np.full((4, 4), 3.0)
    assert psnr(c, c + 1e-3) == float("-inf")


def test_decode_edits_batch_layout():
    blobs = [encode_edits(np.array([1, 5], np.int64),
                          np.array([0.5, 1.5], np.float32)),
             encode_edits(np.zeros(0, np.int64), np.zeros(0, np.float32)),
             encode_edits(np.array([0, 2, 9], np.int64),
                          np.array([1.0, 2.0, 3.0], np.float32))]
    idx_b, val_b, counts = codec.decode_edits_batch(blobs, fill_idx=10)
    assert idx_b.shape == (3, 3) and val_b.shape == (3, 3)
    np.testing.assert_array_equal(counts, [2, 0, 3])
    np.testing.assert_array_equal(idx_b[1], [10, 10, 10])
    np.testing.assert_array_equal(val_b[0], [0.5, 1.5, 0.0])
    pairs = codec.decode_edits_batch(blobs)
    assert len(pairs) == 3 and pairs[1][0].size == 0
