"""Block-decomposition parity suite (DESIGN.md §9): the 2D/3D
block-sharded SPMD fix loop must be BITWISE equal to the single-device
``reference`` backend — fields, violation counts, and iteration counts —
across mesh shapes, with and without the compute/communication-overlap
schedule and the per-block worklist, including block extents that do not
divide the field.

Multi-device cases need emulated devices:
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the 8-device
tier-1 CI legs set this); on smaller hosts they skip cleanly. The CI
block-mesh leg additionally sets ``MSZ_BLOCK_MESH=2,4`` to force the
env-driven parity case below onto a factored mesh.
"""
import functools
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import field_topology, fused_fix, resolve_backend
from repro.distributed import (ShardedBackend, halo_exchange, halo_plan,
                               plan_blocks, sharded_fix, time_step_parts)
from repro.launch.mesh import (factor_block_shape, make_block_mesh,
                               make_data_mesh)

N_AVAIL = len(jax.devices())


def _block_mesh_or_skip(shape):
    n = int(np.prod(shape))
    if N_AVAIL < n:
        pytest.skip(
            f"needs {n} devices, have {N_AVAIL} (run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    return make_block_mesh(shape)


def _pair(shape, seed, xi=0.3):
    rng = np.random.default_rng(seed)
    f = rng.normal(size=shape).astype(np.float32)
    fh = (f + rng.uniform(-xi, xi, size=shape) * 0.999).astype(np.float32)
    return f, fh, xi


@functools.lru_cache(maxsize=None)
def _solo(shape):
    """Single-device reference trajectory for one test pair."""
    f, fh, xi = _pair(shape, seed=sum(shape))
    topo = field_topology(jnp.asarray(f), xi)
    g_r, it_r, ok_r = fused_fix(jnp.asarray(fh), topo, backend="reference")
    assert bool(ok_r)
    return fh, topo, np.asarray(g_r), int(it_r)


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

def test_factor_block_shape():
    assert factor_block_shape(8, 2) == (2, 4)
    assert factor_block_shape(8, 3) == (2, 2, 2)
    assert factor_block_shape(6, 2) == (2, 3)
    assert factor_block_shape(12, 3) == (2, 2, 3)
    assert factor_block_shape(7, 2) == (1, 7)       # prime fallback
    assert factor_block_shape(1, 3) == (1, 1, 1)
    with pytest.raises(ValueError):
        factor_block_shape(0)


def test_make_block_mesh_auto():
    mesh = make_block_mesh()
    assert mesh.axis_names == ("data_y", "data_z")
    assert tuple(mesh.devices.shape) == factor_block_shape(N_AVAIL, 2)
    mesh3 = make_block_mesh(ndim=3)
    assert mesh3.axis_names == ("data_x", "data_y", "data_z")


def test_make_block_mesh_explicit_and_errors():
    mesh = make_block_mesh((1, 1))
    assert mesh.axis_names == ("data_y", "data_z")
    assert make_block_mesh((1,)).axis_names == ("data_z",)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_block_mesh((64, 64))
    with pytest.raises(ValueError, match="'auto'"):
        make_block_mesh("cube")
    with pytest.raises(ValueError, match="1-3 positive"):
        make_block_mesh((2, 2, 2, 2))


def test_plan_rejects_mixed_and_misfit_axes():
    mesh = _block_mesh_or_skip((2, 2))
    with pytest.raises(ValueError, match="no data axis"):
        plan_blocks((8, 8), jax.sharding.Mesh(
            np.asarray(jax.devices()[:1]).reshape(1), ("model",)))
    mesh3 = _block_mesh_or_skip((2, 2, 2))
    with pytest.raises(ValueError, match="2D fields"):
        plan_blocks((8, 8), mesh3)      # >1-device data_x on a 2D field
    del mesh


# ---------------------------------------------------------------------------
# bitwise parity on block meshes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_shape,shape,overlap", [
    ((2, 2), (9, 7, 10), False),     # non-divisible y, overlap off
    ((2, 2), (9, 7, 10), True),      # same field, overlapped schedule
    ((2, 4), (11, 13), True),        # 2D block mesh, pad both axes
    ((2, 2, 2), (9, 7, 10), True),   # full 3D decomposition
    ((1, 1), (8, 8), None),          # all axes size 1: no collectives
])
def test_block_parity_bitwise(mesh_shape, shape, overlap):
    mesh = _block_mesh_or_skip(mesh_shape)
    fh, topo, g_solo, it_solo = _solo(shape)
    g_s, it_s, ok_s = sharded_fix(jnp.asarray(fh), topo, mesh,
                                  overlap=overlap)
    np.testing.assert_array_equal(np.asarray(g_s), g_solo)
    assert int(it_s) == it_solo and bool(ok_s)


def test_overlap_on_off_identity():
    """The overlapped schedule is a pure re-scheduling: same field, same
    iteration count, same convergence as overlap-off on the same mesh."""
    mesh = _block_mesh_or_skip((2, 2))
    fh, topo, g_solo, it_solo = _solo((12, 6, 8))
    outs = [sharded_fix(jnp.asarray(fh), topo, mesh, overlap=ov)
            for ov in (False, True)]
    for g_s, it_s, ok_s in outs:
        np.testing.assert_array_equal(np.asarray(g_s), g_solo)
        assert int(it_s) == it_solo and bool(ok_s)


def test_block_worklist_identity():
    """Per-block dirty tracking never changes the trajectory — only
    which blocks run kernels."""
    mesh = _block_mesh_or_skip((2, 2))
    fh, topo, g_solo, it_solo = _solo((9, 7, 10))
    for wl in (False, True):
        g_s, it_s, ok_s = sharded_fix(jnp.asarray(fh), topo, mesh,
                                      worklist=wl)
        np.testing.assert_array_equal(np.asarray(g_s), g_solo)
        assert int(it_s) == it_solo and bool(ok_s)


def test_env_block_mesh_parity():
    """CI hook: MSZ_BLOCK_MESH='a,b' runs the full-loop parity case on
    that exact factored mesh (the 8-device tier-1 block leg sets 2,4)."""
    spec = os.environ.get("MSZ_BLOCK_MESH")
    if not spec:
        pytest.skip("MSZ_BLOCK_MESH not set (CI block-mesh leg sets it)")
    mesh_shape = tuple(int(s) for s in spec.split(","))
    mesh = _block_mesh_or_skip(mesh_shape)
    fh, topo, g_solo, it_solo = _solo((13, 6, 7))
    g_s, it_s, ok_s = sharded_fix(jnp.asarray(fh), topo, mesh)
    np.testing.assert_array_equal(np.asarray(g_s), g_solo)
    assert int(it_s) == it_solo and bool(ok_s)


def test_auto_backend_binds_block_mesh():
    mesh = _block_mesh_or_skip((2, 2))
    with mesh:
        be = resolve_backend("auto", (8, 6, 10), np.float32)
        assert be.name == "sharded" and be.mesh is not None
    be = resolve_backend("auto", (8, 6, 10), np.float32, mesh=mesh)
    assert be.name == "sharded"
    fh, topo, g_solo, it_solo = _solo((9, 7, 10))
    g_s, it_s, ok_s = fused_fix(jnp.asarray(fh), topo, backend="sharded",
                                mesh=mesh)
    np.testing.assert_array_equal(np.asarray(g_s), g_solo)
    assert int(it_s) == it_solo and bool(ok_s)


# ---------------------------------------------------------------------------
# collective hygiene + halo accounting
# ---------------------------------------------------------------------------

def test_size1_axis_emits_no_ppermute():
    """halo_exchange on a 1-device axis must zero-fill locally, not emit
    a degenerate self-permute collective."""
    jaxpr = jax.make_jaxpr(
        lambda x: halo_exchange(x, "data", 1))(jnp.zeros((4, 5)))
    assert "ppermute" not in str(jaxpr)
    # (the n >= 2 path needs a live mesh axis; its collectives are
    # exercised by every multi-device parity test above)
    lo, hi = halo_exchange(jnp.arange(20.0).reshape(4, 5), "data", 1)
    assert not lo.any() and not hi.any()


def test_halo_plan_block_beats_slab():
    """Analytic per-axis halo bytes: an 8-device block mesh moves less
    ghost traffic per iteration than the 8-device slab chain on a
    cube-ish field — the scaling argument for block decomposition."""
    if N_AVAIL < 8:
        pytest.skip("needs 8 devices")
    shape = (32, 32, 32)
    slab = halo_plan(shape, np.float32, make_data_mesh(8))
    block = halo_plan(shape, np.float32, make_block_mesh((2, 4)))
    assert set(slab) == {"data"} and sum(slab.values()) > 0
    assert set(block) == {"data_y", "data_z"}
    assert all(v > 0 for v in block.values())
    assert sum(block.values()) < sum(slab.values())


def test_time_step_parts_probe():
    mesh = _block_mesh_or_skip((2, 2))
    fh, topo, _, _ = _solo((8, 8, 8))
    parts = time_step_parts(jnp.asarray(fh), topo, mesh, reps=1)
    assert parts["overlap"] is True
    for k in ("t_interior_s", "t_exchange_s", "t_full_s", "t_boundary_s"):
        assert parts[k] >= 0.0


def test_backend_block_device_path_parity():
    """transform/reconstruct/scatter through the protocol on a block
    mesh: sharded must match pallas bitwise (the device compression
    path of DESIGN.md §4/§5)."""
    from repro.core import get_backend
    mesh = _block_mesh_or_skip((2, 2))
    rng = np.random.default_rng(3)
    f = rng.normal(size=(9, 7, 10)).astype(np.float32)
    step = np.float32(0.125)
    be_p = get_backend("pallas")
    be_s = ShardedBackend(mesh=mesh)
    r_p = be_p.transform(jnp.asarray(f), step)
    r_s = be_s.transform(jnp.asarray(f), step)
    np.testing.assert_array_equal(np.asarray(r_p), np.asarray(r_s))
    fh_p = be_p.reconstruct(r_p, step, np.float32)
    fh_s = be_s.reconstruct(r_s, step, np.float32)
    np.testing.assert_array_equal(np.asarray(fh_p), np.asarray(fh_s))
    idx = jnp.asarray([0, 17, 629, 123], jnp.int32)
    val = jnp.asarray([0.5, -0.25, 1.0, 2.0], jnp.float32)
    out_p = be_p.scatter_edits(fh_p, idx, val)
    out_s = be_s.scatter_edits(fh_s, idx, val)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_s))


# ---------------------------------------------------------------------------
# stream / service observability (DESIGN.md §9 surfaces)
# ---------------------------------------------------------------------------

def test_stream_stats_straggler_and_shard_keys():
    """The scheduler's stats always carry the straggler policy state and
    the sharded halo accounting, and a blown step deadline widens the
    coalescing scale instead of stalling."""
    from repro.compress.stream import CompressStream
    st = CompressStream(start=False)
    s = st.stats()
    assert s["straggler"]["linger_scale"] == 1.0
    assert s["shard"]["halo_bytes_total"] == 0
    assert s["shard"]["last"] is None
    st._note_batch(1, 0, 0, 0, 0.01)       # establish the EWMA baseline
    st._note_batch(1, 0, 0, 0, 10.0)       # blow the deadline
    s = st.stats()
    assert s["straggler"]["linger_scale"] > 1.0
    assert s["straggler"]["verdicts"].get("slow", 0) >= 1
    st._note_batch(1, 0, 0, 0, 0.01)       # healthy batch decays it
    assert st.stats()["straggler"]["linger_scale"] < s[
        "straggler"]["linger_scale"] + 1e-9
    st.close()


def test_stream_shard_halo_accounting():
    """A block-mesh stream dispatch records per-axis halo bytes = the
    analytic plan x observed fix iterations."""
    from repro.compress.stream import CompressStream
    from repro.compress import compress_preserving_mss
    mesh = _block_mesh_or_skip((2, 2))
    rng = np.random.default_rng(11)
    f = rng.normal(size=(8, 8, 8)).astype(np.float32)
    xi = 0.1
    ref = compress_preserving_mss(f, xi)
    with CompressStream(window=2, max_batch=1, mesh=mesh) as cs:
        art = cs.submit(f, xi).result()
    assert art.base_payload == ref.base_payload
    assert art.edit_payload == ref.edit_payload
    s = cs.stats()["shard"]
    assert s["fix_iters"] > 0
    assert set(s["halo_bytes_by_axis"]) == {"data_y", "data_z"}
    assert all(v > 0 for v in s["halo_bytes_by_axis"].values())
    assert s["last"]["shape"] == (8, 8, 8)


def test_service_stats_shard_surface():
    """CompressionService.stats() exposes the shard/straggler sections
    and the (initially empty) interior/boundary probe slot."""
    from repro.serve.compression import CompressionService, ServiceConfig
    with CompressionService(ServiceConfig(window=2, max_batch=1)) as svc:
        s = svc.stats()
        assert s["shard_timings"] is None
        assert "straggler" in s["compress"] and "shard" in s["compress"]
        assert svc.shard_timings() is None   # no sharded dispatch yet
