"""Numerical validation of the expert-parallel (shard_map + all_to_all)
MoE against the dense reference dispatch.

The multi-device case runs in a subprocess so the placeholder-device
XLA flag never leaks into this test process (smoke tests must see 1
device)."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.sharding import use_mesh


def _mk(seed, N=64, d=16, E=8, ff=32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, N, d)).astype(np.float32))
    p = {
        "router": jnp.asarray(rng.normal(size=(d, E)).astype(np.float32)),
        "w_gate": jnp.asarray(rng.normal(size=(E, d, ff)).astype(np.float32) * 0.1),
        "w_up": jnp.asarray(rng.normal(size=(E, d, ff)).astype(np.float32) * 0.1),
        "w_down": jnp.asarray(rng.normal(size=(E, ff, d)).astype(np.float32) * 0.1),
    }
    return x, p


# without jax.set_mesh there is no ambient abstract mesh, so moe_ffn_ep
# falls back to the dense path and the EP-vs-dense comparison is vacuous
_NEEDS_SET_MESH = pytest.mark.skipif(
    getattr(jax, "set_mesh", None) is None,
    reason="jax.set_mesh unavailable: EP path cannot engage on this jax")


@_NEEDS_SET_MESH
def test_ep_matches_dense_single_device_mesh():
    """On a 1x1 mesh the a2a is identity; EP must agree with dense up to
    capacity-drop differences (capacity is ample here)."""
    x, p = _mk(0)
    dense = layers.moe_ffn(x, p, n_experts=8, top_k=2, capacity_factor=4.0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        ep = layers.moe_ffn_ep(x, p, n_experts=8, top_k=2,
                               capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(ep.y), np.asarray(dense.y),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(ep.aux_loss), float(dense.aux_loss),
                               rtol=1e-5)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np, jax, jax.numpy as jnp
    from repro.models import layers
    from repro.models.sharding import use_mesh

    rng = np.random.default_rng(1)
    N, d, E, ff, K = 128, 16, {E}, 32, 2
    x = jnp.asarray(rng.normal(size=(1, N, d)).astype(np.float32))
    p = {{
        "router": jnp.asarray(rng.normal(size=(d, E)).astype(np.float32)),
        "w_gate": jnp.asarray(rng.normal(size=(E, d, ff)).astype(np.float32) * .1),
        "w_up": jnp.asarray(rng.normal(size=(E, d, ff)).astype(np.float32) * .1),
        "w_down": jnp.asarray(rng.normal(size=(E, ff, d)).astype(np.float32) * .1),
    }}
    dense = layers.moe_ffn(x, p, E, K, capacity_factor=8.0)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        ep = jax.jit(lambda x, p: layers.moe_ffn_ep(x, p, E, K,
                                                    capacity_factor=8.0))(x, p)
    err = float(jnp.max(jnp.abs(ep.y - dense.y)))
    rel = err / (float(jnp.max(jnp.abs(dense.y))) + 1e-9)
    assert rel < 2e-3, f"EP vs dense mismatch: rel={{rel}}"
    print("EP-OK", rel)
""")


@_NEEDS_SET_MESH
@pytest.mark.parametrize("E", [8, 4])   # E=8 -> E%tp==0 path (tp=4 -> m=1
                                        # after gcd); E=4 -> virtual experts
def test_ep_matches_dense_multidevice(E):
    """2x4 mesh in a subprocess: tokens sharded over data, experts (or
    ff-sliced virtual experts) over model; results must match dense."""
    code = _SUBPROC.format(E=E)
    out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "EP-OK" in out.stdout
