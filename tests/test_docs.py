"""Documentation gates (the PR-5 docs suite):

* the docstring audit of the public API surface is clean — every module
  documented, every ``__all__`` export and public method of exported
  classes carries a docstring (what ``python -m pdoc repro`` renders);
* README.md exists, its relative links resolve, and its 30-second
  quickstart block runs VERBATIM in a fresh interpreter;
* ``pdoc`` builds the API reference cleanly when installed (the docs CI
  job installs it; the gate skips on hosts without it).
"""
import importlib.util
import pathlib
import re
import subprocess
import sys

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load(path: pathlib.Path):
    spec = importlib.util.spec_from_file_location(path.stem, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docstring_audit_clean():
    audit = _load(REPO_ROOT / "docs" / "audit_docstrings.py")
    problems = audit.collect_problems()
    assert problems == [], "\n".join(problems)


def test_readme_links_resolve():
    assert (REPO_ROOT / "README.md").exists(), "README.md is missing"
    links = _load(REPO_ROOT / "docs" / "check_links.py")
    assert links.broken_links() == []


def test_readme_quickstart_runs_verbatim():
    """Extract the fenced block following '## 30-second quickstart' and
    run it unmodified in a fresh interpreter with PYTHONPATH=src."""
    text = (REPO_ROOT / "README.md").read_text()
    m = re.search(r"## 30-second quickstart.*?```python\n(.*?)```",
                  text, re.DOTALL)
    assert m, "README has no fenced quickstart block"
    code = m.group(1)
    proc = subprocess.run(
        [sys.executable, "-c", code], cwd=REPO_ROOT, text=True,
        capture_output=True, timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, \
        f"README quickstart failed:\n{proc.stdout}\n{proc.stderr}"


def test_pdoc_builds_clean(tmp_path):
    if importlib.util.find_spec("pdoc") is None:
        pytest.skip("pdoc not installed (the docs CI job installs it)")
    proc = subprocess.run(
        [sys.executable, "-m", "pdoc", "repro", "-o", str(tmp_path)],
        cwd=REPO_ROOT, text=True, capture_output=True, timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, f"pdoc failed:\n{proc.stdout}\n{proc.stderr}"
    assert (tmp_path / "repro.html").exists() or \
        (tmp_path / "repro" / "index.html").exists()
