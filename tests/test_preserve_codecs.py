"""Codec-agnostic preservation conformance suite (DESIGN.md §11).

Every codec registered through ``compress.preserve`` must satisfy the
same contract, judged by the pure-numpy oracle in ``core/ref.py`` — the
single source of truth this suite checks the production stack against:

* decompressed labels bitwise-equal to ``mss_labels_ref`` on the
  ORIGINAL field, for every (codec, backend, ndim, dtype) cell — the
  reference and Pallas backends plus the slab-sharded SPMD backend on
  2/4/8 emulated devices (skipped cleanly below the device count; the
  tier-1 CI matrix runs them under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``);
* every stored edit delta within the 2*xi slack (|f - f_hat| <= xi and
  |f - g| <= xi bound each side);
* artifacts byte-identical across backends, paths (szlike host vs
  device), and batch vs solo calls;
* magic negotiation: the read side refuses retired blob formats
  (SZJ1/ZFJ1) and metadata/byte-stream disagreements instead of
  misdecoding them.

Also holds the verifier-gap regressions: ``verify_preservation`` on
batched artifacts (stacks go through ``verify_preservation_batch``) and
on ``xi == 0`` zfplike blobs.
"""
import numpy as np
import pytest
import jax

from repro.compress import (check_artifact, compress_preserving_mss,
                            compress_preserving_mss_batch, decode_edits,
                            decode_payload, decompress_artifact,
                            decompress_preserving_mss,
                            get_preserving_codec, payload_codec, szlike,
                            zfplike)
from repro.compress import preserve
from repro.core import ref as R
from repro.core import verify_preservation, verify_preservation_batch
from repro.launch.mesh import make_data_mesh

N_AVAIL = len(jax.devices())

CODECS = ("szlike", "zfplike")
SHAPES = [(9, 10), (5, 6, 4)]
BACKENDS = ("reference", "pallas", "sharded2", "sharded4", "sharded8")


def _field(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=shape).astype(dtype)


def _backend_mesh(spec):
    """Map a matrix cell to (backend, mesh), skipping sharded cells on
    hosts without enough emulated devices."""
    if spec.startswith("sharded"):
        n = int(spec[len("sharded"):])
        if N_AVAIL < n:
            pytest.skip(
                f"needs {n} devices, have {N_AVAIL} (run under "
                "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return "auto", make_data_mesh(n)
    return spec, None


def _assert_conforms(f, art, xi, codec_name):
    """The PreservingCodec contract, judged entirely by the oracle."""
    assert art.base == codec_name
    pc = get_preserving_codec(codec_name)
    assert art.base_magic.encode("ascii") in pc.magics
    g = decompress_artifact(art)
    assert g.dtype == f.dtype and g.shape == f.shape

    # labels of the decompressed field == oracle labels of the ORIGINAL
    Mf, mf = R.mss_labels_ref(f)
    Mg, mg = R.mss_labels_ref(g)
    np.testing.assert_array_equal(Mg, Mf)
    np.testing.assert_array_equal(mg, mf)

    v = R.verify_preservation_ref(f, g, xi)
    assert v["mss_preserved"] and v["bound_ok"], v
    # the production verifier must agree with the oracle verdict
    vp = verify_preservation(f, g, xi)
    assert vp["mss_preserved"] and vp["bound_ok"], vp
    assert vp["right_labeled_ratio"] == v["right_labeled_ratio"] == 1.0

    # each side of an edit moves at most xi away from f -> 2*xi slack
    _, val = decode_edits(art.edit_payload)
    if val.size:
        assert float(np.max(np.abs(val))) <= 2 * xi * (1 + 1e-5)
    return g


@pytest.mark.parametrize("spec", BACKENDS)
@pytest.mark.parametrize("shape", SHAPES, ids=["2d", "3d"])
@pytest.mark.parametrize("codec_name", CODECS)
def test_conformance_f32(codec_name, shape, spec):
    backend, mesh = _backend_mesh(spec)
    f = _field(shape, np.float32, seed=len(shape))
    xi = 0.05
    art = compress_preserving_mss(f, xi, codec=codec_name, backend=backend,
                                  mesh=mesh)
    if mesh is not None:
        assert art.backend == "sharded"
    _assert_conforms(f, art, xi, codec_name)


@pytest.mark.parametrize("spec", ("reference", "pallas", "sharded2"))
@pytest.mark.parametrize("shape", SHAPES, ids=["2d", "3d"])
@pytest.mark.parametrize("codec_name", CODECS)
def test_conformance_f64_under_x64(codec_name, shape, spec):
    from jax.experimental import enable_x64
    backend, mesh = _backend_mesh(spec)
    f = _field(shape, np.float64, seed=7 + len(shape))
    xi = 0.03
    with enable_x64():
        art = compress_preserving_mss(f, xi, codec=codec_name,
                                      backend=backend, mesh=mesh)
        g = _assert_conforms(f, art, xi, codec_name)
    assert g.dtype == np.float64
    # f64 fields store f8 edit values under the "auto" dtype policy, so
    # the decode round-trip is bit-exact per element
    idx, val = decode_edits(art.edit_payload)
    assert val.dtype == (np.float64 if idx.size else val.dtype)


# ---------------------------------------------------------------------------
# byte-identity: backends, paths, batch vs solo
# ---------------------------------------------------------------------------

def _bytes(art):
    return (art.base_payload, art.edit_payload)


@pytest.mark.parametrize("codec_name", CODECS)
def test_artifact_bytes_identical_across_backends(codec_name):
    f = _field((9, 10), np.float32, seed=11)
    xi = 0.05
    ref = compress_preserving_mss(f, xi, codec=codec_name,
                                  backend="reference")
    pal = compress_preserving_mss(f, xi, codec=codec_name, backend="pallas")
    assert _bytes(pal) == _bytes(ref)
    if N_AVAIL >= 2:
        sh = compress_preserving_mss(f, xi, codec=codec_name, backend="auto",
                                     mesh=make_data_mesh(2))
        assert _bytes(sh) == _bytes(ref)


def test_szlike_host_device_bytes_identical():
    f = _field((8, 9, 6), np.float32, seed=12)
    xi = 0.05
    dev = compress_preserving_mss(f, xi, codec="szlike", device_path="auto")
    host = compress_preserving_mss(f, xi, codec="szlike", device_path=False)
    assert dev.path == "device" and host.path == "host"
    assert _bytes(dev) == _bytes(host)
    assert dev.base_magic == host.base_magic == "SZJ2"


@pytest.mark.parametrize("codec_name", CODECS)
def test_batch_bytes_identical_to_solo(codec_name):
    fields = [_field((9, 10), np.float32, seed=s) for s in (1, 2, 3)]
    xi = 0.05
    arts = compress_preserving_mss_batch(fields, xi, codec=codec_name)
    assert len(arts) == 3
    for fi, art in zip(fields, arts):
        solo = compress_preserving_mss(fi, xi, codec=codec_name)
        assert _bytes(art) == _bytes(solo)
        assert art.base_magic == solo.base_magic
    # batched artifacts verify member-by-member (the solo verifier
    # rejects stacks; see test_verify_preservation_rejects_4d_stack)
    g_b = np.stack([decompress_artifact(a) for a in arts])
    verdicts = verify_preservation_batch(np.stack(fields), g_b, xi)
    assert all(v["mss_preserved"] and v["bound_ok"] for v in verdicts)


# ---------------------------------------------------------------------------
# magic negotiation / artifact cross-checks
# ---------------------------------------------------------------------------

def test_payload_codec_negotiates_by_magic():
    f = _field((9, 10), np.float32, seed=4)
    assert payload_codec(szlike.sz_compress(f, 0.05)).name == "szlike"
    assert payload_codec(zfplike.zfp_compress(f, 0.05)).name == "zfplike"
    assert payload_codec(
        szlike.sz_compress(f, 0.05, entropy="device-pack")).name == "szlike"


@pytest.mark.parametrize("magic", [b"SZJ1", b"ZFJ1"])
def test_retired_magics_refused(magic):
    with pytest.raises(ValueError, match="refusing retired"):
        payload_codec(magic + b"\x00" * 32)


def test_unknown_magic_lists_readable_formats():
    with pytest.raises(ValueError, match="readable formats"):
        payload_codec(b"XXXX" + b"\x00" * 32)


def test_artifact_base_payload_mismatch_refused():
    f = _field((9, 10), np.float32, seed=5)
    art = compress_preserving_mss(f, 0.05, codec="zfplike")
    art.base = "szlike"     # metadata now disagrees with the byte stream
    with pytest.raises(ValueError, match="belongs to codec"):
        check_artifact(art)
    with pytest.raises(ValueError):
        decompress_artifact(art)


def test_artifact_dtype_mismatch_refused():
    f = _field((9, 10), np.float32, seed=6)
    art = compress_preserving_mss(f, 0.05, codec="zfplike")
    art.dtype = "float64"   # blob records f32; metadata lies
    with pytest.raises(ValueError, match="decodes to"):
        decode_payload(art)


def test_unknown_codec_name_raises():
    f = _field((9, 10), np.float32, seed=6)
    with pytest.raises(KeyError, match="registered"):
        compress_preserving_mss(f, 0.05, codec="nope")


def test_device_pack_artifact_records_szp1_magic():
    f = _field((9, 10), np.float32, seed=13)
    art = compress_preserving_mss(f, 0.05, codec="szlike",
                                  entropy="device-pack")
    assert art.base_magic == "SZP1"
    assert payload_codec(art.base_payload).name == "szlike"
    _assert_conforms(f, art, 0.05, "szlike")


# ---------------------------------------------------------------------------
# verifier gaps: batched artifacts, xi == 0 blobs
# ---------------------------------------------------------------------------

def test_verify_preservation_rejects_4d_stack():
    f_b = np.stack([_field((5, 6, 4), np.float32, seed=s) for s in (1, 2)])
    with pytest.raises(ValueError, match="verify_preservation_batch"):
        verify_preservation(f_b, f_b, 0.1)


def test_verify_preservation_batch_matches_solo():
    fields = [_field((9, 10), np.float32, seed=s) for s in (4, 5)]
    f_b = np.stack(fields)
    g_b = f_b.copy()
    g_b[1, 0, 0] += np.float32(10.0)   # break member 1 only
    verdicts = verify_preservation_batch(f_b, g_b, [0.1, 0.1])
    solos = [verify_preservation(f_b[i], g_b[i], 0.1) for i in range(2)]
    assert verdicts == solos
    assert verdicts[0]["mss_preserved"] and not verdicts[1]["bound_ok"]
    with pytest.raises(ValueError, match="stack"):
        verify_preservation_batch(fields[0], fields[0], 0.1)


def test_szlike_rejects_nonpositive_xi():
    f = _field((9, 10), np.float32, seed=8)
    for xi in (0.0, -1e-3):
        with pytest.raises(ValueError, match="must be positive"):
            szlike.sz_compress(f, xi)
        with pytest.raises(ValueError):
            compress_preserving_mss(f, xi, codec="szlike",
                                    device_path=False)


def test_zfplike_xi_zero_exact_on_representable_field():
    """xi == 0 is legal for the zfplike codec when the field is exactly
    representable under its block-floating-point transform (constant
    blocks); the artifact carries zero edits and verify_preservation
    accepts the bitwise-exact round-trip at xi = 0."""
    f = np.full((8, 8), -7.5, np.float32)
    art = compress_preserving_mss(f, 0.0, codec="zfplike")
    g = decompress_artifact(art)
    np.testing.assert_array_equal(g, f)
    idx, _ = decode_edits(art.edit_payload)
    assert idx.size == 0
    v = verify_preservation(f, g, 0.0)
    assert v["mss_preserved"] and v["bound_ok"] and v["max_abs_err"] == 0.0


def test_zfplike_rejects_negative_xi():
    f = _field((9, 10), np.float32, seed=9)
    with pytest.raises(ValueError, match="negative"):
        zfplike.zfp_compress(f, -1e-3)


def test_zfplike_f64_roundtrip_keeps_dtype_and_tight_bound():
    """The ZFJ2 regression pair: f64 blobs must decode to f64 carrying
    genuine sub-f32 precision (ZFJ1 always cast the reconstruction to
    f32, losing the precision the bound was derived in) and honor bounds
    near the codec's block-floating-point floor (~amax * 2^-25 per
    fractional bit budget; bounds below it surface at derive time)."""
    from jax.experimental import enable_x64
    f = _field((6, 7), np.float64, seed=10)
    xi = 3e-7
    fh = zfplike.zfp_decompress(zfplike.zfp_compress(f, xi))
    assert fh.dtype == np.float64
    assert float(np.max(np.abs(f - fh))) <= xi
    # the reconstruction is NOT an f32-representable field: the ZFJ1
    # read path could not have produced these bytes
    assert not np.array_equal(fh, fh.astype(np.float32).astype(np.float64))
    with enable_x64():
        art = compress_preserving_mss(f, xi, codec="zfplike")
        _assert_conforms(f, art, xi, "zfplike")


# ---------------------------------------------------------------------------
# device read path + service/stream integration with the codec alias
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec_name", CODECS)
def test_decompress_preserving_mss_serves_any_codec(codec_name):
    f = _field((8, 9), np.float32, seed=14)
    art = compress_preserving_mss(f, 0.05, codec=codec_name)
    g_host = decompress_artifact(art)
    np.testing.assert_array_equal(
        decompress_preserving_mss(art), g_host)


def test_service_codec_alias_matches_one_shot():
    from repro.serve import CompressionService, ServiceConfig
    f = _field((9, 10), np.float32, seed=15)
    xi = 0.05
    svc = CompressionService(ServiceConfig(max_batch=2, coalesce_ms=0.5))
    try:
        art = svc.compress(f, xi, codec="zfplike")
        solo = compress_preserving_mss(f, xi, codec="zfplike")
        assert _bytes(art) == _bytes(solo)
        assert art.base == "zfplike" and art.base_magic == "ZFJ2"
        np.testing.assert_array_equal(svc.decompress(art),
                                      decompress_artifact(solo))
    finally:
        svc.close()


def test_registry_rejects_malformed_codecs():
    with pytest.raises(ValueError, match="4 bytes"):
        preserve.register_preserving_codec(preserve.PreservingCodec(
            name="bad", compress=lambda f, xi: b"", decompress=lambda p: None,
            magics=(b"TOOLONG!",)))
    with pytest.raises(ValueError, match="no payload magics"):
        preserve.register_preserving_codec(preserve.PreservingCodec(
            name="bad", compress=lambda f, xi: b"", decompress=lambda p: None,
            magics=()))
