"""Serving-path and data-pipeline tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import TokenPipeline, synthetic_tokens, synthetic_field, FIELD_GENERATORS
from repro.models import init_params, forward, init_decode_cache
from repro.serve import greedy_generate, make_serve_step


def test_decode_matches_forward_dense():
    """Token-by-token decode must reproduce the full-forward logits
    (same params, same tokens) for the dense family."""
    cfg = get_smoke_config("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = forward(cfg, params, {"tokens": toks}).logits  # (B,S,V)

    cache = init_decode_cache(cfg, B, max_len=S)
    step = make_serve_step(cfg)
    got = []
    for t in range(S):
        _, logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    # bf16 params: chunked (flash) vs unchunked (decode) softmax accumulate
    # in different orders; position 0 matches to 1e-7, later drift ~4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=1e-1)


@pytest.mark.parametrize("arch", ["gemma2-9b", "xlstm-1.3b", "hymba-1.5b"])
def test_decode_matches_forward_other_families(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    B, S = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = forward(cfg, params, {"tokens": toks}).logits

    cache = init_decode_cache(cfg, B, max_len=S)
    step = make_serve_step(cfg)
    got = []
    for t in range(S):
        _, logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_greedy_generate_deterministic():
    cfg = get_smoke_config("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    a = greedy_generate(cfg, params, prompt, n_new=6)
    b = greedy_generate(cfg, params, prompt, n_new=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (1, 6)


def test_token_pipeline_sharding():
    pipe = TokenPipeline(vocab_size=100, batch=8, seq_len=16)
    full = pipe.get_batch(0)
    shards = [TokenPipeline(vocab_size=100, batch=8, seq_len=16,
                            dp_rank=r, dp_size=4).get_batch(0)
              for r in range(4)]
    recon = np.concatenate([s["tokens"] for s in shards], axis=0)
    np.testing.assert_array_equal(recon, full["tokens"])


def test_token_pipeline_labels_shifted():
    b = synthetic_tokens(50, 2, 32, step=0)
    # labels are next-token targets of tokens
    assert b["tokens"].shape == b["labels"].shape == (2, 32)


def test_field_generators_deterministic():
    for name in FIELD_GENERATORS:
        a = synthetic_field(name)
        b = synthetic_field(name)
        np.testing.assert_array_equal(a, b)
        assert a.dtype == np.float32
        assert np.all(np.isfinite(a))
