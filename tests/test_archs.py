"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step + one decode step on CPU; output shapes + no
NaNs. (Full configs are exercised allocation-free by the dry-run.)"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (init_params, forward, decode_step,
                          init_decode_cache, window_schedule)
from repro.train import (AdamWConfig, TrainState, TrainStepConfig, adamw_init,
                         make_train_step)


def _batch_for(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.n_img_tokens:
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_img_tokens, cfg.d_model)), jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_positions, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch_for(cfg, B, S)
    out = forward(cfg, params, batch)
    S_out = S + (cfg.n_img_tokens or 0)
    assert out.logits.shape == (B, S_out, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(out.logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    state = TrainState(params=params, opt=adamw_init(params))
    step = jax.jit(make_train_step(
        cfg, TrainStepConfig(remat=False), AdamWConfig(lr_peak=1e-3,
                                                       warmup_steps=1,
                                                       decay_steps=5)))
    batch = _batch_for(cfg)
    batch["labels"] = batch["tokens"]
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    a = jax.tree.leaves(state.params)[0]
    b = jax.tree.leaves(state2.params)[0]
    assert not np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(2))
    B = 2
    cache = init_decode_cache(cfg, B, max_len=32)
    toks = jnp.zeros((B, 1), jnp.int32)
    logits, cache = decode_step(cfg, params, cache, toks, jnp.int32(0))
    logits2, cache = decode_step(cfg, params, cache, toks, jnp.int32(1))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


def test_full_configs_match_assignment():
    """The full configs must carry the exact assigned hyperparameters."""
    expect = {
        "llava_next_34b": dict(n_layers=60, d_model=7168, n_heads=56,
                               n_kv_heads=8, d_ff=20480, vocab=64000),
        "grok_1_314b": dict(n_layers=64, d_model=6144, n_heads=48,
                            n_kv_heads=8, d_ff=32768, vocab=131072),
        "qwen3_moe_235b_a22b": dict(n_layers=94, d_model=4096, n_heads=64,
                                    n_kv_heads=4, d_ff=1536, vocab=151936),
        "deepseek_coder_33b": dict(n_layers=62, d_model=7168, n_heads=56,
                                   n_kv_heads=8, d_ff=19200, vocab=32256),
        "smollm_135m": dict(n_layers=30, d_model=576, n_heads=9,
                            n_kv_heads=3, d_ff=1536, vocab=49152),
        "granite_8b": dict(n_layers=36, d_model=4096, n_heads=32,
                           n_kv_heads=8, d_ff=14336, vocab=49152),
        "gemma2_9b": dict(n_layers=42, d_model=3584, n_heads=16,
                          n_kv_heads=8, d_ff=14336, vocab=256000),
        "whisper_base": dict(n_layers=6, d_model=512, n_heads=8,
                             n_kv_heads=8, d_ff=2048, vocab=51865),
        "xlstm_1_3b": dict(n_layers=48, d_model=2048, n_heads=4,
                           n_kv_heads=4, d_ff=0, vocab=50304),
        "hymba_1_5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab=32001),
    }
    for arch, spec in expect.items():
        cfg = get_config(arch)
        for k, v in spec.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    # moe settings
    assert get_config("grok_1_314b").moe.n_experts == 8
    assert get_config("grok_1_314b").moe.top_k == 2
    assert get_config("qwen3_moe_235b_a22b").moe.n_experts == 128
    assert get_config("qwen3_moe_235b_a22b").moe.top_k == 8
    assert get_config("hymba_1_5b").ssm_state == 16


def test_param_counts_in_expected_range():
    """Analytic parameter counts should land near the named sizes."""
    cases = {"llava_next_34b": (30e9, 40e9), "grok_1_314b": (280e9, 340e9),
             "qwen3_moe_235b_a22b": (200e9, 260e9),
             "deepseek_coder_33b": (28e9, 38e9),
             "smollm_135m": (0.1e9, 0.2e9), "granite_8b": (6e9, 10e9),
             "gemma2_9b": (7e9, 12e9), "xlstm_1_3b": (0.9e9, 1.8e9),
             "hymba_1_5b": (1.0e9, 2.2e9)}
    for arch, (lo, hi) in cases.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, (arch, n)


def test_gemma2_window_schedule():
    ws = window_schedule(get_config("gemma2_9b"))
    assert ws[0] == 4096 and ws[1] > 1e6 and ws[2] == 4096


def test_hymba_window_schedule():
    cfg = get_config("hymba_1_5b")
    ws = window_schedule(cfg)
    assert ws[0] > 1e6 and ws[16] > 1e6 and ws[31] > 1e6
    assert ws[1] == 1024
