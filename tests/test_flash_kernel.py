"""Pallas flash-attention kernel vs the jnp online-softmax oracle."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.flash import flash_attention_pallas
from repro.models.layers import flash_attention as oracle

CASES = [
    # B, S, H, Hk, Dh, q_block, k_block
    (2, 64, 4, 2, 16, 32, 32),     # GQA, square blocks
    (1, 128, 8, 8, 32, 64, 32),    # MHA, rectangular blocks
    (2, 96, 6, 3, 8, 32, 48),      # non-power-of-two S
    (1, 64, 2, 1, 64, 64, 64),     # single kv head, one block pair
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(case, causal):
    B, S, H, Hk, Dh, qb, kb = case
    rng = np.random.default_rng(hash(case) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hk, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hk, Dh)).astype(np.float32))
    got = flash_attention_pallas(q, k, v, causal=causal, q_block=qb,
                                 k_block=kb, interpret=True)
    want = oracle(q, k, v, causal=causal, q_chunk=qb, k_chunk=kb)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    B, S, H, Hk, Dh = 1, 64, 4, 2, 32
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, Hk, Dh)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, Hk, Dh)), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True, q_block=32,
                                 k_block=32, interpret=True)
    want = oracle(q, k, v, causal=True, q_chunk=32, k_chunk=32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)
