"""Oracle conformance suite: the vectorized label machinery vs the
brute-force core/ref.py oracle.

TopoSZ (arXiv 2304.11768) motivates why EXACTNESS — not approximate
agreement — is the bar for topology-preserving compression, so every
check here is equality, not closeness: ``mss_labels`` /
``labels_from_codes`` / ``segmentation_accuracy`` must reproduce the
per-vertex path-walking oracle bit for bit, on randomized fields AND on
the plateau/tie fields that stress the Simulation-of-Simplicity total
order. Also holds the pointer-jumping regression: the sweep bound is
derived from the field size, so a single integral line snaking through
every vertex still resolves (labels.default_pointer_iters).
"""
import numpy as np
import pytest
import jax.numpy as jnp
from _hyp_compat import given, settings, st

from repro.core import (default_pointer_iters, labels_from_codes, mss_labels,
                        pointer_jump, segmentation_accuracy, steepest_dirs)
from repro.core import ref as R
from repro.core.grid import dir_to_pointer


def _assert_labels_match_oracle(f: np.ndarray):
    M, m = mss_labels(jnp.asarray(f))
    Mr, mr = R.mss_labels_ref(f)
    np.testing.assert_array_equal(np.asarray(M), Mr)
    np.testing.assert_array_equal(np.asarray(m), mr)


def _tie_field(rng, shape, levels: int) -> np.ndarray:
    """Few quantization levels -> large plateaus; every comparison inside
    a plateau is decided purely by the SoS index tie-break."""
    return rng.integers(0, levels, size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# mss_labels vs oracle — randomized seeded grids, smooth and tie-heavy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(11, 13), (5, 6, 7)])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_mss_labels_conform_random(shape, seed):
    rng = np.random.default_rng(seed)
    _assert_labels_match_oracle(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("shape", [(11, 13), (5, 6, 7)])
@pytest.mark.parametrize("levels", [1, 2, 3, 8])
def test_mss_labels_conform_plateaus(shape, levels):
    rng = np.random.default_rng(levels * 101 + len(shape))
    _assert_labels_match_oracle(_tie_field(rng, shape, levels))


@pytest.mark.parametrize("shape", [(9, 9), (4, 5, 6)])
def test_mss_labels_conform_structured_ties(shape):
    """Hand-built non-Morse structures: checkerboard (every vertex on a
    tie front) and an axis-constant ridge (degenerate along one axis)."""
    idx = np.indices(shape).sum(axis=0)
    checker = (idx % 2).astype(np.float32)
    _assert_labels_match_oracle(checker)
    ridge = np.broadcast_to(
        np.arange(shape[-1], dtype=np.float32) % 3, shape).copy()
    _assert_labels_match_oracle(ridge)


def test_mss_labels_conform_central_plateau():
    f = np.zeros((10, 10), np.float32)
    f[3:7, 3:7] = 1.0              # flat square summit
    f[0, 0] = -1.0                 # unique low corner
    _assert_labels_match_oracle(f)


# ---------------------------------------------------------------------------
# labels_from_codes vs oracle (no prior direct coverage)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,levels", [((11, 13), 0), ((5, 6, 7), 0),
                                          ((11, 13), 3), ((5, 6, 7), 2)])
def test_labels_from_codes_conform(shape, levels):
    """Feed ORACLE direction codes into the pointer-jumping resolver: the
    resulting labels must equal the oracle's full path walk, isolating
    labels_from_codes from steepest_dirs."""
    rng = np.random.default_rng(len(shape) * 7 + levels)
    f = (rng.normal(size=shape).astype(np.float32) if levels == 0
         else _tie_field(rng, shape, levels))
    upr, dnr = R.steepest_dirs_ref(f)
    M, m = labels_from_codes(jnp.asarray(upr), jnp.asarray(dnr))
    Mr, mr = R.mss_labels_ref(f)
    np.testing.assert_array_equal(np.asarray(M), Mr)
    np.testing.assert_array_equal(np.asarray(m), mr)
    # and the vectorized codes feeding it agree with the oracle codes
    up, dn = steepest_dirs(jnp.asarray(f))
    np.testing.assert_array_equal(np.asarray(up), upr)
    np.testing.assert_array_equal(np.asarray(dn), dnr)


# ---------------------------------------------------------------------------
# segmentation_accuracy vs oracle (no prior direct coverage)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(11, 13), (5, 6, 7)])
@pytest.mark.parametrize("noise", [0.0, 0.05, 0.5])
def test_segmentation_accuracy_conform(shape, noise):
    rng = np.random.default_rng(int(noise * 100) + len(shape))
    f = rng.normal(size=shape).astype(np.float32)
    g = (f + noise * rng.normal(size=shape)).astype(np.float32)
    Mf, mf = R.mss_labels_ref(f)
    Mg, mg = R.mss_labels_ref(g)
    want = float(np.mean(((Mf == Mg) & (mf == mg)).astype(np.float32)))
    got = float(segmentation_accuracy(jnp.asarray(f), jnp.asarray(g)))
    assert got == pytest.approx(want, abs=1e-7)
    if noise == 0.0:
        assert got == 1.0


def test_segmentation_accuracy_on_tied_pair():
    """Plateau vs slightly-perturbed plateau: the right-labeled ratio is
    entirely SoS-determined and must match the oracle exactly."""
    rng = np.random.default_rng(5)
    f = _tie_field(rng, (10, 12), 2)
    g = f.copy()
    g[4, 5] += 0.5
    Mf, mf = R.mss_labels_ref(f)
    Mg, mg = R.mss_labels_ref(g)
    want = float(np.mean(((Mf == Mg) & (mf == mg)).astype(np.float32)))
    got = float(segmentation_accuracy(jnp.asarray(f), jnp.asarray(g)))
    assert got == pytest.approx(want, abs=1e-7)


# ---------------------------------------------------------------------------
# hypothesis properties (skipped cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(data=st.lists(st.integers(0, 3), min_size=42, max_size=42))
def test_property_2d_tie_labels(data):
    """Arbitrary 4-level 6x7 fields (ties everywhere): labels must equal
    the oracle. Fixed shape keeps the suite compile-bound-free."""
    f = np.asarray(data, np.float32).reshape(6, 7)
    _assert_labels_match_oracle(f)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), levels=st.integers(1, 5))
def test_property_3d_tie_labels(seed, levels):
    rng = np.random.default_rng(seed)
    _assert_labels_match_oracle(_tie_field(rng, (4, 5, 6), levels))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), noise=st.floats(0.0, 0.3))
def test_property_accuracy_conform(seed, noise):
    rng = np.random.default_rng(seed)
    f = rng.normal(size=(8, 9)).astype(np.float32)
    g = (f + noise * rng.normal(size=(8, 9))).astype(np.float32)
    Mf, mf = R.mss_labels_ref(f)
    Mg, mg = R.mss_labels_ref(g)
    want = float(np.mean(((Mf == Mg) & (mf == mg)).astype(np.float32)))
    assert float(segmentation_accuracy(
        jnp.asarray(f), jnp.asarray(g))) == pytest.approx(want, abs=1e-7)


# ---------------------------------------------------------------------------
# pointer_jump: size-derived sweep bound (regression for the silent
# truncation hazard of a fixed max_iters)
# ---------------------------------------------------------------------------

def test_default_pointer_iters_formula():
    assert default_pointer_iters(2) == 2
    assert default_pointer_iters(512) == 10
    assert default_pointer_iters(513) == 11
    assert default_pointer_iters(2**20) == 21
    # monotone in V, and always enough doublings to span any path
    for v in (2, 3, 100, 10_000):
        assert 2 ** (default_pointer_iters(v) - 1) >= v


def test_pointer_jump_long_monotone_staircase():
    """A (1, V) monotone ramp is ONE integral line through all V vertices
    — the worst case the derived bound must cover. The default resolves
    it exactly; an explicitly-too-small bound demonstrably truncates
    (which is why the default is now derived, not hard-coded)."""
    V = 500
    f = np.arange(V, dtype=np.float32).reshape(1, V)
    up, dn = steepest_dirs(jnp.asarray(f))
    nxt_up = dir_to_pointer(up)
    labels = np.asarray(pointer_jump(nxt_up))          # derived default
    np.testing.assert_array_equal(labels, np.full(V, V - 1, np.int32))
    assert default_pointer_iters(V) < 64               # tighter than old cap
    truncated = np.asarray(pointer_jump(nxt_up, max_iters=2))
    assert not np.array_equal(truncated, labels)       # the hazard is real
    # full-stack check: labels on the staircase match the path-walk oracle
    _assert_labels_match_oracle(f)


def test_pointer_jump_serpentine_staircase():
    """2D serpentine: a monotone path over the even rows with a deep
    barrier between them — a long winding integral line plus massive
    barrier plateaus, checked against the oracle."""
    H, W = 9, 21
    f = np.full((H, W), -1e6, np.float32)
    val = 0.0
    for i, y in enumerate(range(0, H, 2)):
        xs = range(W) if i % 2 == 0 else range(W - 1, -1, -1)
        for x in xs:
            f[y, x] = val
            val += 1.0
        if y + 1 < H:                       # connector through the barrier
            f[y + 1, W - 1 if i % 2 == 0 else 0] = val
            val += 1.0
    _assert_labels_match_oracle(f)
