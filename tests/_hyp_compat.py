"""Import hypothesis when available; otherwise provide stand-ins so the
property tests are SKIPPED (not collection errors) while every
deterministic test in the module still runs.

Usage in test modules:  ``from _hyp_compat import given, settings, st``
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Chainable stand-in: any attribute/call returns a _Strategy, so
        strategy expressions like st.floats(0, 1).map(abs) evaluate at
        collection time without hypothesis installed."""

        def __getattr__(self, name):
            return _Strategy()

        def __call__(self, *args, **kwargs):
            return _Strategy()

    st = _Strategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis is not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
