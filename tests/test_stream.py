"""Stream-scheduler suite (repro.compress.stream + repro.serve.compression,
DESIGN.md §6).

The contract under test: the stream/service layer reorders and overlaps
work but never changes it — every artifact and decompressed field must
be BYTE-identical to its one-shot pipeline counterpart — while honoring
the scheduling invariants: submission-order results under out-of-order
completion, per-spec batching of mixed traffic (or rejection under
``strict_uniform``), the bounded in-flight window (backpressure), and
LRU eviction in the dispatch-spec cache. Sharded parity cases need
emulated devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
the second tier-1 CI job); on a 1-device host they skip cleanly.
"""
import functools
import json
import urllib.request

import numpy as np
import pytest
import jax

from repro.compress import (CompressStream, DecompressStream, SpecCache,
                            StreamBackpressure, StreamClosed,
                            compress_preserving_mss,
                            decompress_preserving_mss)
from repro.compress import pipeline
from repro.data import synthetic_field
from repro.launch.mesh import make_data_mesh
from repro.serve import (CompressionService, ServiceConfig, ServiceOverloaded,
                         start_stats_server)

N_AVAIL = len(jax.devices())

SHAPE_3D = (8, 8, 8)
SHAPE_2D = (12, 10)


def _traffic(shape, n, seed0=0, xi_rel=1e-3):
    fields = [synthetic_field("nyx", shape=shape, seed=seed0 + s)
              .astype(np.float32) for s in range(n)]
    return fields, [xi_rel * float(np.ptp(f)) for f in fields]


@functools.lru_cache(maxsize=None)
def _solo_artifacts(shape, n, base="szlike"):
    fields, xis = _traffic(shape, n)
    return fields, xis, [compress_preserving_mss(f, xi, base=base)
                         for f, xi in zip(fields, xis)]


def _assert_identical(arts, refs):
    assert len(arts) == len(refs)
    for a, r in zip(arts, refs):
        assert a.base_payload == r.base_payload
        assert a.edit_payload == r.edit_payload
        assert tuple(a.shape) == tuple(r.shape) and a.dtype == r.dtype


# ---------------------------------------------------------------------------
# byte-identity + ordering
# ---------------------------------------------------------------------------

def test_stream_matches_one_shot():
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 6)
    with CompressStream(window=4, max_batch=4) as cs:
        arts = cs.map(fields, xis)
        st = cs.stats()
    _assert_identical(arts, refs)
    assert st["completed"] == 6 and st["failed"] == 0
    assert st["in_flight"] == 0 and st["batches"] >= 2
    assert 0.0 < st["batch_occupancy"] <= 1.0
    assert st["nbytes_h2d"] > 0 and st["nbytes_d2h"] > 0


def test_ordering_under_out_of_order_completion():
    """Interleaved specs form separate batches that complete in whatever
    order the scheduler reaches them; per-request results must still land
    on the right futures, i.e. map() returns submission order."""
    f3, xi3, ref3 = _solo_artifacts(SHAPE_3D, 3)
    f2, xi2, ref2 = _solo_artifacts(SHAPE_2D, 3)
    fields = [x for pair in zip(f3, f2) for x in pair]
    xis = [x for pair in zip(xi3, xi2) for x in pair]
    refs = [x for pair in zip(ref3, ref2) for x in pair]
    with CompressStream(window=6, max_batch=4) as cs:
        arts = cs.map(fields, xis)
        st = cs.stats()
    _assert_identical(arts, refs)
    # mixed specs may not share a batch: every dispatched batch was
    # uniform, so at least one batch per spec
    assert st["batches"] >= 2


def test_mixed_shapes_batch_separately_and_xi_rides_along():
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 4)
    # per-request xi within one batch: tighten two members' bounds
    xis = [xi * (0.5 if i % 2 else 1.0) for i, xi in enumerate(xis)]
    refs = [compress_preserving_mss(f, xi) for f, xi in zip(fields, xis)]
    with CompressStream(window=4, max_batch=4) as cs:
        arts = cs.map(fields, xis)
    _assert_identical(arts, refs)


def test_strict_uniform_rejects_mixed_specs():
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 2)
    other = synthetic_field("nyx", shape=SHAPE_2D).astype(np.float32)
    with CompressStream(window=4, strict_uniform=True) as cs:
        fut = cs.submit(fields[0], xis[0])
        with pytest.raises(ValueError, match="strict_uniform"):
            cs.submit(other, 1e-3)
        # the pinned spec still serves
        _assert_identical([fut.result()], [refs[0]])


def test_error_propagates_to_the_request_future():
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 2)
    with CompressStream(window=4, device_path=True) as cs:
        bad = cs.submit(fields[0], xis[0], base="zfplike")
        good = cs.submit(fields[1], xis[1])
        with pytest.raises(ValueError, match="szlike"):
            bad.result()
        _assert_identical([good.result()], [refs[1]])
        st = cs.stats()
    assert st["failed"] == 1 and st["completed"] == 1


def test_submit_after_close_raises():
    cs = CompressStream(window=2)
    cs.close()
    with pytest.raises(StreamClosed):
        cs.submit(np.zeros(SHAPE_3D, np.float32), 1e-3)


def test_close_drains_a_never_started_stream():
    """close() must not abandon queued Futures even when the scheduler
    was never started (start=False)."""
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 2)
    cs = CompressStream(window=4, start=False)
    futs = [cs.submit(f, xi) for f, xi in zip(fields, xis)]
    cs.close()
    _assert_identical([f.result(timeout=60) for f in futs], refs)


def test_cancelled_future_does_not_kill_the_scheduler():
    """A caller cancelling a queued request must drop it (slot freed,
    counted as failed) without crashing the scheduler or starving the
    other requests."""
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 3)
    cs = CompressStream(window=3, max_batch=2, start=False)
    futs = [cs.submit(f, xi) for f, xi in zip(fields, xis)]
    assert futs[1].cancel()
    cs.start()
    _assert_identical([futs[0].result(timeout=60),
                       futs[2].result(timeout=60)], [refs[0], refs[2]])
    cs.flush()
    st = cs.stats()
    cs.close()
    assert st["completed"] == 2 and st["failed"] == 1
    assert st["in_flight"] == 0


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def test_backpressure_window_bound_honored():
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 4)
    cs = CompressStream(window=3, max_batch=2, start=False)
    futs = [cs.submit(fields[i], xis[i], block=False) for i in range(3)]
    # window full, scheduler not draining: a non-blocking submit must
    # reject rather than grow the in-flight set
    with pytest.raises(StreamBackpressure):
        cs.submit(fields[3], xis[3], block=False)
    # a blocking submit with a timeout gives up, not deadlocks
    with pytest.raises(StreamBackpressure):
        cs.submit(fields[3], xis[3], timeout=0.05)
    cs.start()
    futs.append(cs.submit(fields[3], xis[3]))   # blocks until a slot frees
    arts = [f.result() for f in futs]
    st = cs.stats()
    cs.close()
    _assert_identical(arts, refs)
    assert st["max_in_flight"] <= 3


def test_service_overload_reject_maps_backpressure():
    with pytest.raises(ValueError):
        ServiceConfig(overload="nope")
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 1)
    svc = CompressionService(ServiceConfig(window=1, overload="reject"))
    # saturate the single-slot window via the stream's own gate, then a
    # service submit must surface ServiceOverloaded
    assert svc._compress._slots.acquire(blocking=False)
    with pytest.raises(ServiceOverloaded):
        svc.submit_compress(fields[0], xis[0])
    svc._compress._slots.release()
    _assert_identical([svc.compress(fields[0], xis[0])], [refs[0]])
    svc.close()


# ---------------------------------------------------------------------------
# spec cache
# ---------------------------------------------------------------------------

def test_spec_cache_lru_eviction():
    with pytest.raises(ValueError):
        SpecCache(maxsize=0)
    c = SpecCache(maxsize=2)
    assert c.get("a", lambda: 1) == 1
    assert c.get("b", lambda: 2) == 2
    assert c.get("a", lambda: -1) == 1          # hit: not rebuilt
    c.get("c", lambda: 3)                        # evicts b (LRU)
    assert c.stats()["evictions"] == 1 and len(c) == 2
    assert c.get("b", lambda: 20) == 20          # b was evicted -> rebuilt
    s = c.stats()
    assert s["hits"] == 1 and s["misses"] == 4 and s["size"] == 2


def test_stream_cache_hits_and_eviction_counters():
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 4)
    with CompressStream(window=4, max_batch=2, cache_size=1) as cs:
        arts = cs.map(fields, [xis[0]] * 4)      # one spec: same shape + xi
        cache1 = cs.stats()["cache"]
        # a second spec (different xi) with cache_size=1 must evict
        cs.map(fields[:2], [xis[0] * 0.5] * 2)
        cache2 = cs.stats()["cache"]
    refs0 = [compress_preserving_mss(f, xis[0]) for f in fields]
    _assert_identical(arts, refs0)
    assert cache1["misses"] >= 1 and cache1["hits"] >= 1
    assert cache2["evictions"] >= 1 and cache2["size"] == 1


# ---------------------------------------------------------------------------
# fix-batching policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["auto", "fused", "pipelined"])
def test_fix_batching_modes_all_byte_identical(mode):
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 4)
    with CompressStream(window=4, max_batch=4, fix_batching=mode) as cs:
        _assert_identical(cs.map(fields, xis), refs)


def test_fix_batching_rejects_unknown_mode():
    with pytest.raises(ValueError, match="fix_batching"):
        CompressStream(fix_batching="eager")


# ---------------------------------------------------------------------------
# decompress stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("base", ["szlike", "zfplike"])
def test_decompress_stream_parity(base):
    fields, xis, arts = _solo_artifacts(SHAPE_3D, 4, base=base)
    want = [decompress_preserving_mss(a) for a in arts]
    with DecompressStream(window=4, max_batch=4) as ds:
        gs = ds.map(arts)
        st = ds.stats()
    for g, w in zip(gs, want):
        np.testing.assert_array_equal(g, w)
    assert st["completed"] == 4 and st["failed"] == 0


def test_decompress_stream_mixed_spec_traffic():
    _, _, a3 = _solo_artifacts(SHAPE_3D, 2)
    _, _, a2 = _solo_artifacts(SHAPE_2D, 2)
    arts = [a3[0], a2[0], a3[1], a2[1]]
    want = [decompress_preserving_mss(a) for a in arts]
    with DecompressStream(window=4, max_batch=4) as ds:
        gs = ds.map(arts)
    for g, w in zip(gs, want):
        np.testing.assert_array_equal(g, w)


# ---------------------------------------------------------------------------
# sharded backend serving stream members across the mesh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_dev", [1, 2, 4, 8])
def test_sharded_stream_parity(n_dev):
    if N_AVAIL < n_dev:
        pytest.skip(
            f"needs {n_dev} devices, have {N_AVAIL} (run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    mesh = make_data_mesh(n_dev)
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 3)
    with CompressStream(window=3, max_batch=2, mesh=mesh) as cs:
        arts = cs.map(fields, xis)
    _assert_identical(arts, refs)       # mesh changes execution, not bytes
    want = [decompress_preserving_mss(a) for a in refs]
    with DecompressStream(window=3, max_batch=2, mesh=mesh) as ds:
        gs = ds.map(arts)
    for g, w in zip(gs, want):
        np.testing.assert_array_equal(g, w)


# ---------------------------------------------------------------------------
# the service layer
# ---------------------------------------------------------------------------

def test_service_roundtrip_and_stats():
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 3)
    with CompressionService(ServiceConfig(window=4, max_batch=2)) as svc:
        futs = [svc.submit_compress(f, xi) for f, xi in zip(fields, xis)]
        arts = [f.result() for f in futs]
        _assert_identical(arts, refs)
        gs = [svc.decompress(a) for a in arts]
        for f, xi, g in zip(fields, xis, gs):
            assert float(np.max(np.abs(f - g))) <= xi * (1 + 1e-6)
        svc.flush()
        st = svc.stats()
    assert st["compress"]["completed"] == 3
    assert st["decompress"]["completed"] == 3
    assert st["uptime_s"] > 0
    assert st["config"]["window"] == 4


def test_service_stats_http_endpoint():
    fields, xis, _ = _solo_artifacts(SHAPE_3D, 1)
    with CompressionService(ServiceConfig(window=2)) as svc:
        svc.compress(fields[0], xis[0])
        server = start_stats_server(svc, port=0)
        try:
            host, port = server.server_address[:2]
            with urllib.request.urlopen(
                    f"http://{host}:{port}/stats", timeout=5) as resp:
                doc = json.loads(resp.read())
            assert doc["compress"]["completed"] == 1
            assert "fields_per_sec" in doc["compress"]
            assert "cache" in doc["compress"]
            with urllib.request.urlopen(
                    f"http://{host}:{port}/healthz", timeout=5) as resp:
                assert resp.read().strip() == b"ok"
        finally:
            server.shutdown()


# ---------------------------------------------------------------------------
# on-device entropy coding through the stream (DESIGN.md §8)
# ---------------------------------------------------------------------------

def _record_pool(stream):
    """Wrap the stream's worker-pool submit so tests can assert exactly
    which jobs (by function name) the scheduler handed off."""
    jobs = []
    orig = stream._pool.submit

    def recording_submit(fn, *args, **kw):
        jobs.append(getattr(fn, "__name__", str(fn)))
        return orig(fn, *args, **kw)

    stream._pool.submit = recording_submit
    return jobs


def test_device_pack_compress_bypasses_worker_pool():
    """A device-pack batch performs ZERO host entropy work: the stream
    scheduler must never hand a device-pack member to the worker pool
    (its entropy stream left the device fully framed), while the
    artifacts stay byte-identical to one-shot device-pack calls."""
    fields, xis = _traffic(SHAPE_3D, 4)
    refs = [compress_preserving_mss(f, xi, entropy="device-pack")
            for f, xi in zip(fields, xis)]
    with CompressStream(window=4, max_batch=4, linger_ms=50) as cs:
        jobs = _record_pool(cs)
        futs = [cs.submit(f, xi, entropy="device-pack")
                for f, xi in zip(fields, xis)]
        arts = [f.result() for f in futs]
        st = cs.stats()
    assert jobs == [], f"worker pool saw {jobs} for device-pack traffic"
    _assert_identical(arts, refs)
    for a in arts:
        assert a.entropy == "device-pack"
    assert st["entropy_codecs"]["device-pack"]["count"] == 4
    assert st["entropy_codecs"]["device-pack"]["bytes"] == \
        sum(len(a.base_payload) for a in arts)


def test_device_pack_batches_sanitized_end_to_end(monkeypatch):
    """The full DESIGN.md §8 claim in one test: a device-pack batch does
    ZERO host entropy work (no worker-pool jobs) AND makes zero
    unexpected host<->device crossings. With ``MSZ_SANITIZERS=1`` the
    scheduler's device stage runs inside ``debug.no_transfers`` — any
    implicit sync would fail the batch — and the ``_transfer_hook``
    count proves the only crossings are the explicit batch-sized seams,
    one each way."""
    fields, xis = _traffic(SHAPE_3D, 4)
    refs = [compress_preserving_mss(f, xi, entropy="device-pack")
            for f, xi in zip(fields, xis)]
    with CompressStream(window=4, max_batch=4, linger_ms=50) as cs:
        # warm-up batch with sanitizers off: first dispatch compiles,
        # and compilation itself may legitimately transfer constants
        [f.result() for f in
         [cs.submit(f, xi, entropy="device-pack")
          for f, xi in zip(fields, xis)]]
        monkeypatch.setenv("MSZ_SANITIZERS", "1")
        log = []
        monkeypatch.setattr(pipeline, "_transfer_hook",
                            lambda d, n: log.append((d, n)))
        jobs = _record_pool(cs)
        futs = [cs.submit(f, xi, entropy="device-pack")
                for f, xi in zip(fields, xis)]
        arts = [f.result() for f in futs]   # raises if the guard fired
    assert jobs == [], f"worker pool saw {jobs} for device-pack traffic"
    _assert_identical(arts, refs)
    batch_bytes = sum(f.nbytes for f in fields)
    assert sum(1 for d, n in log if d == "h2d" and n >= batch_bytes) == 1, log
    # the return traffic is the framed entropy stream, which left the
    # device already compressed: nothing raw-batch-sized crosses back
    assert all(n < batch_bytes for d, n in log if d == "d2h"), log


def test_deflate_compress_still_uses_worker_pool():
    fields, xis, refs = _solo_artifacts(SHAPE_3D, 3)
    with CompressStream(window=3, max_batch=3, linger_ms=50) as cs:
        jobs = _record_pool(cs)
        arts = cs.map(fields, xis)
        st = cs.stats()
    assert "_finish_compress" in jobs   # deflate encode runs on workers
    _assert_identical(arts, refs)
    assert st["entropy_codecs"]["deflate"]["count"] == 3


def test_entropy_is_part_of_the_coalescing_spec():
    """Mixed-codec traffic of one shape must not share a batch — a
    device-pack member inside a deflate batch (or vice versa) would
    force a whole-batch codec decision."""
    fields, xis = _traffic(SHAPE_3D, 4)
    with CompressStream(window=4, max_batch=4, linger_ms=60) as cs:
        futs = [cs.submit(f, xi, entropy=e)
                for (f, xi, e) in zip(fields, xis,
                                      ["deflate", "device-pack"] * 2)]
        arts = [f.result() for f in futs]
        st = cs.stats()
    assert st["batches"] >= 2           # codecs split the batch
    for a, e in zip(arts, ["deflate", "device-pack"] * 2):
        assert a.entropy == e
        ref = compress_preserving_mss(
            fields[arts.index(a)], xis[arts.index(a)], entropy=e)
        assert a.base_payload == ref.base_payload


def test_device_pack_decompress_runs_inline():
    fields, xis = _traffic(SHAPE_3D, 3)
    arts = [compress_preserving_mss(f, xi, entropy="device-pack")
            for f, xi in zip(fields, xis)]
    want = [decompress_preserving_mss(a) for a in arts]
    with DecompressStream(window=3, max_batch=3, linger_ms=50) as ds:
        jobs = _record_pool(ds)
        gs = ds.map(arts)
        st = ds.stats()
    assert jobs == [], f"worker pool saw {jobs} for device-pack artifacts"
    for g, w in zip(gs, want):
        np.testing.assert_array_equal(g, w)
    assert st["entropy_codecs"]["device-pack"]["count"] == 3


def test_stream_submit_rejects_bad_entropy():
    f, xis = _traffic(SHAPE_3D, 1)
    with CompressStream(window=1) as cs:
        with pytest.raises(ValueError, match="entropy"):
            cs.submit(f[0], xis[0], entropy="huffman")
        with pytest.raises(ValueError, match="szlike"):
            cs.submit(f[0], xis[0], base="zfplike", entropy="device-pack")


def test_service_forwards_entropy_and_reports_codecs():
    fields, xis = _traffic(SHAPE_3D, 2)
    ref = compress_preserving_mss(fields[0], xis[0], entropy="device-pack")
    with CompressionService(ServiceConfig(window=4, max_batch=2)) as svc:
        a = svc.compress(fields[0], xis[0], entropy="device-pack")
        b = svc.compress(fields[1], xis[1])            # default: deflate
        g = svc.decompress(a)
        st = svc.stats()
    assert a.base_payload == ref.base_payload
    assert b.entropy == "deflate"
    np.testing.assert_array_equal(g, decompress_preserving_mss(ref))
    assert st["compress"]["entropy_codecs"]["device-pack"]["count"] == 1
    assert st["compress"]["entropy_codecs"]["deflate"]["count"] == 1
    assert st["decompress"]["entropy_codecs"]["device-pack"]["count"] == 1


# ---------------------------------------------------------------------------
# SpecCache build race (one winner per key)
# ---------------------------------------------------------------------------

def test_spec_cache_build_race_single_winner():
    """Concurrent misses of one key must converge on ONE cached instance:
    the old code re-inserted every racer's build unconditionally, so the
    loser's instance replaced the winner's and callers ended up holding
    two distinct backends for one spec (churning jit cache keys). The
    losing build is counted as a hit — the caller got the cached value."""
    import threading

    cache = SpecCache(8)
    n = 6
    barrier = threading.Barrier(n)
    built = []

    def build():
        barrier.wait()          # every thread reaches its miss before
        obj = object()          # anyone can insert: maximal race
        built.append(obj)
        return obj

    results = [None] * n

    def worker(i):
        results[i] = cache.get("spec", build)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(built) == n                     # everyone built (raced)...
    assert len({id(r) for r in results}) == 1  # ...but all hold ONE winner
    st = cache.stats()
    assert st["misses"] == 1                   # one true miss
    assert st["hits"] == n - 1                 # losers reclassified as hits
    assert st["size"] == 1
    assert cache.get("spec", lambda: object()) is results[0]
