"""Table 3 (beyond-paper): decompression-path throughput — the host
byte-codec loop vs the device-resident decode (on-device reconstruct +
edit scatter, DESIGN.md §5), fields/sec vs batch size vs device count.

The read path is what serves traffic at scale (ROADMAP north star), so
this table answers the deployment question TopoSZp poses: is the
topology-corrected decode light enough to serve from? Artifacts are
synthesized directly (base blob + a sparse random edit stream) so the
table measures DECOMPRESSION only, independent of fix-loop cost; a
one-time bitwise cross-check of host vs device output guards the
parity contract while the clock runs.
"""
from __future__ import annotations

import time

import numpy as np

from repro.compress import decompress_artifact, decompress_artifact_batch
from repro.compress import codec, szlike
from repro.compress.pipeline import CompressedArtifact
from repro.data import synthetic_field

from .common import emit


def _synthetic_artifact(f: np.ndarray, xi: float, edit_frac: float = 0.002,
                        seed: int = 0) -> CompressedArtifact:
    """An szlike artifact with a plausible sparse edit stream; decode cost
    does not depend on how the edits were derived, so the fix loop is
    skipped (it would dominate setup at 256^3)."""
    payload = szlike.sz_compress(f, xi)
    rng = np.random.default_rng(seed)
    n = max(1, int(edit_frac * f.size))
    idx = np.sort(rng.choice(f.size, size=n, replace=False)).astype(np.int64)
    val = (0.5 * xi * rng.standard_normal(n)).astype(np.float32)
    return CompressedArtifact(
        base="szlike", base_payload=payload,
        edit_payload=codec.encode_edits(idx, val),
        shape=f.shape, dtype=str(f.dtype), xi=xi)


def _time_fields_per_sec(fn, n_fields: int, iters: int) -> float:
    fn()                                    # warmup (jit compile)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return n_fields / times[len(times) // 2]


def run(quick: bool = True):
    import jax

    side = 32 if quick else 256
    batches = (1, 4) if quick else (1, 4, 16)
    iters = 3 if quick else 5
    f = synthetic_field("nyx", shape=(side,) * 3).astype(np.float32)
    xi = 1e-3 * float(np.ptp(f))
    art = _synthetic_artifact(f, xi)

    # parity guard: the numbers below only count if both paths agree
    np.testing.assert_array_equal(
        decompress_artifact(art),
        decompress_artifact_batch([art], device_path=True)[0])

    for B in batches:
        arts = [art] * B
        host = _time_fields_per_sec(
            lambda: [decompress_artifact(a) for a in arts], B, iters)
        emit(f"table3/{side}^3/host/B={B}", 1e6 * B / host,
             f"fields_per_sec={host:.2f};path=host")
        dev = _time_fields_per_sec(
            lambda: decompress_artifact_batch(arts, device_path=True),
            B, iters)
        emit(f"table3/{side}^3/device/B={B}", 1e6 * B / dev,
             f"fields_per_sec={dev:.2f};path=device;"
             f"speedup={dev / host:.2f}x")

    n_avail = len(jax.devices())
    if n_avail >= 2:
        from repro.launch.mesh import make_data_mesh
        B = batches[-1]
        arts = [art] * B
        for n_dev in (2, 4, 8):
            if n_dev > n_avail:
                break
            mesh = make_data_mesh(n_dev)
            sh = _time_fields_per_sec(
                lambda: decompress_artifact_batch(
                    arts, device_path=True, backend="sharded", mesh=mesh),
                B, iters)
            emit(f"table3/{side}^3/sharded/B={B}/devices={n_dev}",
                 1e6 * B / sh, f"fields_per_sec={sh:.2f};path=device")


if __name__ == "__main__":
    run()
