"""Table 4 (beyond-paper): streaming-service throughput — the sequential
per-field loop vs the double-buffered stream scheduler
(repro.compress.stream, DESIGN.md §6), fields/sec vs in-flight window vs
batch size vs device count.

This is the table behind the ROADMAP's serving north star: pMSz frames
fields as a *stream* of timesteps/ensemble members, and the question
that decides deployability is whether overlapping host entropy coding,
transfers, and the batched device fix loop beats calling the one-shot
pipeline per field. Artifacts are checked byte-identical to the one-shot
path while the clock runs, so every row measures the same computation.

Quick mode uses tiny fields (the CI smoke leg); ``--full`` runs the
acceptance configuration — >= 4 in-flight 128^3 f32 fields on one device
and, when emulated devices are available (``XLA_FLAGS=
--xla_force_host_platform_device_count=8`` before jax initializes), on
the 8-device ('data',) mesh.

  PYTHONPATH=src python -m benchmarks.table4_stream --smoke
  PYTHONPATH=src python -m benchmarks.run --only table4
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import numpy as np

from repro.compress import (CompressStream, DecompressStream,
                            compress_preserving_mss,
                            decompress_preserving_mss)
from repro.data import synthetic_field
from repro.launch.mesh import make_data_mesh

from .common import emit


def _traffic(n: int, shape: Tuple[int, ...], xi_rel: float = 1e-3
             ) -> Tuple[List[np.ndarray], List[float]]:
    """n same-shape f32 fields (a synthetic timestep stream) + bounds."""
    fields = [synthetic_field("nyx", shape=shape, seed=s).astype(np.float32)
              for s in range(n)]
    return fields, [xi_rel * float(np.ptp(f)) for f in fields]


def _check_identical(arts, ref_arts) -> None:
    for a, r in zip(arts, ref_arts):
        assert a.base_payload == r.base_payload \
            and a.edit_payload == r.edit_payload, \
            "stream artifact differs from the one-shot path"


def _bench_device_count(fields, xis, n_dev: Optional[int], window: int,
                        max_batch: int, iters: int):
    """One (device count, window, batch) cell: sequential baseline,
    stream compress, stream decompress — byte-identity enforced."""
    mesh = make_data_mesh(n_dev) if n_dev and n_dev > 1 else None
    tag = f"ndev={n_dev or 1}"
    n = len(fields)

    # sequential per-field baseline (the pre-§6 serving loop)
    ref_arts = [compress_preserving_mss(f, xi, mesh=mesh)
                for f, xi in zip(fields, xis)]          # warmup + reference
    t_seq = []
    for _ in range(iters):
        t0 = time.perf_counter()
        ref_arts = [compress_preserving_mss(f, xi, mesh=mesh)
                    for f, xi in zip(fields, xis)]
        t_seq.append(time.perf_counter() - t0)
    fps_seq = n / sorted(t_seq)[len(t_seq) // 2]
    emit(f"table4/compress/sequential/{tag}",
         sorted(t_seq)[len(t_seq) // 2] / n * 1e6, f"fields_s={fps_seq:.3f}")

    def stream_pass():
        with CompressStream(window=window, max_batch=max_batch,
                            mesh=mesh) as cs:
            arts = cs.map(fields, xis)
            occ = cs.stats()["batch_occupancy"]
        return arts, occ

    arts, _ = stream_pass()                             # warmup (batch jit)
    _check_identical(arts, ref_arts)
    t_str = []
    occ = 0.0
    for _ in range(iters):
        t0 = time.perf_counter()
        arts, occ = stream_pass()
        t_str.append(time.perf_counter() - t0)
    fps_str = n / sorted(t_str)[len(t_str) // 2]
    emit(f"table4/compress/stream/w{window}_b{max_batch}/{tag}",
         sorted(t_str)[len(t_str) // 2] / n * 1e6,
         f"fields_s={fps_str:.3f} speedup={fps_str / fps_seq:.2f} "
         f"occupancy={occ:.2f}")

    # read side: sequential one-shot decode vs the decompress stream
    gs_ref = [decompress_preserving_mss(a, mesh=mesh) for a in ref_arts]
    t0 = time.perf_counter()
    gs_ref = [decompress_preserving_mss(a, mesh=mesh) for a in ref_arts]
    fps_dseq = n / (time.perf_counter() - t0)
    with DecompressStream(window=window, max_batch=max_batch,
                          mesh=mesh) as ds:
        ds.map(ref_arts)                                # warmup
    t0 = time.perf_counter()
    with DecompressStream(window=window, max_batch=max_batch,
                          mesh=mesh) as ds:
        gs = ds.map(ref_arts)
    fps_dstr = n / (time.perf_counter() - t0)
    for g, gr in zip(gs, gs_ref):
        assert np.array_equal(g, gr), "stream decode differs from one-shot"
    emit(f"table4/decompress/stream/w{window}_b{max_batch}/{tag}",
         1e6 / fps_dstr, f"fields_s={fps_dstr:.3f} "
         f"speedup={fps_dstr / fps_dseq:.2f}")
    return fps_seq, fps_str


def run(quick: bool = True):
    import jax

    shape = (16, 16, 16) if quick else (128, 128, 128)
    n_fields = 8
    iters = 1 if quick else 2
    # (window, max_batch): the window axis is what buys cross-batch
    # pipelining — entropy coding of batch k overlaps batch k+1's device
    # stage only when the window holds more than one batch
    cells = ((4, 4), (8, 4)) if quick else ((4, 1), (4, 4), (8, 4), (8, 8))
    fields, xis = _traffic(n_fields, shape)

    n_avail = len(jax.devices())
    device_counts = [None] + [n for n in (8,) if n <= n_avail]
    for n_dev in device_counts:
        for window, max_batch in cells:
            _bench_device_count(fields, xis, n_dev, window, max_batch, iters)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fields, one repetition (the CI leg)")
    ap.add_argument("--full", action="store_true",
                    help="acceptance configuration: 128^3 f32 fields")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full)
