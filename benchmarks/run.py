"""Benchmark driver — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  PYTHONPATH=src python -m benchmarks.run            # quick mode
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sizes
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes to run")
    args = ap.parse_args()
    quick = not args.full

    from . import (fig1_label_distortion, table1_components, table2_overhead,
                   table3_decompress, table4_stream, table5_fixloop,
                   table6_entropy, table7_preserve, fig7_fixed_bound,
                   fig8_fixed_bitrate, fig9_scaling, fig11_convergence)
    modules = {
        "fig1": fig1_label_distortion,
        "table1": table1_components,
        "table2": table2_overhead,
        "table3": table3_decompress,
        "table4": table4_stream,
        "table5": table5_fixloop,   # also writes BENCH_fixloop.json
        "table6": table6_entropy,   # also writes BENCH_entropy.json
        "table7": table7_preserve,  # also writes BENCH_preserve.json
        "fig7": fig7_fixed_bound,
        "fig8": fig8_fixed_bitrate,
        "fig9": fig9_scaling,
        "fig11": fig11_convergence,
    }
    selected = (args.only.split(",") if args.only else list(modules))
    print("name,us_per_call,derived")
    failures = []
    for key in selected:
        mod = modules[key]
        t0 = time.time()
        try:
            mod.run(quick=quick)
            print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures.append(key)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark modules failed: {failures}")


if __name__ == "__main__":
    main()
