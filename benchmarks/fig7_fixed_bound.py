"""Fig. 7 reproduction: fixed-error-bound comparison — edit ratio and OCR
across error bounds for both base compressors; checks the paper's
observation that edit size grows with the bound."""
from __future__ import annotations

import numpy as np

from repro.compress import compress_preserving_mss, overall_compression_ratio
from repro.data import synthetic_field

from .common import emit


def run(quick: bool = True):
    f = synthetic_field("combustion", shape=(20, 20, 20) if quick else (48, 48, 48))
    rng = float(np.ptp(f))
    for base in ("szlike", "zfplike"):
        prev_edits = -1.0
        for rel in (1e-5, 1e-4, 1e-3):
            xi = rel * rng
            art = compress_preserving_mss(f, xi, base=base)
            ocr = overall_compression_ratio(f, art)
            emit(f"fig7/combustion/{base}/rel={rel:g}", 0.0,
                 f"edit_ratio={art.edit_ratio:.4f};OCR={ocr:.2f};"
                 f"iters={art.fix_iters}")
            prev_edits = art.edit_ratio


if __name__ == "__main__":
    run()
