"""Shared benchmark utilities: timing harness + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def base_transform_closure(be, fj, step) -> Callable[[], None]:
    """The device base stage as one timeable unit: quantize+Lorenzo
    forward (``be.transform``) then cumsum inverse (``be.reconstruct``),
    synced. Shared by table1/fig9 so every backend row measures the same
    dispatch."""
    import jax

    def go():
        r = be.transform(fj, step)
        jax.block_until_ready(be.reconstruct(r, step, fj.dtype))

    return go


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def flush_rows():
    ROWS.clear()
