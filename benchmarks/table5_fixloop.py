"""Table 5 (beyond-paper): fix-loop execution strategies — the batched
``B*max(iters)`` while_loop vs active-member compaction vs per-member
pipelined loops, plus the dirty-slab worklist and a fix-loop roofline.

The batched fix loop (PR 3/4's ``fused_fix_batch``) holds every member
until the slowest converges; on mixed-convergence traffic (one member a
no-op, another a straggler) that is the dominating tax this table
quantifies. Three strategies over the SAME mixed batch, all verified
bitwise identical to solo per-member ``fused_fix`` while the clock runs:

* ``fused``      — the legacy single vmapped while_loop (B*max cost);
* ``compact``    — the PR-6 driver: converged members retire from the
  vmap every ``compact_every`` iterations via pow2-bucket compaction;
* ``pipelined``  — B solo loops (sum(iters) steps, B dispatches).

The worklist section runs the slab-tiled Pallas path on a field whose
violations are confined to a few interior slabs and reports how many
slab-group stencil launches the dirty-slab bitmap skipped (bitwise
identity against the dense pallas loop enforced). The roofline section
models the fix iteration's memory traffic (bytes/voxel/iteration) and
compares the measured per-iteration time against the machine's measured
copy bandwidth — the bound a perfectly memory-bound fix step would hit.

Results land in ``BENCH_fixloop.json`` (the repo's first perf-trajectory
artifact) as well as the usual CSV rows. ``--check-regression`` makes
the process fail when the compacted driver is slower than the legacy
fused driver on the benchmarked shapes — the CI guard for this PR's
core claim.

  PYTHONPATH=src python -m benchmarks.table5_fixloop --smoke --check-regression
  PYTHONPATH=src python -m benchmarks.run --only table5
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from .common import emit

OUT_JSON = "BENCH_fixloop.json"
#: modeled fix-iteration traffic per voxel: g read by the extrema pass
#: and the fix pass, g written once (3 float32 accesses), plus the five
#: int32 stencil masks written then read back (10 int32 accesses)
BYTES_PER_VOXEL_ITER = 3 * 4 + 10 * 4


def _median_s(fn, reps: int = 3) -> float:
    """Median wall seconds over ``reps`` calls after one warm-up (the
    warm-up absorbs trace+compile so rows time steady-state dispatch)."""
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def _mixed_batch(B: int, shape: Tuple[int, ...], xi: float = 0.05,
                 seed: int = 7) -> Tuple[np.ndarray, np.ndarray]:
    """A (B, *shape) mixed-convergence batch: one smooth base field; the
    first 3/4 of the members carry at most a couple of isolated voxel
    bumps (they converge in 1-2 iterations), the rest carry dense
    near-bound noise (an order of magnitude more iterations). This is
    the traffic shape that makes the ``B*max(iters)`` tax visible: the
    bulk retires in the first compaction round, the stragglers keep only
    a narrow vmap bucket busy."""
    rng = np.random.default_rng(seed)
    axes = np.meshgrid(*[np.linspace(0, 1, s) for s in shape], indexing="ij")
    f = np.sin(4 * axes[0]) * np.cos(3 * axes[1])
    for a in axes[2:]:
        f = f + 0.5 * a
    f = f.astype(np.float32)
    n_fast = max((3 * B) // 4, min(B, 1))
    members = []
    for i in range(B):
        if i < n_fast:
            fh = f.reshape(-1).copy()
            idx = rng.choice(f.size, i % 3, replace=False)   # 0-2 bumps
            np.add.at(fh, idx, 0.9 * xi * rng.choice([-1.0, 1.0], idx.size))
            members.append(fh.reshape(shape))
        else:
            members.append(f + 0.99 * xi * rng.uniform(-1, 1, shape))
    fh = np.stack(members).astype(np.float32)
    return np.broadcast_to(f, fh.shape).astype(np.float32), fh


def bench_batch(quick: bool) -> Dict[str, object]:
    """The three strategies on one mixed-convergence batch, byte-
    identity enforced against solo per-member loops."""
    import jax
    import jax.numpy as jnp

    from repro.core import fixes
    from repro.core.backend import get_backend

    B = 8 if quick else 16
    shape = (16, 16, 16) if quick else (32, 32, 32)
    xi = 0.05
    f, fh = _mixed_batch(B, shape, xi=xi)
    be = get_backend("reference")   # the vmap-native stencils: all three
    #                                 strategies dispatch the same kernels
    topos = [fixes.field_topology(jnp.asarray(f[i]), xi) for i in range(B)]
    topo_b = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *topos)
    fh_j = jnp.asarray(fh)

    # solo reference: the bitwise ground truth and the iteration counts
    solo = [fixes.fused_fix(fh_j[i], topos[i], backend=be) for i in range(B)]
    g_ref = np.stack([np.asarray(g) for g, _, _ in solo])
    iters = [int(it) for _, it, _ in solo]
    spread = max(iters) / max(min(iters), 1)
    assert spread >= 8, \
        f"benchmark batch lost its iteration spread: {iters} ({spread:.1f}x)"
    iters_saved = B * max(iters) - sum(iters)

    def run_mode(batching):
        def go():
            # compact_every=2 retires the fast bulk after one round even
            # when its members need a couple of iterations each
            g, it, ok = fixes.fused_fix_batch(fh_j, topo_b, backend=be,
                                              batching=batching,
                                              compact_every=2)
            jax.block_until_ready(g)
            return g, it, ok
        return go

    def run_pipelined():
        gs = [fixes.fused_fix(fh_j[i], topos[i], backend=be)[0]
              for i in range(B)]
        jax.block_until_ready(gs)
        return gs

    results = {}
    for mode in ("fused", "compact"):
        g, it, ok = run_mode(mode)()
        assert np.array_equal(np.asarray(g), g_ref), f"{mode} != solo"
        assert [int(x) for x in np.asarray(it)] == iters, f"{mode} iters"
        results[mode] = _median_s(run_mode(mode))
    gs = run_pipelined()
    assert np.array_equal(np.stack([np.asarray(g) for g in gs]), g_ref)
    results["pipelined"] = _median_s(run_pipelined)

    fps = {k: B / t for k, t in results.items()}
    speedup = fps["compact"] / fps["fused"]
    for k in ("fused", "compact", "pipelined"):
        emit(f"table5/batch/{k}/B{B}_{'x'.join(map(str, shape))}",
             results[k] / B * 1e6,
             f"fields_s={fps[k]:.2f}" + (
                 f" speedup_vs_fused={speedup:.2f}" if k == "compact" else ""))
    return dict(B=B, shape=list(shape), iters=iters,
                iters_spread=round(spread, 2), iters_saved=iters_saved,
                t_s={k: round(v, 6) for k, v in results.items()},
                fields_per_sec={k: round(v, 3) for k, v in fps.items()},
                speedup_compact_vs_fused=round(speedup, 3))


def bench_worklist(quick: bool) -> Dict[str, object]:
    """Dirty-slab worklist vs the dense slab sweep on a field whose
    violations live in a few interior slabs — the skip counts this PR's
    acceptance requires to be nonzero."""
    import jax.numpy as jnp

    from repro.core import fixes

    shape = (48, 12, 12) if quick else (96, 32, 32)
    xi = 0.05
    rng = np.random.default_rng(3)
    axes = np.meshgrid(*[np.linspace(0, 1, s) for s in shape], indexing="ij")
    f = (np.sin(3 * axes[0]) + 0.5 * axes[1] + 0.25 * axes[2]) \
        .astype(np.float32)
    fh = f.copy()
    mid = shape[0] // 2
    fh[mid - 3:mid + 3] += (0.9 * xi * rng.uniform(
        -1, 1, (6,) + shape[1:])).astype(np.float32)

    topo = fixes.field_topology(jnp.asarray(f), xi)
    fh_j = jnp.asarray(fh)

    def dense():
        import jax
        out = fixes.fused_fix(fh_j, topo, backend="pallas")
        jax.block_until_ready(out[0])
        return out

    def worklist():
        import jax
        out = fixes.fused_fix_worklist(fh_j, topo, backend="pallas_worklist")
        jax.block_until_ready(out[0])
        return out

    g_d, it_d, _ = dense()
    g_w, it_w, _, skipped = worklist()
    assert np.array_equal(np.asarray(g_w), np.asarray(g_d)), \
        "worklist != dense pallas"
    assert int(it_w) == int(it_d)
    skipped = int(skipped)
    total = shape[0] * int(it_w)
    t_d, t_w = _median_s(dense), _median_s(worklist)
    emit(f"table5/worklist/{'x'.join(map(str, shape))}", t_w * 1e6,
         f"dense_us={t_d * 1e6:.1f} skipped={skipped}/{total} "
         f"iters={int(it_w)}")
    return dict(shape=list(shape), iters=int(it_w), slabs_skipped=skipped,
                slab_passes_total=total,
                skip_frac=round(skipped / total, 3),
                t_dense_s=round(t_d, 6), t_worklist_s=round(t_w, 6))


def bench_roofline(quick: bool) -> Dict[str, object]:
    """Fix-loop roofline: modeled bytes per iteration against measured
    copy bandwidth — how far the measured per-iteration time sits above
    the memory-bound floor (CPU interpret-mode stencils sit far above
    it; a lowered GPU/TPU path is what closes the gap)."""
    import jax
    import jax.numpy as jnp

    from repro.core import fixes

    shape = (12, 12, 12) if quick else (64, 64, 64)
    V = int(np.prod(shape))
    f, fh = _mixed_batch(1, shape)
    topo = fixes.field_topology(jnp.asarray(f[0]), 0.05)
    fh_j = jnp.asarray(fh[0])
    _, it, _ = fixes.fused_fix(fh_j, topo, backend="reference")
    iters = max(int(it), 1)

    def run():
        jax.block_until_ready(
            fixes.fused_fix(fh_j, topo, backend="reference")[0])

    t_loop = _median_s(run)
    us_per_iter = t_loop / iters * 1e6

    # measured streaming bandwidth: an elementwise add reads + writes the
    # buffer once each (2 accesses); size matched to the probe field
    x = jnp.asarray(np.zeros(max(V, 1 << 16), np.float32))
    add = jax.jit(lambda a: a + 1.0)

    def copy():
        jax.block_until_ready(add(x))

    bw = 2 * x.nbytes / _median_s(copy, reps=5)
    bound_us = V * BYTES_PER_VOXEL_ITER / bw * 1e6
    frac = bound_us / us_per_iter if us_per_iter else 0.0
    emit(f"table5/roofline/{'x'.join(map(str, shape))}", us_per_iter,
         f"bound_us={bound_us:.2f} bw_gbs={bw / 1e9:.1f} "
         f"frac_of_bound={frac:.4f}")
    return dict(shape=list(shape), iters=iters,
                bytes_per_voxel_iter=BYTES_PER_VOXEL_ITER,
                copy_bw_gbs=round(bw / 1e9, 2),
                measured_us_per_iter=round(us_per_iter, 2),
                bound_us_per_iter=round(bound_us, 3),
                frac_of_bound=round(frac, 5))


def run(quick: bool = True, check_regression: bool = False,
        out: str = OUT_JSON) -> Dict[str, object]:
    """All three sections; writes ``out`` (default BENCH_fixloop.json in
    the working directory) and, with ``check_regression``, raises when
    the compacted driver fails to at least match the legacy fused one."""
    import jax

    doc = dict(schema="msz-bench-fixloop/1", quick=bool(quick),
               jax_backend=jax.default_backend(),
               batch=bench_batch(quick),
               worklist=bench_worklist(quick),
               roofline=bench_roofline(quick))
    Path(out).write_text(json.dumps(doc, indent=2) + "\n")
    if check_regression:
        sp = doc["batch"]["speedup_compact_vs_fused"]
        if sp < 0.98:        # 2% grace for timer noise; compaction must
            #                  never lose to the B*max(iters) driver
            raise SystemExit(
                f"regression: compacted driver is slower than the fused "
                f"driver (speedup {sp:.2f}x < 0.98x); see {out}")
        if doc["worklist"]["slabs_skipped"] <= 0:
            raise SystemExit(
                "regression: dirty-slab worklist skipped zero slab "
                "passes on a localized-violation field")
    return doc


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fields, the CI leg (default)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes")
    ap.add_argument("--check-regression", action="store_true",
                    help="fail when compaction loses to the fused driver")
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, check_regression=args.check_regression,
        out=args.out)
