"""Table 6 (beyond-paper): residual entropy coding — host DEFLATE vs the
on-device chunked bitplane packer (DESIGN.md §8).

The device path's last host dependency was the residual entropy stage:
every compressed member paid a d2h copy of its full int32 code array
plus a worker-thread ``zlib.compress``. The device-pack codec builds the
framed byte stream ON the accelerator (per-chunk bit widths, plane-major
bitplane transpose, prefix-sum compaction) so only the packed words —
typically 3-10x fewer bytes than the raw codes — cross the link, and the
host does pure header assembly. This table quantifies the trade on one
shape sweep:

* encode/decode wall time per field for both codecs (pipeline-level,
  device path, steady state);
* payload bytes per codec (the ratio CI guards: device-pack may trade
  ratio for speed, but never more than ``MAX_SIZE_RATIO``x DEFLATE);
* the d2h byte reduction the packed stream buys.

Every timed artifact pair is cross-checked: both codecs must decompress
to the IDENTICAL array (the clock never runs on unverified work).
Results land in ``BENCH_entropy.json`` plus the usual CSV rows.

  PYTHONPATH=src python -m benchmarks.table6_entropy --smoke --check-regression
  PYTHONPATH=src python -m benchmarks.run --only table6
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from .common import emit

OUT_JSON = "BENCH_entropy.json"
#: CI guard: device-pack payloads may give up at most this factor vs
#: DEFLATE on the benchmarked fields (it usually wins on smooth data —
#: the bound only catches a broken bit-width or framing regression)
MAX_SIZE_RATIO = 1.35


def _median_s(fn, reps: int = 3) -> float:
    """Median wall seconds over ``reps`` calls after one warm-up."""
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def bench_shape(shape, xi_rel: float = 1e-3) -> Dict[str, object]:
    """Both codecs through the device-path pipeline on one field."""
    from repro.compress import pipeline
    from repro.data import synthetic_field

    f = synthetic_field("nyx", shape=shape, seed=11).astype(np.float32)
    xi = xi_rel * float(np.ptp(f))
    tag = "x".join(map(str, shape))

    arts, t_enc, t_dec = {}, {}, {}
    for entropy in ("deflate", "device-pack"):
        def enc(entropy=entropy):
            return pipeline.compress_preserving_mss(
                f, xi, entropy=entropy, device_path=True)
        arts[entropy] = enc()
        t_enc[entropy] = _median_s(enc)

        def dec(entropy=entropy):
            return pipeline.decompress_preserving_mss(arts[entropy])
        t_dec[entropy] = _median_s(dec)

    # correctness gate: the codecs must reconstruct the identical field
    g_sz = pipeline.decompress_preserving_mss(arts["deflate"])
    g_dp = pipeline.decompress_preserving_mss(arts["device-pack"])
    assert np.array_equal(g_sz, g_dp), f"codec cross-decode mismatch @ {tag}"

    size = {k: len(a.base_payload) for k, a in arts.items()}
    ratio = size["device-pack"] / max(size["deflate"], 1)
    raw_codes = 4 * f.size          # the d2h the packed stream replaces
    for k in ("deflate", "device-pack"):
        emit(f"table6/encode/{k}/{tag}", t_enc[k] * 1e6,
             f"payload_B={size[k]}" + (
                 f" size_vs_deflate={ratio:.3f}" if k == "device-pack"
                 else ""))
        emit(f"table6/decode/{k}/{tag}", t_dec[k] * 1e6, "")
    return dict(shape=list(shape), xi=xi,
                payload_bytes=size,
                size_ratio_pack_vs_deflate=round(ratio, 4),
                raw_code_bytes=raw_codes,
                d2h_reduction_vs_raw=round(raw_codes / max(
                    size["device-pack"], 1), 2),
                t_encode_s={k: round(v, 6) for k, v in t_enc.items()},
                t_decode_s={k: round(v, 6) for k, v in t_dec.items()})


def bench_kernel(quick: bool) -> Dict[str, object]:
    """The raw pack/unpack kernels (no pipeline around them): device
    codec vs the numpy host mirror vs ``zlib.compress`` on the same
    residual codes, bit-identity of the framed stream enforced."""
    import zlib

    import jax
    import jax.numpy as jnp

    from repro.kernels import pack

    n = 1 << (16 if quick else 22)
    rng = np.random.default_rng(5)
    # Laplacian-ish residuals: the distribution Lorenzo codes actually
    # have — mostly tiny, occasional wide outliers
    codes = np.round(rng.laplace(scale=3.0, size=n)).astype(np.int32)
    codes[:: max(n // 64, 1)] = rng.integers(-2**20, 2**20,
                                             size=codes[::max(n // 64,
                                                              1)].size)
    codes_j = jnp.asarray(codes)
    w_h, b_h = pack.pack_codes_host(codes)

    def dev_pack():
        w, b, nw = pack.pack_codes_jnp(codes_j)
        jax.block_until_ready(w)
        return w, b, nw

    w_d, b_d, nw = dev_pack()
    assert int(nw) == w_h.size
    assert np.array_equal(np.asarray(w_d)[:int(nw)], w_h)
    assert np.array_equal(np.asarray(b_d), b_h)

    t_dev = _median_s(dev_pack)
    t_host = _median_s(lambda: pack.pack_codes_host(codes))
    t_zlib = _median_s(lambda: zlib.compress(
        codes.astype("<i4").tobytes(), 6))
    packed_b = 4 * w_h.size + b_h.size
    zlib_b = len(zlib.compress(codes.astype("<i4").tobytes(), 6))
    emit(f"table6/kernel/pack_jnp/{n}", t_dev * 1e6,
         f"stream_B={packed_b} zlib_B={zlib_b}")
    emit(f"table6/kernel/pack_host/{n}", t_host * 1e6, "")
    emit(f"table6/kernel/zlib6/{n}", t_zlib * 1e6, "")
    return dict(n_codes=n, stream_bytes=packed_b, zlib_bytes=zlib_b,
                t_pack_jnp_s=round(t_dev, 6),
                t_pack_host_s=round(t_host, 6),
                t_zlib_s=round(t_zlib, 6))


def run(quick: bool = True, check_regression: bool = False,
        out: str = OUT_JSON) -> Dict[str, object]:
    """The shape sweep + kernel section; writes ``out`` (default
    BENCH_entropy.json) and, with ``check_regression``, raises when a
    device-pack payload exceeds ``MAX_SIZE_RATIO``x its DEFLATE twin."""
    import jax

    shapes = [(16, 16, 16), (24, 20, 16)] if quick else \
        [(64, 64, 64), (128, 64, 64), (96, 96, 96)]
    fields: List[Dict[str, object]] = [bench_shape(s) for s in shapes]
    doc = dict(schema="msz-bench-entropy/1", quick=bool(quick),
               jax_backend=jax.default_backend(),
               max_size_ratio=MAX_SIZE_RATIO,
               fields=fields,
               kernel=bench_kernel(quick))
    Path(out).write_text(json.dumps(doc, indent=2) + "\n")
    if check_regression:
        worst = max(f["size_ratio_pack_vs_deflate"] for f in fields)
        if worst > MAX_SIZE_RATIO:
            raise SystemExit(
                f"regression: device-pack payload is {worst:.2f}x DEFLATE "
                f"(> {MAX_SIZE_RATIO}x guard); see {out}")
    return doc


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fields, the CI leg (default)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes")
    ap.add_argument("--check-regression", action="store_true",
                    help="fail when device-pack payloads exceed "
                         f"{MAX_SIZE_RATIO}x DEFLATE")
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, check_regression=args.check_regression,
        out=args.out)
