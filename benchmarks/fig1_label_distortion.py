"""Fig. 1 reproduction: % of vertices with wrong MS segmentation labels in
SZ-like / ZFP-like decompressed data vs relative error bound — before any
correction. (The paper observes up to 100% distortion even at 1e-5.)"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.compress import sz_roundtrip, zfp_roundtrip
from repro.core import segmentation_accuracy
from repro.data import synthetic_field

from .common import emit, timeit


def run(quick: bool = True):
    f = synthetic_field("molecular", shape=(24, 24, 12) if quick else (48, 48, 24))
    rng = float(np.ptp(f))
    bounds = (1e-5, 1e-4, 1e-3, 1e-2)
    for name, rt in (("sz", sz_roundtrip), ("zfp", zfp_roundtrip)):
        for rel in bounds:
            xi = rel * rng
            fh, nbytes = rt(f, xi)
            acc = float(segmentation_accuracy(jnp.asarray(f), jnp.asarray(fh)))
            wrong = (1 - acc) * 100
            emit(f"fig1/{name}/rel={rel:g}", 0.0,
                 f"wrong_label_pct={wrong:.2f};ratio={f.nbytes/nbytes:.1f}")


if __name__ == "__main__":
    run()
