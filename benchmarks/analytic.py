"""Analytic FLOP/byte cost model per (arch x shape), used for the roofline
compute and memory terms.

Rationale (EXPERIMENTS.md §Roofline): XLA's cost_analysis() counts while-
loop bodies ONCE, so any scan-over-layers program under-reports flops and
bytes by ~the layer count. Rather than unrolling 94-layer models for the
dry-run (compile-time explosion), we use exact analytic matmul counts —
the same accounting used for MFU in PaLM/MaxText — and keep the measured
cost_analysis values as recorded lower bounds. Collective bytes come from
the HLO parse with the scan trip-count correction (entry + L x body).
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.models.config import ArchConfig, ShapeConfig, shape_by_name
from repro.models.model import window_schedule


def _attn_flops(cfg: ArchConfig, B: int, S: int, ctx_fn) -> float:
    """Projections + score/value matmuls. ctx_fn(window) -> avg context."""
    d, H, Hk, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2.0 * B * S * d * (H * Dh + 2 * Hk * Dh + H * Dh)
    sc = 0.0
    for w in window_schedule(cfg):
        ctx = ctx_fn(int(w))
        sc += 2.0 * B * S * ctx * H * Dh * 2        # qk^T and pV
    # proj applies per layer; sc already summed over layers
    return proj * cfg.n_layers + sc


def _ffn_flops(cfg: ArchConfig, B: int, S: int) -> float:
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.moe:
        per_tok = (2.0 * d * cfg.moe.n_experts                    # router
                   + cfg.moe.top_k * 3 * 2.0 * d * ff)            # experts
    elif cfg.enc_dec:
        per_tok = 2 * 2.0 * d * ff                                # GELU MLP
    elif ff:
        per_tok = 3 * 2.0 * d * ff                                # SwiGLU
    else:
        per_tok = 0.0
    return B * S * per_tok * cfg.n_layers


def _recurrent_flops(cfg: ArchConfig, B: int, S: int) -> float:
    d, H = cfg.d_model, cfg.n_heads
    if cfg.family == "ssm":
        Dh = d // H
        G = cfg.n_layers // (cfg.slstm_every or cfg.n_layers)
        n_m = cfg.n_layers - G
        # mLSTM: upproj 2d, qkv 3 d->d, down d->d, gates; chunk math
        m_proj = 2.0 * B * S * d * (2 * d + 3 * d + d + 2 * H)
        L = 256
        m_scan = B * S * (4.0 * H * Dh * Dh / 1 + 4.0 * L * H * Dh)
        s_proj = 2.0 * B * S * d * (4 * d + d)
        s_rec = 2.0 * B * S * H * Dh * 4 * Dh
        return n_m * (m_proj + m_scan) + G * (s_proj + s_rec)
    if cfg.family == "hybrid":
        N, Dh = cfg.ssm_state, cfg.head_dim
        proj = 2.0 * B * S * d * (H * Dh + H + 2 * H * N)
        scan = 6.0 * B * S * H * N * Dh
        return cfg.n_layers * (proj + scan)
    return 0.0


def _unembed_flops(cfg: ArchConfig, B: int, S: int) -> float:
    return 2.0 * B * S * cfg.d_model * cfg.vocab


def step_flops(arch: str, shape_name: str) -> float:
    """Total (all-chip) flops for one step of this cell's program."""
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    B = shape.global_batch

    if shape.kind in ("train", "prefill"):
        S = shape.seq_len
        if cfg.n_img_tokens:
            S = shape.seq_len            # image tokens included in S
        if cfg.family in ("ssm",):
            core = _recurrent_flops(cfg, B, S)
        elif cfg.family == "hybrid":
            ctx = lambda w: min(w, S) / 2 if w < (1 << 29) else S / 2
            core = (_attn_flops(cfg, B, S, ctx)
                    + _ffn_flops(cfg, B, S) + _recurrent_flops(cfg, B, S))
        elif cfg.enc_dec:
            Te = cfg.enc_positions
            enc = (_attn_flops_dec(cfg, B, Te, Te, cfg.n_enc_layers,
                                   causal=False)
                   + _ffn_flops_n(cfg, B, Te, cfg.n_enc_layers))
            dec = (_attn_flops_dec(cfg, B, S, S / 2, cfg.n_layers)
                   + _cross_flops(cfg, B, S, Te)
                   + _ffn_flops_n(cfg, B, S, cfg.n_layers))
            core = enc + dec
        else:
            ctx = lambda w: min(w, S / 2) if w < (1 << 29) else S / 2
            core = _attn_flops(cfg, B, S, ctx) + _ffn_flops(cfg, B, S)
        fwd = core + _unembed_flops(cfg, B, S if shape.kind == "train" else 1)
        return 3.0 * fwd if shape.kind == "train" else fwd

    # decode: one token against a T-long context
    T = shape.seq_len
    S = 1
    if cfg.family == "ssm":
        core = _recurrent_flops(cfg, B, S)
    elif cfg.family == "hybrid":
        ctx = lambda w: min(w, T) if w < (1 << 29) else T
        core = (_attn_flops(cfg, B, S, ctx) + _ffn_flops(cfg, B, S)
                + _recurrent_flops(cfg, B, S))
    elif cfg.enc_dec:
        core = (_attn_flops_dec(cfg, B, S, T, cfg.n_layers)
                + _cross_flops(cfg, B, S, cfg.enc_positions)
                + _ffn_flops_n(cfg, B, S, cfg.n_layers))
    else:
        ctx = lambda w: min(w, T) if w < (1 << 29) else T
        core = _attn_flops(cfg, B, S, ctx) + _ffn_flops(cfg, B, S)
    return core + _unembed_flops(cfg, B, 1)


def _attn_flops_dec(cfg, B, S, ctx, n_layers, causal=True):
    d, H, Hk, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2.0 * B * S * d * (2 * H * Dh + 2 * Hk * Dh)
    sc = 2.0 * B * S * ctx * H * Dh * 2
    return n_layers * (proj + sc)


def _cross_flops(cfg, B, S, Te):
    d, H, Hk, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2.0 * B * (S * d * 2 * H * Dh + Te * d * 2 * Hk * Dh)
    sc = 2.0 * B * S * Te * H * Dh * 2
    return cfg.n_layers * (proj + sc)


def _ffn_flops_n(cfg, B, S, n_layers):
    return B * S * 2 * 2.0 * cfg.d_model * cfg.d_ff * n_layers


# --- HBM traffic model ------------------------------------------------------

def step_bytes_per_device(arch: str, shape_name: str, chips: int,
                          tp: int = 16) -> float:
    """Approximate HBM bytes touched per device per step (lower bound)."""
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    P_total = cfg.n_params()
    dp = chips // tp

    if shape.kind == "train":
        p_dev = P_total / chips if P_total * 2 / tp > 4 * 2**30 \
            else P_total / tp                     # fsdp vs tp-only
        # bf16 params read fwd+bwd (+gathered copies), f32 grad w+r,
        # f32 m,v r+w, bf16 param write
        param_traffic = p_dev * (2 * 2 + 4 + 4 + 16 + 2)
        B_dev = shape.global_batch / dp
        act = (B_dev * shape.seq_len * cfg.d_model * 2
               * cfg.n_layers * 6)                # resid r/w + block io
        return param_traffic + act
    # inference: params read once + KV/state traffic
    p_dev = P_total / tp
    param_traffic = p_dev * 2
    if shape.kind == "prefill":
        B_dev = shape.global_batch / dp
        act = (B_dev * shape.seq_len * cfg.d_model * 2 * cfg.n_layers * 4)
        return param_traffic + act
    # decode: read the whole KV cache shard per step
    B_dev = max(shape.global_batch / dp, 1)
    kv = (2 * B_dev * shape.seq_len * cfg.n_kv_heads * cfg.head_dim
          * 2 * cfg.n_layers / tp) if cfg.family not in ("ssm",) else 0.0
    if cfg.family == "ssm":
        H, Dh = cfg.n_heads, cfg.d_model // cfg.n_heads
        kv = cfg.n_layers * B_dev * H * Dh * Dh * 4
    return param_traffic + kv
