"""Roofline analysis: read the dry-run JSONs (experiments/dryrun/*.json)
and derive the three roofline terms per (arch x shape x mesh):

  compute    = FLOPs_step  / (chips * 197e12 FLOP/s bf16)
  memory     = HBM_bytes   / (chips * 819e9  B/s)     [per-device model]
  collective = coll_bytes  / (4 links * 50e9 B/s)     [per-device, HLO]

FLOPs/bytes use the analytic per-arch cost model (benchmarks/analytic.py):
XLA cost_analysis counts scan bodies once, so its flops/bytes are recorded
as-is for reference but under-report layer loops (see EXPERIMENTS.md).
Collective bytes come from the optimized-HLO parse with the scan
trip-count correction: entry_bytes + n_layers * body_bytes.

Also reports MODEL_FLOPS (6*N*D train / 2*N*D inference; N_active for
MoE), the useful-compute ratio MODEL_FLOPS/FLOPs_step, and the structural
roofline fraction (MODEL_FLOPS time at peak) / (dominant term).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.configs import get_config
from repro.models.config import shape_by_name

from .analytic import step_flops, step_bytes_per_device

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
ICI_LINKS = 4                # links/chip usable for the collective mix


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    n = cfg.n_active_params() if cfg.moe else cfg.n_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "prefill"
                                   else 1)
    return 2.0 * n * tokens


def analyze(record: dict) -> dict:
    arch, shape_name = record["arch"], record["shape"]
    chips = 512 if record["multi_pod"] else 256
    cfg = get_config(arch)

    flops_total = step_flops(arch, shape_name)
    bytes_dev = step_bytes_per_device(arch, shape_name, chips)
    coll = record["collectives"]
    body = coll.get("body_bytes", 0)
    entry = coll.get("entry_bytes", coll["total_bytes"])
    coll_dev = entry + cfg.n_layers * body

    t_compute = flops_total / chips / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / (ICI_LINKS * ICI_BW)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(arch, shape_name)
    useful = mf / flops_total if flops_total > 0 else 0.0
    bound = max(terms.values())
    frac = (mf / chips / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": arch, "shape": shape_name, "chips": chips,
        **{k: round(v * 1e3, 4) for k, v in terms.items()},   # in ms
        "dominant": dom.replace("_s", ""),
        "useful_ratio": round(useful, 3),
        "roofline_frac": round(frac, 4),
        "hlo_flops_dev": record["cost"]["flops"],
        "hlo_bytes_dev": record["cost"]["bytes_accessed"],
        "coll_bytes_dev": coll_dev,
        "peak_gb": record["memory"]["peak_gb"],
        "compile_s": record.get("t_compile_s"),
        "status": record["status"],
    }


def load_rows(dry_dir: str = "experiments/dryrun"):
    rows = []
    for fn in sorted(Path(dry_dir).glob("*.json")):
        rec = json.loads(fn.read_text())
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "chips": 512 if rec["multi_pod"] else 256,
                         "status": rec.get("status"),
                         "reason": rec.get("reason",
                                           rec.get("error", ""))[:90]})
            continue
        rows.append(analyze(rec))
    return rows


def main(dry_dir: str = "experiments/dryrun"):
    rows = load_rows(dry_dir)
    hdr = ["arch", "shape", "chips", "compute_s", "memory_s", "collective_s",
           "dominant", "useful_ratio", "roofline_frac", "status"]
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in hdr))
    return rows


if __name__ == "__main__":
    main(*sys.argv[1:])
