"""Table 7 (beyond-paper): codec-agnostic preservation — the MSz
correction cost per base codec (DESIGN.md §11).

The PreservingCodec seam promises that edit derivation is independent of
the base compressor; this table quantifies what each codec actually PAYS
for topology preservation on the same fields:

* edit count / edit bytes — how much correction each codec's artifacts
  need (zfplike's block transform reconstructs smoother fields and
  historically needs ~10x FEWER edit bytes than szlike's Lorenzo
  predictor at the same bound);
* bit-rate overhead — edit bytes relative to the base payload, the
  price of exactness on the wire;
* fix iterations and wall time of the correction stage.

Every timed artifact is verified (``verify_preservation`` on the
decompressed field — the clock never runs on unverified work). Results
land in ``BENCH_preserve.json`` plus the usual CSV rows; the CI guard
catches a zfplike edit-stream regression (> ``MAX_EDIT_RATIO``x the
szlike edit bytes on the same field — generous: it sits near 0.1-0.2x
today, so tripping it means the block codec's bound accounting broke).

  PYTHONPATH=src python -m benchmarks.table7_preserve --smoke --check-regression
  PYTHONPATH=src python -m benchmarks.run --only table7
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from .common import emit

OUT_JSON = "BENCH_preserve.json"
#: CI guard: zfplike artifacts may carry at most this factor of the
#: szlike edit bytes on the benchmarked fields
MAX_EDIT_RATIO = 2.0
CODECS = ("szlike", "zfplike")


def _median_s(fn, reps: int = 3) -> float:
    """Median wall seconds over ``reps`` calls after one warm-up."""
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def bench_shape(shape, xi_rel: float = 1e-3) -> Dict[str, object]:
    """Both codecs through the preserving pipeline on one field."""
    from repro.compress import codec as edit_codec
    from repro.compress import pipeline
    from repro.core.driver import verify_preservation
    from repro.data import synthetic_field

    f = synthetic_field("nyx", shape=shape, seed=11).astype(np.float32)
    xi = xi_rel * float(np.ptp(f))
    tag = "x".join(map(str, shape))

    per_codec: Dict[str, Dict[str, object]] = {}
    for name in CODECS:
        def enc(name=name):
            return pipeline.compress_preserving_mss(f, xi, codec=name)
        art = enc()
        t_total = _median_s(enc)
        g = pipeline.decompress_artifact(art)
        v = verify_preservation(f, g, xi)
        assert v["mss_preserved"] and v["bound_ok"], (name, tag, v)
        idx, _ = edit_codec.decode_edits(art.edit_payload)
        edit_b = len(art.edit_payload)
        base_b = len(art.base_payload)
        per_codec[name] = dict(
            edit_count=int(idx.size),
            edit_ratio=round(art.edit_ratio, 6),
            edit_bytes=edit_b,
            base_bytes=base_b,
            bitrate_overhead=round(edit_b / max(base_b, 1), 4),
            obr_bits=round(pipeline.overall_bit_rate(f, art), 4),
            fix_iters=art.fix_iters,
            t_fix_s=round(art.t_fix, 6),
            t_total_s=round(t_total, 6),
        )
        emit(f"table7/compress/{name}/{tag}", t_total * 1e6,
             f"edits={idx.size} edit_B={edit_b} "
             f"overhead={per_codec[name]['bitrate_overhead']:.3f} "
             f"iters={art.fix_iters}")

    ratio = (per_codec["zfplike"]["edit_bytes"]
             / max(per_codec["szlike"]["edit_bytes"], 1))
    emit(f"table7/edit_ratio_zfp_vs_sz/{tag}", 0.0, f"ratio={ratio:.3f}")
    return dict(shape=list(shape), xi=xi, codecs=per_codec,
                edit_bytes_zfp_vs_sz=round(ratio, 4))


def run(quick: bool = True, check_regression: bool = False,
        out: str = OUT_JSON) -> Dict[str, object]:
    """The shape sweep; writes ``out`` (default BENCH_preserve.json)
    and, with ``check_regression``, raises when a zfplike edit stream
    exceeds ``MAX_EDIT_RATIO``x its szlike twin."""
    import jax

    shapes = [(16, 16, 16), (24, 20, 16)] if quick else \
        [(64, 64, 64), (128, 64, 64), (96, 96, 96)]
    fields: List[Dict[str, object]] = [bench_shape(s) for s in shapes]
    doc = dict(schema="msz-bench-preserve/1", quick=bool(quick),
               jax_backend=jax.default_backend(),
               max_edit_ratio=MAX_EDIT_RATIO,
               fields=fields)
    Path(out).write_text(json.dumps(doc, indent=2) + "\n")
    if check_regression:
        worst = max(f["edit_bytes_zfp_vs_sz"] for f in fields)
        if worst > MAX_EDIT_RATIO:
            raise SystemExit(
                f"regression: zfplike edit stream is {worst:.2f}x szlike "
                f"(> {MAX_EDIT_RATIO}x guard); see {out}")
    return doc


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fields, the CI leg (default)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes")
    ap.add_argument("--check-regression", action="store_true",
                    help="fail when zfplike edit streams exceed "
                         f"{MAX_EDIT_RATIO}x szlike")
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, check_regression=args.check_regression,
        out=args.out)
