"""Table 2 reproduction: computation/storage overhead of MSS-preserving
compression vs plain lossy (SZ-like/ZFP-like) and lossless (GZIP/ZSTD
stand-ins) across datasets and two error bounds."""
from __future__ import annotations

import numpy as np

from repro.compress import (compress_preserving_mss, gzip_like, zstd_like,
                            overall_compression_ratio)
from repro.data import synthetic_field

from .common import emit

DATASETS_QUICK = {
    "molecular": (20, 20, 12),
    "fingering": (24, 24, 24),
    "climate": (64, 128),
}


def run(quick: bool = True):
    for name, shape in DATASETS_QUICK.items():
        f = synthetic_field(name, shape=shape)
        rng = float(np.ptp(f))
        for rel in (1e-4, 5e-4):
            xi = rel * rng
            for base in ("szlike", "zfplike"):
                art = compress_preserving_mss(f, xi, base=base)
                ocr = overall_compression_ratio(f, art)
                # device-path artifacts split the base-transform time out
                # of t_comp (t_xform; 0 on the host path)
                emit(f"table2/{name}/{base}/rel={rel:g}",
                     (art.t_base + art.t_fix) * 1e6,
                     f"OCR={ocr:.2f};t_comp={art.t_base:.3f}s;"
                     f"t_fix={art.t_fix:.3f}s;t_xform={art.t_transform:.3f}s;"
                     f"path={art.path};edit_ratio={art.edit_ratio:.4f}")
        emit(f"table2/{name}/gzip", 0.0, f"CR={f.nbytes/gzip_like(f):.2f}")
        emit(f"table2/{name}/zstd", 0.0, f"CR={f.nbytes/zstd_like(f):.2f}")


if __name__ == "__main__":
    run()
