"""Fig. 9/10 reinterpretation: the paper's strong-scaling study sweeps CPU
threads; we sweep two axes instead:

* *problem size* on one device — flat vertices/s means the dense
  formulation scales linearly in V, the property the paper's
  parallelization targets;
* *device count* over the ('data',) mesh — the slab-sharded SPMD loop
  (repro.distributed.shardfix) on 1/2/4/8 devices of one field, the
  strong-scaling axis proper. On CPU hosts emulate devices with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before jax
  initializes); with one device the sweep reports the degenerate point
  only.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.compress.szlike import effective_step
from repro.core import field_topology, fused_fix
from repro.core.backend import get_backend
from repro.data import synthetic_field
from repro.launch.mesh import make_data_mesh

from .common import base_transform_closure, emit, timeit


def _field_pair(shape, rng):
    f = synthetic_field("fingering", shape=shape)
    xi = 1e-3 * float(np.ptp(f))
    g = jnp.asarray((f + rng.uniform(-xi, xi, size=shape))
                    .astype(np.float32))
    return f, g, xi


def run(quick: bool = True):
    sizes = [(16, 16, 16), (24, 24, 24), (32, 32, 32)]
    if not quick:
        sizes += [(48, 48, 48), (64, 64, 64)]
    # off-TPU the pallas backend runs in interpret mode (correctness
    # path); sweep it only in full runs to keep --quick fast on CPU
    backends = ("reference",) if quick else ("reference", "pallas")
    rng = np.random.default_rng(0)
    for shape in sizes:
        f, g, xi = _field_pair(shape, rng)
        topo = field_topology(jnp.asarray(f), xi)
        V = int(np.prod(shape))

        for backend in backends:
            def go():
                out, it, ok = fused_fix(g, topo, backend=backend)
                jax.block_until_ready(out)

            t = timeit(go, warmup=1, iters=3)
            emit(f"fig9/fused_fix/{backend}/V={V}", t, f"Mvert_s={V/t:.3f}")

            # base-transform time of the device-resident path, reported
            # separately from the fix loop (DESIGN.md §4): the fused
            # dispatch is transform -> reconstruct -> fix on-device
            step = effective_step(f, xi)
            t = timeit(base_transform_closure(get_backend(backend),
                                              jnp.asarray(f), step),
                       warmup=1, iters=3)
            emit(f"fig9/base_transform/{backend}/V={V}", t,
                 f"Mvert_s={V/t:.3f}")

    # -- device-count scaling of the sharded loop (one fixed field) ----
    n_avail = len(jax.devices())
    shape = (16, 16, 16) if quick else (32, 32, 32)
    f, g, xi = _field_pair(shape, rng)
    topo = field_topology(jnp.asarray(f), xi)
    V = int(np.prod(shape))
    for n_dev in (n for n in (1, 2, 4, 8) if n <= n_avail):
        mesh = make_data_mesh(n_dev)

        def go_sharded():
            out, it, ok = fused_fix(g, topo, backend="sharded", mesh=mesh)
            jax.block_until_ready(out)

        t = timeit(go_sharded, warmup=1, iters=3)
        emit(f"fig9/shardfix/ndev={n_dev}/V={V}", t, f"Mvert_s={V/t:.3f}")

        # sharded base transform (each device quantizes its own Z-slab)
        sb = get_backend("sharded").with_mesh(mesh)
        step = effective_step(f, xi)
        t = timeit(base_transform_closure(sb, jnp.asarray(f), step),
                   warmup=1, iters=3)
        emit(f"fig9/base_transform/sharded/ndev={n_dev}/V={V}", t,
             f"Mvert_s={V/t:.3f}")


if __name__ == "__main__":
    run()
