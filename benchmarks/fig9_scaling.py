"""Fig. 9/10 reinterpretation: the paper's strong-scaling study sweeps CPU
threads; on one CPU we sweep the *problem size* instead and report
throughput (vertices/s) of the end-to-end fix — flat throughput means the
dense formulation scales linearly in V, which is the property the paper's
parallelization targets."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import field_topology, fused_fix
from repro.data import synthetic_field

from .common import emit, timeit


def run(quick: bool = True):
    sizes = [(16, 16, 16), (24, 24, 24), (32, 32, 32)]
    if not quick:
        sizes += [(48, 48, 48), (64, 64, 64)]
    # off-TPU the pallas backend runs in interpret mode (correctness
    # path); sweep it only in full runs to keep --quick fast on CPU
    backends = ("reference",) if quick else ("reference", "pallas")
    rng = np.random.default_rng(0)
    for shape in sizes:
        f = synthetic_field("fingering", shape=shape)
        xi = 1e-3 * float(np.ptp(f))
        g = jnp.asarray((f + rng.uniform(-xi, xi, size=shape))
                        .astype(np.float32))
        topo = field_topology(jnp.asarray(f), xi)
        V = int(np.prod(shape))

        for backend in backends:
            def go():
                out, it, ok = fused_fix(g, topo, backend=backend)
                jax.block_until_ready(out)

            t = timeit(go, warmup=1, iters=3)
            emit(f"fig9/fused_fix/{backend}/V={V}", t, f"Mvert_s={V/t:.3f}")


if __name__ == "__main__":
    run()
