"""Fig. 9/10 reinterpretation: the paper's strong-scaling study sweeps CPU
threads; we sweep three axes instead:

* *problem size* on one device — flat vertices/s means the dense
  formulation scales linearly in V, the property the paper's
  parallelization targets;
* *device count* over the ('data',) mesh — the slab-sharded SPMD loop
  (repro.distributed.shardfix) on 1/2/4/8 devices of one field, the
  strong-scaling axis proper;
* *mesh shape* — 1D slab chains vs 2D block meshes at the same device
  count, with the compute/communication-overlap schedule on and off
  (DESIGN.md §9). This sweep writes ``BENCH_shard.json``;
  ``--check-regression`` fails the process when block decomposition at
  the top device count loses to the slab chain — the CI guard for the
  block-mesh PR's core claim.

On CPU hosts emulate devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before jax
initializes); with one device the sweeps report the degenerate point
only.

  PYTHONPATH=src python -m benchmarks.fig9_scaling --smoke --check-regression
  PYTHONPATH=src python -m benchmarks.run --only fig9
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.compress.szlike import effective_step
from repro.core import field_topology, fused_fix
from repro.core.backend import get_backend
from repro.data import synthetic_field
from repro.distributed import halo_plan, sharded_fix
from repro.launch.mesh import (factor_block_shape, make_block_mesh,
                               make_data_mesh)

from .common import base_transform_closure, emit, timeit

OUT_JSON = "BENCH_shard.json"


def _field_pair(shape, rng):
    f = synthetic_field("fingering", shape=shape)
    xi = 1e-3 * float(np.ptp(f))
    g = jnp.asarray((f + rng.uniform(-xi, xi, size=shape))
                    .astype(np.float32))
    return f, g, xi


def run(quick: bool = True):
    sizes = [(16, 16, 16), (24, 24, 24), (32, 32, 32)]
    if not quick:
        sizes += [(48, 48, 48), (64, 64, 64)]
    # off-TPU the pallas backend runs in interpret mode (correctness
    # path); sweep it only in full runs to keep --quick fast on CPU
    backends = ("reference",) if quick else ("reference", "pallas")
    rng = np.random.default_rng(0)
    for shape in sizes:
        f, g, xi = _field_pair(shape, rng)
        topo = field_topology(jnp.asarray(f), xi)
        V = int(np.prod(shape))

        for backend in backends:
            def go():
                out, it, ok = fused_fix(g, topo, backend=backend)
                jax.block_until_ready(out)

            t = timeit(go, warmup=1, iters=3)
            emit(f"fig9/fused_fix/{backend}/V={V}", t, f"Mvert_s={V/t:.3f}")

            # base-transform time of the device-resident path, reported
            # separately from the fix loop (DESIGN.md §4): the fused
            # dispatch is transform -> reconstruct -> fix on-device
            step = effective_step(f, xi)
            t = timeit(base_transform_closure(get_backend(backend),
                                              jnp.asarray(f), step),
                       warmup=1, iters=3)
            emit(f"fig9/base_transform/{backend}/V={V}", t,
                 f"Mvert_s={V/t:.3f}")

    # -- device-count scaling of the sharded loop (one fixed field) ----
    n_avail = len(jax.devices())
    shape = (16, 16, 16) if quick else (32, 32, 32)
    f, g, xi = _field_pair(shape, rng)
    topo = field_topology(jnp.asarray(f), xi)
    V = int(np.prod(shape))
    for n_dev in (n for n in (1, 2, 4, 8) if n <= n_avail):
        mesh = make_data_mesh(n_dev)

        def go_sharded():
            out, it, ok = fused_fix(g, topo, backend="sharded", mesh=mesh)
            jax.block_until_ready(out)

        t = timeit(go_sharded, warmup=1, iters=3)
        emit(f"fig9/shardfix/ndev={n_dev}/V={V}", t, f"Mvert_s={V/t:.3f}")

        # sharded base transform (each device quantizes its own Z-slab)
        sb = get_backend("sharded").with_mesh(mesh)
        step = effective_step(f, xi)
        t = timeit(base_transform_closure(sb, jnp.asarray(f), step),
                   warmup=1, iters=3)
        emit(f"fig9/base_transform/sharded/ndev={n_dev}/V={V}", t,
             f"Mvert_s={V/t:.3f}")

    # -- mesh-shape sweep: slab chain vs block mesh, overlap on/off ----
    bench_shard(quick=quick)


def _median_s(fn, reps: int = 3) -> float:
    """Median wall seconds over ``reps`` calls after one warm-up (the
    warm-up absorbs trace+compile so rows time steady-state dispatch)."""
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def bench_shard(quick: bool = True, check_regression: bool = False,
                out: str = OUT_JSON) -> Dict[str, object]:
    """Slab-vs-block mesh-shape sweep of the sharded fix loop on one
    field: 1D ``('data',)`` chains against 2D ``('data_y','data_z')``
    block meshes at matched device counts, the overlapped schedule on
    and off. Writes ``out`` and returns the document; with
    ``check_regression`` (and >= 4 devices available) the process fails
    when either (a) the best block configuration at the top device count
    moves MORE halo bytes per iteration than the slab chain — the
    deterministic face-vs-plane claim block decomposition exists for —
    or (b) it is slower beyond a generous wall-clock margin. The
    wall-clock margin is deliberately loose (1.5x): emulated host
    devices share cores, so smoke-sized timings jitter 30%+ run to run;
    the byte guard carries the strict claim, the time guard only
    catches gross scheduling regressions."""
    n_avail = len(jax.devices())
    shape = (16, 16, 16) if quick else (32, 32, 32)
    rng = np.random.default_rng(1)
    f, g, xi = _field_pair(shape, rng)
    topo = field_topology(jnp.asarray(f), xi)
    V = int(np.prod(shape))

    n_top = max(n for n in (1, 2, 4, 8) if n <= n_avail)
    configs = [("slab", make_data_mesh(n_top), None)]
    if not quick:
        for n in (2, 4):
            if n < n_top:
                configs.append(("slab", make_data_mesh(n), None))
    if n_top >= 4:
        bshape = factor_block_shape(n_top, 2)
        bmesh = make_block_mesh(bshape)
        configs.append(("block", bmesh, True))
        configs.append(("block", bmesh, False))

    rows = []
    for kind, mesh, ov in configs:
        n_dev = int(np.prod(mesh.devices.shape))
        mesh_shape = "x".join(str(s) for s in mesh.devices.shape)

        def go():
            out_g, it, ok = sharded_fix(g, topo, mesh, overlap=ov)
            jax.block_until_ready(out_g)

        t = _median_s(go)
        tag = f"fig9/shard/{kind}/mesh={mesh_shape}/overlap={ov}"
        emit(tag, t, f"Mvert_s={V/t:.3f}")
        rows.append(dict(
            kind=kind, mesh_shape=[int(s) for s in mesh.devices.shape],
            n_devices=n_dev, overlap=ov, median_s=t, vert_per_s=V / t,
            halo_bytes_per_iter={k: int(v) for k, v in halo_plan(
                shape, np.float32, mesh, overlap=ov).items()}))

    doc = dict(schema="msz-bench-shard/1", quick=bool(quick),
               jax_backend=jax.default_backend(), shape=list(shape),
               n_devices_available=n_avail, n_devices_top=n_top,
               max_slowdown_block_vs_slab=1.50, rows=rows)
    Path(out).write_text(json.dumps(doc, indent=2) + "\n")
    if check_regression:
        slab = [r for r in rows
                if r["kind"] == "slab" and r["n_devices"] == n_top]
        block = [r for r in rows if r["kind"] == "block"]
        if not block:
            raise SystemExit(
                f"regression guard needs >= 4 devices for a block mesh; "
                f"have {n_avail} (set XLA_FLAGS="
                "--xla_force_host_platform_device_count=8)")
        slab_bytes = sum(slab[0]["halo_bytes_per_iter"].values())
        block_bytes = min(sum(b["halo_bytes_per_iter"].values())
                          for b in block)
        if block_bytes >= slab_bytes:
            raise SystemExit(
                f"regression: block mesh at {n_top} devices moves "
                f"{block_bytes} halo bytes/iter vs slab {slab_bytes} — "
                f"face exchange must beat plane exchange; see {out}")
        best_block = max(b["vert_per_s"] for b in block)
        slab_rate = slab[0]["vert_per_s"]
        if best_block < slab_rate / doc["max_slowdown_block_vs_slab"]:
            raise SystemExit(
                f"regression: block mesh at {n_top} devices runs at "
                f"{best_block:,.0f} vert/s vs slab {slab_rate:,.0f} "
                f"(> {doc['max_slowdown_block_vs_slab']}x slower); "
                f"see {out}")
    return doc


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny field, shard sweep only (the CI leg)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes, all sweeps")
    ap.add_argument("--check-regression", action="store_true",
                    help="fail when block decomposition loses to the "
                         "1D slab chain at the top device count")
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        bench_shard(quick=True, check_regression=args.check_regression,
                    out=args.out)
    else:
        run(quick=not args.full)
        if args.check_regression:
            bench_shard(quick=not args.full,
                        check_regression=args.check_regression,
                        out=args.out)
