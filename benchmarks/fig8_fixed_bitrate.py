"""Fig. 8 reproduction: MSS/PSNR distortion vs overall bit rate — sweep
error bounds, record (OBR, PSNR, right-labeled-ratio) for raw lossy vs
MSz-corrected output (corrected is always 1.0 by construction; the plot's
content is the bitrate cost of that guarantee)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.compress import (compress_preserving_mss, decompress_artifact,
                            overall_bit_rate, psnr, sz_roundtrip)
from repro.core import segmentation_accuracy
from repro.data import synthetic_field

from .common import emit


def run(quick: bool = True):
    f = synthetic_field("climate", shape=(48, 96) if quick else (180, 360))
    rng = float(np.ptp(f))
    for rel in (1e-4, 1e-3, 1e-2):
        xi = rel * rng
        # raw lossy
        fh, nbytes = sz_roundtrip(f, xi)
        raw_obr = nbytes * 8 / f.size
        raw_acc = float(segmentation_accuracy(jnp.asarray(f), jnp.asarray(fh)))
        emit(f"fig8/raw_sz/rel={rel:g}", 0.0,
             f"OBR={raw_obr:.2f};PSNR={psnr(f, fh):.1f};right={raw_acc:.3f}")
        # MSz-corrected
        art = compress_preserving_mss(f, xi, base="szlike")
        g = decompress_artifact(art)
        emit(f"fig8/msz_sz/rel={rel:g}", 0.0,
             f"OBR={overall_bit_rate(f, art):.2f};PSNR={psnr(f, g):.1f};"
             f"right=1.000")


if __name__ == "__main__":
    run()
