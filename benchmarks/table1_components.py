"""Table 1 reproduction: timings of the paper's four parallelized
components on one field. The paper compares Serial / OpenMP / CUDA; here
the XLA-fused jnp path plays 'optimized parallel baseline' and the Pallas
kernels are the TPU-target implementation (timed in interpret mode on CPU,
so their numbers are a correctness exercise — the structural win is
recorded by the roofline analysis instead)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (field_topology, mss_labels, steepest_dirs,
                        false_critical_masks, fused_pass)
from repro.core.labels import pointer_jump
from repro.core.grid import dir_to_pointer
from repro.data import synthetic_field
from repro.kernels import extrema_masks, fix_pass

from .common import base_transform_closure, emit, timeit


def run(quick: bool = True):
    shape = (32, 32, 32) if quick else (64, 64, 64)
    f = synthetic_field("fingering", shape=shape)
    xi = 0.01 * float(np.ptp(f))
    rng = np.random.default_rng(0)
    g = jnp.asarray((f + rng.uniform(-xi, xi, size=shape)).astype(np.float32))
    fj = jnp.asarray(f)
    topo = field_topology(fj, xi)
    V = f.size

    # 1. update directions (fused with extrema classification)
    t = timeit(lambda: jax.block_until_ready(steepest_dirs(g)))
    emit("table1/update_directions/jnp", t, f"Mvert_s={V/t:.2f}")

    # 2. find false critical points
    t = timeit(lambda: jax.block_until_ready(false_critical_masks(g, topo)))
    emit("table1/find_false_points/jnp", t, f"Mvert_s={V/t:.2f}")

    # 3. fix false critical points (one fused pass)
    t = timeit(lambda: jax.block_until_ready(fused_pass(g, topo)))
    emit("table1/fix_false_points/jnp", t, f"Mvert_s={V/t:.2f}")

    # 4. MSS computation (pointer jumping / path compression)
    up, dn = steepest_dirs(g)
    nxt = dir_to_pointer(up)
    t = timeit(lambda: jax.block_until_ready(pointer_jump(nxt)))
    emit("table1/mss_computation/jnp", t, f"Mvert_s={V/t:.2f}")

    # 5. device base transform (quantize+Lorenzo forward + cumsum inverse;
    # the device-resident pipeline's base stage, DESIGN.md §4) — reported
    # SEPARATELY from the fix components so the fused dispatch's
    # base-vs-fix split shows up in the perf trajectory
    from repro.compress.szlike import effective_step
    from repro.core.backend import get_backend
    step = effective_step(f, xi)
    for be_name in ("reference",) + (("pallas",) if quick else ()):
        be = get_backend(be_name)
        t = timeit(base_transform_closure(be, fj, step),
                   iters=2 if be_name == "pallas" else 5)
        emit(f"table1/base_transform/{be_name}", t, f"Mvert_s={V/t:.2f}")

    # Pallas kernels (interpret mode on CPU; TPU path on real hardware)
    Mf, mf = topo.M, topo.m
    maxf = topo.is_max.astype(jnp.int32)
    minf = topo.is_min.astype(jnp.int32)
    if quick:
        t = timeit(lambda: jax.block_until_ready(
            extrema_masks(g, Mf, mf, topo.is_max, topo.is_min,
                          use_pallas=True)), iters=2)
        emit("table1/find+update/pallas_interpret", t, f"Mvert_s={V/t:.2f}")


if __name__ == "__main__":
    run()
