"""Fig. 11 reproduction: per-iteration trace of the alternating loops —
violation counts decrease as iterations progress (paper: time and
sub-iterations drop across outer iterations). Also contrasts the paper's
C/R alternation against our fused single-loop (beyond-paper)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import field_topology, fused_pass, derive_edits
from repro.data import synthetic_field

from .common import emit


def run(quick: bool = True):
    f = synthetic_field("molecular", shape=(16, 16, 12) if quick else (48, 48, 24))
    xi = 5e-3 * float(np.ptp(f))
    rng = np.random.default_rng(1)
    fh = (f + rng.uniform(-xi, xi, size=f.shape)).astype(np.float32)
    topo = field_topology(jnp.asarray(f), xi)

    g = jnp.asarray(fh)
    trace = []
    for i in range(100):
        g, viol = fused_pass(g, topo)
        v = int(viol)
        trace.append(v)
        if v == 0:
            break
    emit("fig11/fused/iters", 0.0,
         "trace=" + "|".join(str(v) for v in trace[:20]))

    res_paper = derive_edits(f, fh, xi, mode="paper")
    res_fused = derive_edits(f, fh, xi, mode="fused", backend="reference")
    res_pallas = derive_edits(f, fh, xi, mode="fused", backend="pallas")
    assert res_pallas.iters == res_fused.iters          # backend parity
    assert np.array_equal(res_pallas.g, res_fused.g)
    emit("fig11/outer_iters", 0.0,
         f"paper={res_paper.iters};fused={res_fused.iters};"
         f"edits_paper={res_paper.edit_ratio:.4f};"
         f"edits_fused={res_fused.edit_ratio:.4f}")


if __name__ == "__main__":
    run()
