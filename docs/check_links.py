"""README/markdown link check — the docs CI gate's second half.

Scans the repo's top-level markdown files for relative links and fails
(exit 1) when a target path does not exist. External (scheme-qualified)
links and pure anchors are skipped — this guards the cross-file pointers
(README -> DESIGN.md, CHANGES.md -> ...) that silently rot when files
move.

  python docs/check_links.py
"""
from __future__ import annotations

import pathlib
import re
import sys
from typing import List

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ("README.md", "DESIGN.md", "ROADMAP.md", "PAPER.md", "ISSUE.md")
_LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")


def broken_links() -> List[str]:
    """Every dangling relative link as a ``file: target`` string."""
    problems = []
    for doc in DOCS:
        path = REPO_ROOT / doc
        if not path.exists():
            continue
        for target in _LINK.findall(path.read_text()):
            if "://" in target or target.startswith(("#", "mailto:")):
                continue
            rel = target.split("#", 1)[0]
            if rel and not (path.parent / rel).exists():
                problems.append(f"{doc}: {target}")
    return problems


def main() -> int:
    """CLI entry: print dangling links and exit 1 when any exist."""
    problems = broken_links()
    for p in problems:
        print(f"BROKEN LINK: {p}")
    if problems:
        return 1
    print("markdown link check clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
