"""Docstring audit of the public API surface — the docs CI gate.

Walks every module under ``repro`` and fails (exit 1) when

* a module is missing its module docstring,
* a name exported via a package's ``__all__`` resolves to a function or
  class without a docstring, or
* a public method *defined on* an exported class (not inherited, not
  interpreter-generated) is missing one.

This is what keeps ``python -m pdoc repro`` useful: pdoc renders exactly
these surfaces, so an empty page here is a missing docstring there. Run
locally with

  PYTHONPATH=src python docs/audit_docstrings.py

``tests/test_docs.py`` runs the same collection in-process, so the gate
also holds under plain pytest (no pdoc needed).
"""
from __future__ import annotations

import importlib
import inspect
import pkgutil
import sys
from typing import List

ROOT_PACKAGE = "repro"


def _iter_module_names() -> List[str]:
    root = importlib.import_module(ROOT_PACKAGE)
    names = [ROOT_PACKAGE]
    for info in pkgutil.walk_packages(root.__path__, prefix=ROOT_PACKAGE + "."):
        names.append(info.name)
    return sorted(names)


def _audit_class(qualname: str, cls: type, problems: List[str]) -> None:
    for attr, member in vars(cls).items():
        if attr.startswith("_"):
            continue
        func = member.__func__ if isinstance(
            member, (classmethod, staticmethod)) else member
        if inspect.isfunction(func) and not inspect.getdoc(func):
            problems.append(f"{qualname}.{attr}: public method missing "
                            "docstring")


def collect_problems() -> List[str]:
    """Every missing-docstring finding on the public surface, as
    ``module.name: reason`` strings (empty list = audit passes)."""
    problems: List[str] = []
    for mod_name in _iter_module_names():
        try:
            mod = importlib.import_module(mod_name)
        except Exception as exc:                     # noqa: BLE001
            problems.append(f"{mod_name}: import failed: {exc!r}")
            continue
        if not inspect.getdoc(mod):
            problems.append(f"{mod_name}: module missing docstring")
        for name in getattr(mod, "__all__", ()):
            obj = getattr(mod, name, None)
            if obj is None:
                problems.append(f"{mod_name}.{name}: exported in __all__ "
                                "but not defined")
                continue
            if inspect.isfunction(obj) or inspect.isclass(obj):
                if not inspect.getdoc(obj):
                    problems.append(f"{mod_name}.{name}: exported name "
                                    "missing docstring")
                if inspect.isclass(obj):
                    _audit_class(f"{mod_name}.{name}", obj, problems)
    return sorted(set(problems))


def main() -> int:
    """CLI entry: print findings and exit 1 when any exist."""
    problems = collect_problems()
    for p in problems:
        print(f"MISSING: {p}")
    n_mod = len(_iter_module_names())
    if problems:
        print(f"\n{len(problems)} public-surface docstring problem(s) "
              f"across {n_mod} modules")
        return 1
    print(f"docstring audit clean: {n_mod} modules, all exported names "
          "documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
